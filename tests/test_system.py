"""End-to-end behaviour tests: the paper's Queries 1-3 as library calls."""

import numpy as np
import pytest

from repro.core import (Catalog, MockProvider, SemanticContext,
                        llm_embedding, llm_filter, llm_rerank,
                        reset_global_catalog, rrf)
from repro.engine import Pipeline, Table, ask
from repro.retrieval import BM25Index, VectorIndex


@pytest.fixture
def ctx():
    reset_global_catalog()
    c = SemanticContext()
    c.catalog.create_model("model-relevance-check", arch="mock",
                           scope="global")
    c.catalog.create_prompt("joins-prompt",
                            "is related to join algos given abstract")
    return c


@pytest.fixture
def papers():
    return Table({
        "id": list(range(6)),
        "title": ["Hash joins", "Sort-merge joins", "B-trees",
                  "Cyclic joins", "Vector DBs", "Hash joins"],
        "abstract": ["hash join algo", "merge join algo", "index struct",
                     "cyclic join queries wcoj", "ann search",
                     "hash join algo"],
    })


def test_query2_pipeline(ctx, papers):
    """Paper Query 2: filter -> summarize -> extract JSON, with chaining."""
    pipe = (Pipeline(ctx, papers, "research_papers")
            .llm_filter({"model_name": "model-relevance-check"},
                        {"prompt_name": "joins-prompt"},
                        ["title", "abstract"])
            .llm_complete("summary", {"model": "gpt-4o"},
                          {"prompt": "Summarize the abstract in 1 sentence"},
                          ["abstract"])
            .llm_complete_json("meta", {"model": "gpt-4o"},
                               {"prompt": "extract keywords"},
                               ["title", "abstract"]))
    out = pipe.collect()
    assert set(out.column_names) >= {"id", "title", "summary", "meta"}
    assert all(isinstance(m, dict) for m in out.column("meta"))
    plan = pipe.explain()
    assert "llm_filter" in plan and "batch_sizes" in plan


def test_query2_dedup_batching_visible(ctx, papers):
    pipe = Pipeline(ctx, papers, "p").llm_filter(
        {"model_name": "model-relevance-check"},
        {"prompt_name": "joins-prompt"}, ["title", "abstract"])
    pipe.collect()
    rep = ctx.reports[-1]
    assert rep.n_tuples == 6
    assert rep.n_unique == 5           # duplicate row predicted once
    assert rep.requests == 1           # batched into a single request


def test_query3_hybrid_search(ctx, papers):
    """Paper Query 3: embedding scan + BM25 + fusion + LLM rerank."""
    docs = papers.column("abstract")
    emb_model = {"model": "text-embedding-3-small", "embedding_dim": 64}
    bm = BM25Index.build(docs)
    b_idx, b_s = bm.topk("join algorithms in databases", 5)
    vi = VectorIndex(llm_embedding(ctx, emb_model, docs))
    q = llm_embedding(ctx, emb_model, ["join algorithms in databases"])
    v_s, v_idx = vi.topk(q, 5)

    full_b = np.full(len(docs), np.nan)
    full_b[b_idx] = b_s / max(b_s.max(), 1e-9)
    full_v = np.full(len(docs), np.nan)
    full_v[v_idx[0]] = v_s[0] / max(v_s[0].max(), 1e-9)
    fused = rrf(full_b, full_v)
    assert fused.shape == (len(docs),)
    order = np.argsort(-fused)

    top = [docs[i] for i in order[:4]]
    perm = llm_rerank(ctx, {"model": "gpt-4o"},
                      {"prompt": "mentions cyclic joins"},
                      [{"doc": d} for d in top])
    assert sorted(perm) == list(range(4))


def test_ask_demo(ctx, papers):
    sql, pipe = ask(ctx, papers,
                    "list reviews mentioning technical issues and assign a "
                    "severity score to each issue")
    assert "llm_filter" in sql
    out = pipe.collect()
    assert "assessment" in out.column_names


def test_resource_versioning(ctx):
    m1 = ctx.catalog.get_model("model-relevance-check")
    ctx.catalog.update_model("model-relevance-check", context_window=9999)
    m2 = ctx.catalog.get_model("model-relevance-check")
    assert m2.version == m1.version + 1
    assert m2.context_window == 9999
    # previous version stays addressable
    old = ctx.catalog.get_model(f"model-relevance-check@{m1.version}")
    assert old.context_window == m1.context_window
    # local shadows global
    ctx.catalog.create_model("model-relevance-check", arch="olmo-1b",
                             scope="local")
    assert ctx.catalog.get_model("model-relevance-check").arch == "olmo-1b"
