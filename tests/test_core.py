"""Unit tests: meta-prompt, provider protocol, rerank, catalog persistence."""

import json

import numpy as np
import pytest

from repro.core import (Catalog, ContextOverflowError, MockProvider,
                        SemanticContext, build_metaprompt, llm_complete,
                        llm_embedding, llm_filter, llm_first, llm_last,
                        llm_reduce, llm_reduce_json, llm_rerank,
                        reset_global_catalog)
from repro.core.fusion import fusion
from repro.core.resources import ModelResource


def test_metaprompt_prefix_stable_across_batches():
    """The static prefix must be byte-identical across calls (KV reuse)."""
    t1 = [{"a": "x"}]
    t2 = [{"a": "y"}, {"a": "z"}]
    m1 = build_metaprompt("filter", "is relevant?", t1)
    m2 = build_metaprompt("filter", "is relevant?", t2)
    assert m1.prefix == m2.prefix
    assert m1.suffix != m2.suffix


@pytest.mark.parametrize("fmt", ["xml", "json", "markdown"])
def test_metaprompt_serializations(fmt):
    mp = build_metaprompt("complete", "task", [{"a": 1, "b": "two"}], fmt)
    assert "task" in mp.prefix
    assert "two" in mp.suffix


def test_provider_context_overflow():
    p = MockProvider()
    model = ModelResource(name="m", version=1, arch="mock",
                          context_window=10, max_output_tokens=5)
    mp = build_metaprompt("complete", "x" * 500, [{"a": "b"}])
    with pytest.raises(ContextOverflowError):
        p.complete(model, mp, 1)


def test_filter_returns_booleans():
    ctx = SemanticContext()
    out = llm_filter(ctx, {"model": "m"}, {"prompt": "p"},
                     [{"v": i} for i in range(10)])
    assert all(isinstance(b, bool) for b in out)


def test_reduce_and_json():
    ctx = SemanticContext()
    rows = [{"v": i} for i in range(5)]
    s = llm_reduce(ctx, {"model": "m"}, {"prompt": "summarize"}, rows)
    assert isinstance(s, str)
    j = llm_reduce_json(ctx, {"model": "m"}, {"prompt": "summarize"}, rows)
    assert isinstance(j, dict)


def test_rerank_first_last_consistent():
    ctx = SemanticContext()
    rows = [{"doc": f"d{i}"} for i in range(7)]
    perm = llm_rerank(ctx, {"model": "m"}, {"prompt": "relevance"}, rows)
    assert sorted(perm) == list(range(7))
    assert llm_first(ctx, {"model": "m"}, {"prompt": "relevance"}, rows) \
        == rows[perm[0]]
    assert llm_last(ctx, {"model": "m"}, {"prompt": "relevance"}, rows) \
        == rows[perm[-1]]


def test_rerank_windowed_over_long_lists():
    ctx = SemanticContext()
    rows = [{"doc": f"d{i}"} for i in range(37)]
    perm = llm_rerank(ctx, {"model": "m"}, {"prompt": "q"}, rows,
                      window=10, stride=5)
    assert sorted(perm) == list(range(37))


def test_embedding_shape_and_dedup():
    ctx = SemanticContext()
    texts = ["a", "b", "a", "c", "b"]
    e = llm_embedding(ctx, {"model": "e", "embedding_dim": 16}, texts)
    assert e.shape == (5, 16)
    np.testing.assert_allclose(e[0], e[2])
    assert ctx.reports[-1].n_unique == 3


def test_catalog_persistence(tmp_path):
    path = tmp_path / "catalog.json"
    c1 = Catalog(str(path))
    c1.create_model("m", arch="olmo-1b", context_window=123)
    c1.create_prompt("p", "text-v1")
    c1.update_prompt("p", "text-v2")
    c2 = Catalog(str(path))
    assert c2.get_model("m").context_window == 123
    assert c2.get_prompt("p").text == "text-v2"
    assert c2.get_prompt("p@1").text == "text-v1"


def test_fusion_dispatch_unknown():
    with pytest.raises(ValueError):
        fusion("nope", np.ones(3))


def test_null_on_single_tuple_overflow():
    """Paper semantics: a single tuple exceeding the window -> NULL."""
    ctx = SemanticContext()
    rows = [{"v": "x" * 10_000}, {"v": "small"}]
    out = llm_complete(ctx, {"model": "m", "context_window": 512,
                             "max_output_tokens": 16},
                       {"prompt": "p"}, rows)
    assert out[0] is None
    assert out[1] is not None
    assert ctx.reports[-1].nulls == 1
