"""Async provider scheduler tests: determinism vs the serial path,
single-flight dedup of in-flight keys, overflow split-and-requeue under
concurrency, thread-safety of the shared counters, and the persistence
satellites (selectivity sidecar, prediction-cache compaction).
"""

import json
import threading
import time

import pytest

from repro.core import (Catalog, MockProvider, PredictionCache,
                        RequestScheduler, SelectivityStore,
                        SemanticContext, llm_complete, llm_embedding,
                        llm_filter, reset_global_catalog)
from repro.core.batching import ContextOverflowError
from repro.core.provider import ProviderStats
from repro.core.resources import ModelResource
from repro.engine import Pipeline, Table

MODEL = {"model": "m", "context_window": 700, "max_output_tokens": 8,
         "max_concurrency": 4}


def _table(n=24):
    return Table({
        "text": [f"review {i} about {'join' if i % 3 == 0 else 'index'} "
                 f"algorithms with a body" for i in range(n)],
        "year": [2000 + i % 20 for i in range(n)],
    })


def _resource(**kw) -> ModelResource:
    base = dict(name="m", version=1, arch="mock", context_window=4096,
                max_output_tokens=8, max_concurrency=4)
    base.update(kw)
    return ModelResource(**base)


# ---------------------------------------------------------------------------
# determinism: scheduled == serial, bit for bit
# ---------------------------------------------------------------------------
def _build(ctx, table):
    return (Pipeline(ctx, table, "reviews")
            .llm_filter(MODEL, {"prompt": "is about joins"}, ["text"])
            .llm_complete("summary", MODEL, {"prompt": "summarize"},
                          ["text"])
            .llm_complete_json("meta", MODEL, {"prompt": "extract"},
                               ["text"])
            .limit(8))


@pytest.mark.parametrize("optimize", [False, True])
def test_scheduled_results_identical_to_serial(optimize):
    reset_global_catalog()
    table = _table()
    ctx_s = SemanticContext(provider=MockProvider())
    rows_s = _build(ctx_s, table).collect(optimize=optimize).rows()
    with RequestScheduler() as sched:
        ctx_c = SemanticContext(provider=MockProvider(), scheduler=sched)
        rows_c = _build(ctx_c, table).collect(optimize=optimize).rows()
    assert rows_c == rows_s
    assert ctx_c.provider.stats.calls == ctx_s.provider.stats.calls
    assert (ctx_c.provider.stats.prompt_tokens
            == ctx_s.provider.stats.prompt_tokens)


def test_scheduled_embedding_identical_to_serial():
    texts = [f"passage {i}" for i in range(12)] * 2     # dups exercise dedup
    model = {"model": "e", "embedding_dim": 16}
    ctx_s = SemanticContext(provider=MockProvider())
    ref = llm_embedding(ctx_s, model, texts)
    with RequestScheduler() as sched:
        ctx_c = SemanticContext(provider=MockProvider(), scheduler=sched)
        out = llm_embedding(ctx_c, model, texts)
    assert out.shape == ref.shape
    assert (out == ref).all()
    assert ctx_c.provider.stats.calls == ctx_s.provider.stats.calls


# ---------------------------------------------------------------------------
# single-flight: concurrent identical cache-miss keys issue ONE request
# ---------------------------------------------------------------------------
def test_single_flight_dedups_concurrent_identical_jobs():
    rows = [{"t": f"row {i}"} for i in range(10)]
    model = dict(MODEL, context_window=4096)     # one batch
    prov = MockProvider(latency_per_call_s=0.25)
    with RequestScheduler() as sched:
        ctx = SemanticContext(provider=prov, scheduler=sched)
        out = [None, None]

        def call(slot):
            out[slot] = llm_complete(ctx, model, {"prompt": "p"}, rows)

        t1 = threading.Thread(target=call, args=(0,))
        t2 = threading.Thread(target=call, args=(1,))
        t1.start()
        time.sleep(0.05)        # t1's request is in flight, not done
        t2.start()
        t1.join()
        t2.join()
    assert out[0] == out[1]
    assert prov.stats.calls == 1, \
        "second job must coalesce onto the in-flight request"
    assert sched.stats.coalesced == 10


def test_single_flight_late_submitter_reads_cache():
    # once the owning job resolved and left the in-flight registry, a new
    # submit() sees the value via the cache re-check, not a new request
    rows = [{"t": "same"}]
    prov = MockProvider()
    with RequestScheduler() as sched:
        ctx = SemanticContext(provider=prov, scheduler=sched)
        a = llm_complete(ctx, MODEL, {"prompt": "p"}, rows)
        b = llm_complete(ctx, MODEL, {"prompt": "p"}, rows)
    assert a == b
    assert prov.stats.calls == 1


def test_no_coalescing_when_dedup_or_cache_disabled():
    # single-flight is an extension of the cache: with dedup or caching
    # off, duplicate keys must issue duplicate requests, exactly like
    # the serial path (count parity is the scheduler's core contract)
    rows = [{"t": "same"}] * 6
    for kw in ({"enable_dedup": False}, {"enable_cache": False}):
        ctx_s = SemanticContext(provider=MockProvider(), **kw)
        ref = llm_complete(ctx_s, MODEL, {"prompt": "p"}, rows)
        with RequestScheduler() as sched:
            ctx_c = SemanticContext(provider=MockProvider(),
                                    scheduler=sched, **kw)
            out = llm_complete(ctx_c, MODEL, {"prompt": "p"}, rows)
            assert out == ref
            assert (ctx_c.provider.stats.calls
                    == ctx_s.provider.stats.calls), kw
            assert sched.stats.coalesced == 0


def test_parallel_sibling_nodes_sharing_keys_match_serial_counts():
    # two concurrently-dispatched map nodes with the same model/prompt/
    # cols share cache keys; serial execution gives node 2 cache hits,
    # concurrent dispatch must coalesce to the same total request count
    table = Table({"text": [f"doc {i}" for i in range(12)]})
    model = dict(MODEL, context_window=900)

    def build(ctx):
        return (Pipeline(ctx, table)
                .llm_complete("a", model, {"prompt": "same"}, ["text"])
                .llm_complete("b", model, {"prompt": "same"}, ["text"]))

    ctx_s = SemanticContext(provider=MockProvider(), enable_dedup=False)
    rows_s = build(ctx_s).collect(optimize=False).rows()
    with RequestScheduler() as sched:
        ctx_c = SemanticContext(provider=MockProvider(), scheduler=sched,
                                enable_dedup=False)
        rows_c = build(ctx_c).collect(optimize=False).rows()
    assert rows_c == rows_s
    assert ctx_c.provider.stats.calls == ctx_s.provider.stats.calls


def test_duplicate_keys_inherit_borrowed_disposition():
    # dedup disabled + cache on, two concurrent jobs over duplicate
    # rows: job 2's first occurrence borrows job 1's in-flight entry,
    # and its duplicates must inherit that borrow (the serial path
    # would see cache hits for all of them) — one provider call total
    rows = [{"t": "same"}] * 6
    prov = MockProvider(latency_per_call_s=0.25)
    with RequestScheduler() as sched:
        ctx = SemanticContext(provider=prov, scheduler=sched,
                              enable_dedup=False)
        out = [None, None]

        def call(slot):
            out[slot] = llm_complete(ctx, MODEL, {"prompt": "p"}, rows)

        t1 = threading.Thread(target=call, args=(0,))
        t2 = threading.Thread(target=call, args=(1,))
        t1.start()
        time.sleep(0.05)
        t2.start()
        t1.join()
        t2.join()
    assert out[0] == out[1]
    assert prov.stats.calls == 1, \
        "duplicates of a borrowed key must not issue their own requests"


def test_borrower_sees_owner_error_not_none():
    # if the owning job's provider request dies, a coalesced borrower
    # must re-raise the error, not return silent NULLs
    rows = [{"t": f"row {i}"} for i in range(4)]

    def bad(kind, prefix, batch_rows):
        time.sleep(0.2)
        raise RuntimeError("provider down")

    prov = MockProvider(bad)
    with RequestScheduler() as sched:
        ctx = SemanticContext(provider=prov, scheduler=sched)
        errors = []

        def call():
            try:
                llm_complete(ctx, MODEL, {"prompt": "p"}, rows)
            except Exception as exc:        # noqa: BLE001 - recording
                errors.append(exc)

        t1 = threading.Thread(target=call)
        t2 = threading.Thread(target=call)
        t1.start()
        time.sleep(0.05)
        t2.start()
        t1.join()
        t2.join()
    assert len(errors) == 2
    assert all(isinstance(e, RuntimeError) for e in errors)


# ---------------------------------------------------------------------------
# overflow split-and-requeue inside the scheduler
# ---------------------------------------------------------------------------
def test_overflow_splits_and_requeues_under_concurrency():
    with RequestScheduler(max_workers=4) as sched:
        calls = []

        def run(batch):
            calls.append(list(batch))
            if len(batch) > 3:
                raise ContextOverflowError("too big")
            return [f"v{p}" for p in batch]

        keys = [f"k{i}" for i in range(20)]
        job = sched.submit(_resource(), keys, run,
                           batches=[list(range(20))])
        values, stats = job.result(timeout=10)
    assert values == [f"v{i}" for i in range(20)]
    assert stats.retries > 0
    assert stats.nulls == 0
    # batch_sizes records successful requests only: every one must have
    # been split below the overflow threshold, covering all 20 positions
    assert sum(stats.batch_sizes) == 20
    assert all(s <= 3 for s in stats.batch_sizes)
    assert calls[0] == list(range(20))       # the original oversized batch


def test_overflow_single_tuple_yields_null():
    with RequestScheduler(max_workers=2) as sched:
        def run(batch):
            raise ContextOverflowError("always")

        job = sched.submit(_resource(), ["a", "b"], run,
                           batches=[[0], [1]])
        values, stats = job.result(timeout=10)
    assert values == [None, None]
    assert stats.nulls == 2


def test_overflow_end_to_end_matches_serial():
    # tight context window: the planner's estimate under-counts the row
    # wrappers, so real provider overflows trigger the split protocol,
    # which must land on the same results/nulls as the serial path
    rows = [{"t": f"x{i}"} for i in range(6)] + [{"t": "y" * 4000}]
    model = {"model": "m", "context_window": 200, "max_output_tokens": 4}
    ctx_s = SemanticContext(provider=MockProvider(), enable_dedup=False,
                            enable_cache=False)
    ref = llm_complete(ctx_s, model, {"prompt": "p"}, rows)
    with RequestScheduler() as sched:
        ctx_c = SemanticContext(provider=MockProvider(), scheduler=sched,
                                enable_dedup=False, enable_cache=False)
        out = llm_complete(ctx_c, model, {"prompt": "p"}, rows)
    assert out == ref
    assert out[-1] is None          # the oversized tuple is NULL both ways
    assert any(v is not None for v in out[:-1])
    assert ctx_c.reports[-1].nulls == ctx_s.reports[-1].nulls
    assert ctx_s.reports[-1].retries > 0


# ---------------------------------------------------------------------------
# per-model concurrency + node-level overlap
# ---------------------------------------------------------------------------
def test_max_concurrency_bounds_inflight_requests():
    n_batches, seen = 8, []
    lock = threading.Lock()
    live = [0]

    def run(batch):
        with lock:
            live[0] += 1
            seen.append(live[0])
        time.sleep(0.03)
        with lock:
            live[0] -= 1
        return [f"v{p}" for p in batch]

    with RequestScheduler(max_workers=16) as sched:
        job = sched.submit(_resource(max_concurrency=2),
                           [f"k{i}" for i in range(n_batches)], run,
                           batches=[[i] for i in range(n_batches)])
        job.result(timeout=10)
    assert max(seen) <= 2
    assert sched.stats.max_inflight <= 2


def test_independent_nodes_overlap_wall_clock():
    table = Table({"text": [f"doc {i}" for i in range(6)]})
    model = {"model": "m", "context_window": 8192, "max_output_tokens": 4,
             "max_concurrency": 8}

    def build(ctx):
        return (Pipeline(ctx, table)
                .llm_complete("a", model, {"prompt": "p1"}, ["text"])
                .llm_complete("b", model, {"prompt": "p2"}, ["text"])
                .llm_complete("c", model, {"prompt": "p3"}, ["text"]))

    # latency large enough that thread-wakeup noise under a loaded
    # suite run cannot eat the 0.75x overlap margin
    ctx_s = SemanticContext(provider=MockProvider(latency_per_call_s=0.12),
                            enable_cache=False)
    t0 = time.perf_counter()
    rows_s = build(ctx_s).collect(optimize=False).rows()
    dt_serial = time.perf_counter() - t0

    with RequestScheduler() as sched:
        ctx_c = SemanticContext(
            provider=MockProvider(latency_per_call_s=0.12),
            scheduler=sched, enable_cache=False)
        t0 = time.perf_counter()
        rows_c = build(ctx_c).collect(optimize=False).rows()
        dt_sched = time.perf_counter() - t0
    assert rows_c == rows_s
    assert dt_sched < 0.75 * dt_serial, \
        f"no overlap: scheduled {dt_sched:.3f}s vs serial {dt_serial:.3f}s"


def test_coalesced_positions_repack_densely():
    # keys served by the cache re-check must not leave sparse batches:
    # the surviving owned positions re-plan through plan_batches
    cache = PredictionCache()
    for i in range(0, 10, 2):
        cache.put(f"k{i}", f"cached{i}")
    with RequestScheduler() as sched:
        job = sched.submit_map(
            _resource(context_window=60, max_output_tokens=8),
            [f"k{i}" for i in range(10)], [10] * 10, 0,
            lambda batch: [f"v{p}" for p in batch], cache=cache)
        values, stats = job.result(timeout=10)
    assert values == [f"cached{i}" if i % 2 == 0 else f"v{i}"
                      for i in range(10)]
    assert job.late_hits == 5       # cache peeks, not in-flight sharing
    assert job.coalesced == 0
    # 5 owned positions at 18 tokens each under a 60-token budget pack
    # as [3, 2]; filtering the 10-key plan would have given 4 requests
    assert stats.batch_sizes == [3, 2]


def test_model_gate_most_restrictive_limit_wins():
    with RequestScheduler() as sched:
        g1 = sched._model_gate(_resource(max_concurrency=8))
        g2 = sched._model_gate(_resource(max_concurrency=2))
        g3 = sched._model_gate(_resource(max_concurrency=8))
    assert g1 is g2 is g3
    assert g3.limit == 2        # limits only shrink, never grow


def test_dispatch_groups_respect_def_use_edges():
    from repro.engine.pipeline import PlanNode

    def node(op, cols, out=None):
        return PlanNode(op, {"cols": cols, "out": out})

    a = node("llm_complete", ["text"], "a")
    b = node("llm_complete", ["text"], "b")
    dep = node("llm_complete", ["a"], "c")       # reads a's output
    flt = node("llm_filter", ["text"])
    groups = Pipeline._dispatch_groups([a, b, dep, flt])
    assert [len(g) for g in groups] == [2, 1, 1]
    assert groups[0] == [a, b]


# ---------------------------------------------------------------------------
# stress: speculative fan-out + concurrent map groups on ONE model
# ---------------------------------------------------------------------------
def test_mixed_speculative_and_map_load_respects_gates_no_starvation():
    # a speculative filter-chain fan-out and several concurrently
    # collected map pipelines all target the same model: the per-model
    # max_concurrency gate must still bound in-flight requests, and
    # every pipeline must complete (the gate's parking queue hands
    # slots off fairly — no job starves behind the fan-out)
    reset_global_catalog()
    n = 30
    table = Table({"text": [f"doc {i} {'join' if i % 2 else 'scan'} body"
                            for i in range(n)]})
    model = {"model": "shared", "context_window": 650,
             "max_output_tokens": 8, "max_concurrency": 2}

    def build_chain(ctx):
        return (Pipeline(ctx, table, "chain")
                .llm_filter(model, {"prompt": "is about joins"}, ["text"])
                .llm_filter(model, {"prompt": "is long"}, ["text"]))

    def build_map(ctx, k):
        return (Pipeline(ctx, table, f"map{k}")
                .llm_complete(f"out{k}", model, {"prompt": f"task {k}"},
                              ["text"]))

    # serial reference results (fresh context per pipeline: no cache
    # sharing, so every run issues its own requests)
    refs = {}
    refs["chain"] = build_chain(
        SemanticContext(provider=MockProvider())).collect(
            speculate=False).rows()
    for k in range(3):
        refs[k] = build_map(
            SemanticContext(provider=MockProvider()), k).collect().rows()

    with RequestScheduler(max_workers=16) as sched:
        ctx = SemanticContext(provider=MockProvider(
            latency_per_call_s=0.005), scheduler=sched)
        results, errors = {}, []

        def run_chain():
            try:
                results["chain"] = build_chain(ctx).collect(
                    speculate="always").rows()
            except Exception as exc:        # noqa: BLE001 - recording
                errors.append(exc)

        def run_map(k):
            try:
                results[k] = build_map(ctx, k).collect().rows()
            except Exception as exc:        # noqa: BLE001 - recording
                errors.append(exc)

        threads = [threading.Thread(target=run_chain)] + [
            threading.Thread(target=run_map, args=(k,)) for k in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        stalled = [t for t in threads if t.is_alive()]
    assert not stalled, "pipelines starved under mixed speculative load"
    assert not errors, errors
    assert sched.stats.max_inflight <= 2, \
        f"max_concurrency gate violated: {sched.stats.max_inflight}"
    for key, ref in refs.items():
        assert results[key] == ref, f"pipeline {key} diverged"


# ---------------------------------------------------------------------------
# thread-safety stress: shared counters under the worker pool
# ---------------------------------------------------------------------------
def test_provider_stats_thread_safety_stress():
    stats = ProviderStats()
    n_threads, n_iter = 8, 2000

    def worker():
        for _ in range(n_iter):
            stats.add(calls=1, prompt_tokens=3, output_tokens=2)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert stats.calls == n_threads * n_iter
    assert stats.prompt_tokens == 3 * n_threads * n_iter
    assert stats.output_tokens == 2 * n_threads * n_iter


def test_prediction_cache_thread_safety_stress(tmp_path):
    cache = PredictionCache(capacity=500,
                            persist_path=str(tmp_path / "c.jsonl"))
    n_threads, n_iter = 8, 400

    def worker(tid):
        for i in range(n_iter):
            key = f"k{(tid * 7 + i) % 300}"
            cache.put(key, f"v{i % 5}")
            cache.get(key)
            cache.peek(key)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(cache._data) <= 300
    cache.compact()
    reloaded = PredictionCache(persist_path=str(tmp_path / "c.jsonl"))
    assert set(reloaded._data) == set(cache._data)


# ---------------------------------------------------------------------------
# satellite: prediction-cache persistence growth
# ---------------------------------------------------------------------------
def test_cache_noop_puts_do_not_grow_file(tmp_path):
    path = tmp_path / "cache.jsonl"
    cache = PredictionCache(persist_path=str(path))
    for _ in range(50):
        cache.put("k", "v")              # 49 re-puts of an identical entry
    assert len(path.read_text().splitlines()) == 1
    cache.put("k", "v2")                 # value change IS appended
    assert len(path.read_text().splitlines()) == 2


def test_cache_compact_rewrites_from_live_lru(tmp_path):
    path = tmp_path / "cache.jsonl"
    cache = PredictionCache(capacity=10, persist_path=str(path))
    for i in range(30):
        cache.put(f"k{i}", f"v{i}")      # 20 evicted from the LRU
    assert len(path.read_text().splitlines()) == 30
    cache.compact()
    lines = path.read_text().splitlines()
    assert len(lines) == 10
    assert {json.loads(ln)["k"] for ln in lines} \
        == {f"k{i}" for i in range(20, 30)}
    reloaded = PredictionCache(persist_path=str(path))
    assert reloaded.get("k29") == (True, "v29")


# ---------------------------------------------------------------------------
# satellite: selectivity stats persistence sidecar
# ---------------------------------------------------------------------------
def test_selectivity_persists_across_sessions(tmp_path):
    reset_global_catalog()
    cache_path = str(tmp_path / "cache.jsonl")
    catalog = Catalog()
    catalog.create_prompt("joins", "is about joins")
    rows = [{"t": f"{'join' if i % 4 == 0 else 'scan'} {i}"}
            for i in range(16)]

    ctx1 = SemanticContext(catalog=catalog,
                           cache=PredictionCache(persist_path=cache_path))
    llm_filter(ctx1, MODEL, {"prompt_name": "joins"}, rows)
    ref = catalog.get_prompt("joins").ref
    sel = ctx1.expected_selectivity(ref, default=-1.0)
    assert sel >= 0.0
    assert (tmp_path / "cache.jsonl.selectivity.json").exists()

    # fresh session, same sidecar: stats are warm before any execution
    ctx2 = SemanticContext(catalog=catalog,
                           cache=PredictionCache(persist_path=cache_path))
    assert ctx2.expected_selectivity(ref, default=-1.0) == sel


def test_selectivity_invalidated_on_prompt_version_bump(tmp_path):
    reset_global_catalog()
    cache_path = str(tmp_path / "cache.jsonl")
    catalog = Catalog()
    catalog.create_prompt("joins", "is about joins")
    ctx1 = SemanticContext(catalog=catalog,
                           cache=PredictionCache(persist_path=cache_path))
    old_ref = catalog.get_prompt("joins").ref
    ctx1.record_selectivity(old_ref, 3, 10)

    catalog.update_prompt("joins", "is strictly about join algorithms")
    ctx2 = SemanticContext(catalog=catalog,
                           cache=PredictionCache(persist_path=cache_path))
    # stale version's stats are pruned; the new version starts fresh
    assert ctx2.expected_selectivity(old_ref, default=-1.0) == -1.0
    assert ctx2.expected_selectivity(catalog.get_prompt("joins").ref,
                                     default=-1.0) == -1.0


def test_selectivity_store_roundtrip_and_corruption(tmp_path):
    store = SelectivityStore(str(tmp_path / "s.json"))
    assert store.load() == {}
    store.save({"p@1": [3, 10], "inline:x": [1, 2]})
    assert store.load() == {"p@1": [3, 10], "inline:x": [1, 2]}
    (tmp_path / "s.json").write_text("{not json")
    assert store.load() == {}
