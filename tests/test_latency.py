"""Latency-first scheduling tests: monotonic latency measurement (the
provider must not mix wall clock and monotonic clock with the
scheduler), CalibrationStore self-heal on corrupt legacy sidecars, the
calibrated co-pack linger window, and the ``objective`` knob's surface
through the optimizer and ``explain()``.
"""

import json
import time

import pytest

from repro.core import (MockProvider, RequestScheduler, SemanticContext,
                        llm_complete, reset_global_catalog)
from repro.core.cache import CalibrationStore
from repro.core.metaprompt import build_metaprompt
from repro.core.resources import ModelResource
from repro.core.scheduler import (PACK_LINGER_LATENCY_FRACTION,
                                  PACK_LINGER_MIN_S)
from repro.engine import Pipeline, Table, copack_identity

_MODEL = {"model": "cp", "context_window": 100_000,
          "max_output_tokens": 8, "max_concurrency": 8}


def _two_node_pipe(ctx, n=22):
    table = Table({
        "a": [f"first column text number {i} with body" for i in range(n)],
        "b": [f"second column text number {i} with body"
              for i in range(n)],
    })
    return (Pipeline(ctx, table, "docs")
            .llm_complete("s1", _MODEL, {"prompt": "summarize"}, ["a"])
            .llm_complete("s2", _MODEL, {"prompt": "summarize"}, ["b"]))


# ---------------------------------------------------------------------------
# bugfix: provider latency measurement must be monotonic
# ---------------------------------------------------------------------------
def test_mock_provider_latency_survives_wall_clock_step(monkeypatch):
    # an NTP step (wall clock jumping backwards mid-request) must not
    # record a negative latency — the scheduler's deadlines are
    # monotonic, so the provider's measurements must be too
    import repro.core.provider as pm
    steps = iter([1e9 - 100.0 * i for i in range(64)])
    monkeypatch.setattr(pm.time, "time", lambda: next(steps))
    model = ModelResource(name="m", version=1, arch="mock",
                          context_window=4096, max_output_tokens=8,
                          max_concurrency=4)
    prov = pm.MockProvider()
    mp = build_metaprompt("complete", "p", [{"t": "x"}], "xml")
    out = prov.complete(model, mp, 1)
    assert len(out) == 1
    assert prov.stats.latency_s >= 0.0, \
        "latency went negative: wall clock used instead of monotonic"


def test_calibration_latencies_nonnegative_under_clock_step(monkeypatch):
    import repro.core.provider as pm
    steps = iter([1e9 - 100.0 * i for i in range(4096)])
    monkeypatch.setattr(pm.time, "time",
                        lambda: next(steps, 0.0))
    ctx = SemanticContext(provider=MockProvider())
    llm_complete(ctx, _MODEL, {"prompt": "p"},
                 [{"t": f"row {i}"} for i in range(4)])
    for rec in ctx.calibration_stats.values():
        assert all(x >= 0 for x in rec["latency_s"])


# ---------------------------------------------------------------------------
# bugfix: CalibrationStore self-heals corrupt legacy sidecars
# ---------------------------------------------------------------------------
def test_calibration_store_drops_negative_latency_values(tmp_path):
    # a sidecar written before the monotonic fix may hold negative
    # latencies; the record must load with the bad SAMPLES dropped, not
    # be discarded wholesale (the counters are still good)
    path = tmp_path / "c.json"
    path.write_text(
        '{"models": {"m@1": {"requests": 10, "retries": 1, '
        '"tuples": 50, "latency_s": '
        '[0.1, -3.0, Infinity, NaN, true, "bogus", 0.2]}}}')
    loaded = CalibrationStore(str(path)).load()
    assert loaded["m@1"]["requests"] == 10
    assert loaded["m@1"]["retries"] == 1
    assert loaded["m@1"]["latency_s"] == [0.1, 0.2]


def test_calibration_store_still_rejects_malformed_records(tmp_path):
    path = tmp_path / "c.json"
    path.write_text(json.dumps({"models": {
        "a@1": {"requests": -1, "retries": 0, "tuples": 0,
                "latency_s": []},
        "b@1": {"requests": 1, "retries": 0, "tuples": 2,
                "latency_s": "oops"},
        "c@1": {"requests": 1, "retries": 0, "tuples": 2,
                "latency_s": [0.3]},
    }}))
    loaded = CalibrationStore(str(path)).load()
    assert set(loaded) == {"c@1"}
    assert loaded["c@1"]["latency_s"] == [0.3]


# ---------------------------------------------------------------------------
# calibrated linger window
# ---------------------------------------------------------------------------
def test_copack_linger_calibrated_window():
    with RequestScheduler(pack_linger_s=0.5) as sched:
        ctx = SemanticContext(provider=MockProvider(), scheduler=sched)
        assert ctx.copack_linger("m@1") is None      # uncalibrated
        ctx.record_calibration("m@1", requests=4, retries=0, tuples=8,
                               latencies=[0.1] * 4)
        assert ctx.copack_linger("m@1") == pytest.approx(
            PACK_LINGER_LATENCY_FRACTION * 0.1)
        # capped at the scheduler's configured window
        ctx.record_calibration("slow@1", requests=4, retries=0,
                               tuples=8, latencies=[10.0] * 4)
        assert ctx.copack_linger("slow@1") == 0.5
        # floored for very fast models
        ctx.record_calibration("fast@1", requests=4, retries=0,
                               tuples=8, latencies=[1e-5] * 4)
        assert ctx.copack_linger("fast@1") == PACK_LINGER_MIN_S
    # the cost objective keeps the fixed window (density dial)
    with RequestScheduler(pack_linger_s=0.5) as sched:
        ctx = SemanticContext(provider=MockProvider(), scheduler=sched,
                              objective="cost")
        ctx.record_calibration("m@1", requests=4, retries=0, tuples=8,
                               latencies=[0.1] * 4)
        assert ctx.copack_linger("m@1") is None
    # no scheduler: nothing to linger
    ctx = SemanticContext(provider=MockProvider())
    ctx.record_calibration("m@1", requests=4, retries=0, tuples=8,
                           latencies=[0.1] * 4)
    assert ctx.copack_linger("m@1") is None


def test_parked_tail_deadline_respects_calibrated_window():
    # a parked segment is never older than the calibrated window: with
    # a 30s configured linger but ~0.2s observed latency, a tail whose
    # rider never shows dispatches on the ~0.1s calibrated deadline
    reset_global_catalog()
    rows = [{"a": f"text number {i} with body"} for i in range(22)]
    with RequestScheduler(pack_linger_s=30.0) as sched:
        ctx = SemanticContext(provider=MockProvider(), scheduler=sched,
                              max_batch=16)
        ref = ctx.resolve_model(_MODEL).ref
        ctx.record_calibration(ref, requests=4, retries=0, tuples=64,
                               latencies=[0.2] * 4)
        probe = Pipeline(ctx, Table({"a": [r["a"] for r in rows]}), "d") \
            .llm_complete("s", _MODEL, {"prompt": "summarize"}, ["a"])
        ident = copack_identity(ctx, probe.nodes[-1])
        t0 = time.monotonic()
        ctx.copack_begin({ident: 2})     # a rider is expected...
        try:
            out = llm_complete(ctx, _MODEL, {"prompt": "summarize"},
                               rows)
        finally:
            ctx.copack_end({ident: 2})   # ...but never dispatches
        elapsed = time.monotonic() - t0
    assert len(out) == len(rows) and all(v is not None for v in out)
    assert elapsed < 5.0, \
        f"parked tail waited {elapsed:.1f}s: calibrated deadline ignored"
    assert sched.stats.packed_requests == 0


# ---------------------------------------------------------------------------
# objective knob: context / collect / optimizer / explain
# ---------------------------------------------------------------------------
def test_objective_validation():
    with pytest.raises(ValueError):
        SemanticContext(provider=MockProvider(), objective="bogus")
    ctx = SemanticContext(provider=MockProvider())
    assert ctx.objective == "latency"
    pipe = _two_node_pipe(ctx, n=4)
    with pytest.raises(ValueError):
        pipe.collect(objective="bogus")


def test_collect_objective_override_restores_context():
    reset_global_catalog()
    ctx = SemanticContext(provider=MockProvider(), max_batch=16)
    pipe = _two_node_pipe(ctx)
    rows_default = pipe.collect().rows()
    rows_cost = pipe.collect(objective="cost").rows()
    assert rows_cost == rows_default, \
        "the objective is a scheduling knob: rows must be identical"
    assert ctx.objective == "latency"


def test_explain_reports_objective_frontiers():
    reset_global_catalog()
    with RequestScheduler() as sched:
        ctx = SemanticContext(provider=MockProvider(), scheduler=sched,
                              max_batch=16)
        pipe = _two_node_pipe(ctx)
        text = pipe.explain()
        plan = pipe._plan()
        cost_plan = pipe._plan(objective="cost")
    assert "Objectives:" in text
    assert "latency:" in text and "cost:" in text
    assert "<- active" in text
    assert plan.objective == "latency"
    assert cost_plan.objective == "cost"
    assert plan.frontiers["latency"]["packed_req"] \
        == plan.optimized_cost.packed_requests
    # uncalibrated: no wall estimate on either frontier
    assert plan.frontiers["latency"]["est_wall"] is None
    assert "est_wall=uncalibrated" in text


def test_frontiers_price_pack_wait_when_calibrated():
    # the cost frontier's wall estimate carries the linger the density
    # dial would spend waiting for merges; the latency frontier doesn't
    reset_global_catalog()
    with RequestScheduler(pack_linger_s=0.5) as sched:
        ctx = SemanticContext(provider=MockProvider(), scheduler=sched,
                              max_batch=16)
        ctx.record_calibration(ctx.resolve_model(_MODEL).ref,
                               requests=4, retries=0, tuples=64,
                               latencies=[0.05] * 4)
        plan = _two_node_pipe(ctx)._plan()
    fr = plan.frontiers
    assert fr["latency"]["est_wall"] is not None
    assert plan.optimized_cost.pack_wait_s > 0
    assert fr["cost"]["est_wall"] == pytest.approx(
        fr["latency"]["est_wall"] + plan.optimized_cost.pack_wait_s)
