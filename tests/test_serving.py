"""Serving engine: continuous batching == one-shot oracle; chunked prefill."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import ServingEngine


def _oracle(cfg, params, prompt, n_new, cache_len=64):
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    lg, cache, pos = M.prefill(cfg, params, batch, cache_len)
    toks = [int(jnp.argmax(lg[0, -1]))]
    for i in range(n_new - 1):
        lg, cache = M.decode_step(
            cfg, params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
            jnp.int32(pos + i))
        toks.append(int(jnp.argmax(lg[0, 0])))
    return toks


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["olmo-1b", "falcon-mamba-7b",
                                  "recurrentgemma-9b"])
def test_engine_matches_oracle(arch, rng):
    cfg = get_smoke_config(arch).replace(remat=False, capacity_factor=16.0)
    eng = ServingEngine(cfg, n_slots=2, max_context=64, chunk=8, seed=0)
    prompt = list(rng.integers(0, cfg.vocab_size, 21))
    out = eng.generate(prompt, max_new_tokens=5)
    assert out == _oracle(cfg, eng.params, prompt, 5)


def test_concurrent_requests_isolated(rng):
    """Two in-flight requests produce the same tokens as each alone."""
    cfg = get_smoke_config("olmo-1b").replace(remat=False)
    p1 = list(rng.integers(0, cfg.vocab_size, 21))
    p2 = list(rng.integers(0, cfg.vocab_size, 13))

    eng = ServingEngine(cfg, n_slots=2, max_context=64, chunk=8, seed=0)
    r1, r2 = eng.submit(p1, 5), eng.submit(p2, 5)
    eng.run_until_idle()

    solo = ServingEngine(cfg, n_slots=2, max_context=64, chunk=8, seed=0)
    assert r1.generated == solo.generate(p1, 5)
    solo2 = ServingEngine(cfg, n_slots=2, max_context=64, chunk=8, seed=0)
    assert r2.generated == solo2.generate(p2, 5)


def test_more_requests_than_slots(rng):
    cfg = get_smoke_config("olmo-1b").replace(remat=False)
    eng = ServingEngine(cfg, n_slots=2, max_context=64, chunk=8)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 9)), 3)
            for _ in range(5)]
    eng.run_until_idle()
    assert all(r.finished for r in reqs)
    assert all(len(r.generated) == 3 for r in reqs)


def test_oversized_request_rejected():
    cfg = get_smoke_config("olmo-1b").replace(remat=False)
    eng = ServingEngine(cfg, n_slots=1, max_context=32, chunk=8)
    r = eng.submit(list(range(30)), max_new_tokens=10)
    eng.run_until_idle()
    assert r.finished and r.generated == []


def test_embedding_deterministic_and_normalised():
    cfg = get_smoke_config("olmo-1b").replace(remat=False)
    eng = ServingEngine(cfg, n_slots=1, max_context=64)
    e1 = eng.embed([1, 2, 3, 4])
    e2 = eng.embed([1, 2, 3, 4])
    e3 = eng.embed([5, 6, 7])
    assert np.allclose(e1, e2)
    assert not np.allclose(e1, e3)
    assert abs(np.linalg.norm(e1) - 1.0) < 1e-3


def test_chunked_prefill_equals_full_prefill(rng):
    """prefill_chunk chain == one-shot prefill (cache + logits)."""
    for arch in ["olmo-1b", "falcon-mamba-7b"]:
        cfg = get_smoke_config(arch).replace(remat=False)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        full = {"tokens": jnp.asarray([prompt])}
        lg_full, cache_full, _ = M.prefill(cfg, params, full, 32)

        cache = M.init_cache(cfg, 1, 32)
        off = 0
        for c0 in range(0, 16, 8):
            chunk = jnp.asarray([prompt[c0:c0 + 8]])
            lg, cache = M.prefill_chunk(cfg, params, chunk, cache,
                                        jnp.int32(off))
            off += 8
        np.testing.assert_allclose(np.asarray(lg[:, -1]),
                                   np.asarray(lg_full[:, -1]),
                                   atol=2e-3, rtol=2e-3)
