"""IVF-ANN contracts (ISSUE 7): the approximate scan's correctness
envelope, incremental index maintenance, and the plan-level wiring.

  * ``nprobe == nlist`` is BIT-IDENTICAL to the exact scan (same numpy
    scorer by construction) — property-based over shapes/seeds;
  * recall@k meets the requested target on clustered corpora (seeded);
  * an incremental append equals the from-scratch rebuild bit-for-bit
    and embeds ONLY the delta (request/tuple counts asserted);
  * ``IndexStore`` segments: append persists only the delta, reloads
    concatenate exactly, eviction garbage-collects unreferenced
    segments (no orphaned sidecar payloads);
  * plan level: ``ann="ivf"`` with full probing matches the exact plan,
    ``ann="auto"`` picks IVF on big corpora and exact on small ones,
    ``explain()`` renders both priced frontiers and the ann_select
    rewrite;
  * ``BM25Index.score_many`` is bit-identical to per-query ``score``.
"""

import json

import numpy as np
import pytest

from repro.core import MockProvider, PredictionCache, SemanticContext
from repro.core.cache import IndexStore, corpus_fingerprint
from repro.engine import Pipeline, Table
from repro.retrieval import BM25Index, IVFIndex, VectorIndex, ensure_index
from repro.retrieval.ivf import (default_nlist, ivf_scan_flops, kmeans,
                                 planned_nprobe, planned_recall)

EMB = {"model": "e", "embedding_dim": 16, "context_window": 4096}


def clustered(rng, n, d=24, centers=8):
    """Mixture-of-Gaussians corpus: the clustered geometry IVF exploits."""
    mu = rng.standard_normal((centers, d)) * 4.0
    labels = rng.integers(0, centers, n)
    return (mu[labels] + rng.standard_normal((n, d))).astype(np.float32)


# ---------------------------------------------------------------------------
# IVF index contracts
# ---------------------------------------------------------------------------
def test_ivf_full_probe_bit_identical_to_exact():
    rng = np.random.default_rng(0)
    for n, d, nlist, q, k in ((200, 8, 14, 5, 10), (64, 4, 8, 3, 64),
                              (33, 16, 33, 2, 1), (500, 12, 22, 7, 17)):
        vs = clustered(rng, n, d)
        vs /= np.maximum(np.linalg.norm(vs, axis=1, keepdims=True), 1e-9)
        idx = IVFIndex.build(vs, nlist)
        qs = rng.standard_normal((q, d)).astype(np.float32)
        qs /= np.maximum(np.linalg.norm(qs, axis=1, keepdims=True), 1e-9)
        s_ex, i_ex = idx.exact_scan(qs, min(k, n))
        s, i = idx.search(qs, min(k, n), nprobe=idx.nlist)
        assert s.tobytes() == s_ex.tobytes()
        assert i.tobytes() == i_ex.tobytes()


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(2, 120), d=st.integers(2, 12),
           k=st.integers(1, 20), seed=st.integers(0, 10_000))
    def test_ivf_full_probe_bit_identical_property(n, d, k, seed):
        rng = np.random.default_rng(seed)
        vs = rng.standard_normal((n, d)).astype(np.float32)
        vs /= np.maximum(np.linalg.norm(vs, axis=1, keepdims=True), 1e-9)
        idx = IVFIndex.build(vs)
        qs = rng.standard_normal((3, d)).astype(np.float32)
        qs /= np.maximum(np.linalg.norm(qs, axis=1, keepdims=True), 1e-9)
        kk = min(k, n)
        s_ex, i_ex = idx.exact_scan(qs, kk)
        s, i = idx.search(qs, kk, nprobe=idx.nlist)
        assert s.tobytes() == s_ex.tobytes()
        assert i.tobytes() == i_ex.tobytes()
except ImportError:                          # pragma: no cover
    pass


def test_ivf_recall_meets_target_on_clustered_corpus():
    rng = np.random.default_rng(7)
    vs = clustered(rng, 4000, d=24, centers=16)
    vs /= np.maximum(np.linalg.norm(vs, axis=1, keepdims=True), 1e-9)
    idx = IVFIndex.build(vs)
    # queries near corpus points (the RAG regime: query embeds live in
    # the same space as passage embeds)
    qs = vs[rng.integers(0, len(vs), 32)] + \
        0.05 * rng.standard_normal((32, 24)).astype(np.float32)
    qs /= np.maximum(np.linalg.norm(qs, axis=1, keepdims=True), 1e-9)
    target = 0.95
    nprobe = idx.nprobe_for(target)
    assert nprobe < idx.nlist                  # calibrated: partial probe
    _, i_ex = idx.exact_scan(qs, 10)
    _, i = idx.search(qs, 10, nprobe=nprobe)
    hits = np.mean([len(set(a) & set(b)) / 10.0
                    for a, b in zip(i, i_ex)])
    assert hits >= target


def test_ivf_incremental_append_equals_rebuild():
    rng = np.random.default_rng(3)
    vs = clustered(rng, 600, d=16)
    vs /= np.maximum(np.linalg.norm(vs, axis=1, keepdims=True), 1e-9)
    base = IVFIndex.build(vs[:500])
    ext = base.extended(vs, 100)
    assert ext.nlist == base.nlist             # centroids shared
    qs = rng.standard_normal((6, 16)).astype(np.float32)
    qs /= np.maximum(np.linalg.norm(qs, axis=1, keepdims=True), 1e-9)
    # full probing: appended index == exact scan over the grown corpus,
    # bit-for-bit — no candidate lost in the lazy merge
    s, i = ext.search(qs, 12, nprobe=ext.nlist)
    s_ex, i_ex = ext.exact_scan(qs, 12)
    assert s.tobytes() == s_ex.tobytes() and i.tobytes() == i_ex.tobytes()
    # partial probing still covers every appended row's list
    _, i_part = ext.search(qs, 12, nprobe=max(1, ext.nlist // 2))
    assert i_part.shape == (6, 12)


def test_planning_prior_shapes():
    assert planned_recall(10, 10) == 1.0
    assert planned_nprobe(316, 0.95) < 316 * 0.15
    assert planned_recall(planned_nprobe(316, 0.95), 316) >= 0.95
    assert default_nlist(100_000) == 316
    # probe flops strictly below exact at partial probing
    assert ivf_scan_flops(4, 100_000, 64, 316, 29) < \
        2.0 * 4 * 100_000 * 64
    # degenerate corpora
    assert default_nlist(0) >= 1
    km = kmeans(np.ones((3, 4), np.float32), 2)
    assert km.shape == (2, 4)


# ---------------------------------------------------------------------------
# cosine_topk / VectorIndex edge guards + routing
# ---------------------------------------------------------------------------
def test_cosine_topk_k_exceeds_corpus_and_empty():
    import jax.numpy as jnp
    from repro.retrieval import cosine_topk
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((2, 8)), jnp.float32)
    s, i = cosine_topk(c, q, 9)                # k > N: capped, no raise
    assert s.shape == (2, 4)
    s, i = cosine_topk(jnp.zeros((0, 8), jnp.float32), q, 3)
    assert s.shape == (2, 0) and i.shape == (2, 0)


def test_vector_index_empty_and_k_cap():
    vi = VectorIndex(np.zeros((0, 0), np.float32))
    s, i = vi.topk(np.zeros((2, 8), np.float32), 5)
    assert s.shape == (2, 0)
    vi2 = VectorIndex(np.random.default_rng(0)
                      .standard_normal((3, 8)).astype(np.float32))
    s, i = vi2.topk(np.random.default_rng(1)
                    .standard_normal((2, 8)).astype(np.float32), 10)
    assert s.shape == (2, 3)


def test_vector_index_kernel_route_matches_jnp():
    rng = np.random.default_rng(0)
    vs = rng.standard_normal((300, 16)).astype(np.float32)
    q = rng.standard_normal((4, 16)).astype(np.float32)
    jnp_s, jnp_i = VectorIndex(vs, use_kernel=False).topk(q, 7)
    ker_s, ker_i = VectorIndex(vs, use_kernel=True).topk(q, 7)
    np.testing.assert_allclose(ker_s, jnp_s, atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(ker_i, jnp_i)


# ---------------------------------------------------------------------------
# incremental ensure_index: delta-only embeds
# ---------------------------------------------------------------------------
def _texts(n):
    return [f"passage {i} about topic {i % 9} with searchable body"
            for i in range(n)]


def _embedded(ctx):
    return sum(r.n_tuples for r in ctx.reports
               if r.function == "embedding")


def test_ensure_index_append_embeds_only_delta():
    texts = _texts(40)
    ctx = SemanticContext(provider=MockProvider(), enable_cache=False)
    idx_base, src = ensure_index(ctx, EMB, texts[:30])
    assert src == "built"
    assert _embedded(ctx) == 30
    base_calls = ctx.provider.stats.calls

    idx, src = ensure_index(ctx, EMB, texts)
    assert src == "appended"
    assert _embedded(ctx) == 40                # +10, the delta ONLY
    assert ctx.provider.stats.calls > base_calls

    # bit-identical to a from-scratch build over the full corpus
    ctx2 = SemanticContext(provider=MockProvider(), enable_cache=False)
    idx_full, _ = ensure_index(ctx2, EMB, texts)
    np.testing.assert_array_equal(idx.raw, idx_full.raw)
    np.testing.assert_array_equal(idx.vectors, idx_full.vectors)
    # the base index object is untouched
    assert len(idx_base.vectors) == 30
    # and the grown corpus is now registered: third call is a session hit
    _, src3 = ensure_index(ctx, EMB, texts)
    assert src3 == "session"


def test_ensure_index_append_across_sessions_via_store(tmp_path):
    texts = _texts(24)
    store_path = str(tmp_path / "cache.jsonl.index.json")
    ctx1 = SemanticContext(provider=MockProvider(), enable_cache=False,
                           index_path=store_path)
    ensure_index(ctx1, EMB, texts[:20])

    # new session: base comes from the sidecar, only the delta embeds
    ctx2 = SemanticContext(provider=MockProvider(), enable_cache=False,
                           index_path=store_path)
    idx, src = ensure_index(ctx2, EMB, texts)
    assert src == "appended"
    assert _embedded(ctx2) == 4
    # the sidecar recorded the grown corpus as base + delta segment
    store = IndexStore(store_path)
    model_ref = ctx2.resolve_model(EMB).ref
    fps = dict(store.entries(model_ref))
    assert fps[corpus_fingerprint(texts)] == 24
    assert len(store.segment_keys()) == 2      # base chain + delta
    np.testing.assert_array_equal(
        store.get(model_ref, corpus_fingerprint(texts)), idx.raw)

    # a third session over the grown corpus pays ZERO embeds
    ctx3 = SemanticContext(provider=MockProvider(), enable_cache=False,
                           index_path=store_path)
    _, src3 = ensure_index(ctx3, EMB, texts)
    assert src3 == "store"
    assert ctx3.provider.stats.calls == 0


# ---------------------------------------------------------------------------
# IndexStore segment lifecycle
# ---------------------------------------------------------------------------
def test_index_store_segment_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    base = rng.standard_normal((5, 4)).astype(np.float32)
    delta = rng.standard_normal((3, 4)).astype(np.float32)
    path = str(tmp_path / "idx.json")
    st = IndexStore(path)
    st.put("m@1", "fpA", base)
    assert st.append_segment("m@1", "fpA", "fpB", delta)
    np.testing.assert_array_equal(st.get("m@1", "fpB"),
                                  np.concatenate([base, delta]))
    assert st.entries("m@1") == [("fpA", 5), ("fpB", 8)]
    # reload: segments concatenate exactly; base still whole
    st2 = IndexStore(path)
    np.testing.assert_array_equal(st2.get("m@1", "fpB"),
                                  np.concatenate([base, delta]))
    np.testing.assert_array_equal(st2.get("m@1", "fpA"), base)
    # append with an unknown base is refused (caller falls back to put)
    assert not st2.append_segment("m@1", "nope", "fpC", delta)


def test_index_store_eviction_garbage_collects_segments(tmp_path):
    rng = np.random.default_rng(1)
    base = rng.standard_normal((4, 3)).astype(np.float32)
    delta = rng.standard_normal((2, 3)).astype(np.float32)
    path = str(tmp_path / "idx.json")
    st = IndexStore(path, capacity=2)
    st.put("m@1", "f1", base)
    st.append_segment("m@1", "f1", "f2", delta)
    # f1's segment is SHARED with f2's chain: evicting f1 must keep it
    st.put("m@1", "f3", base)                  # evicts f1 (oldest)
    assert not st.has("m@1", "f1") and st.has("m@1", "f2")
    np.testing.assert_array_equal(st.get("m@1", "f2"),
                                  np.concatenate([base, delta]))
    assert len(st.segment_keys()) == 2
    # evicting the whole chain frees every segment — on disk too
    st.put("m@1", "f4", base)                  # evicts f2
    assert st.segment_keys() == []
    assert json.loads(open(path).read())["segments"] == {}


def test_index_store_segmented_corruption_recovery(tmp_path):
    path = tmp_path / "idx.json"
    path.write_text(json.dumps({
        "indexes": {
            "ok|fp": {"segments": ["ok|fp#0"], "n": 1},
            "dangling|fp": {"segments": ["missing#0"], "n": 2},
            "legacy|fp": {"vectors": [[1.0, 2.0]]},
        },
        "segments": {"ok|fp#0": [[3.0, 4.0]],
                     "orphan#9": [[9.9]]},
    }))
    st = IndexStore(str(path))
    # dangling chains drop; orphan segments are GC'd; legacy loads
    assert sorted(st.keys()) == ["legacy|fp", "ok|fp"]
    assert st.segment_keys() == ["ok|fp#0"]
    np.testing.assert_array_equal(st.get("ok|fp".split("|")[0], "fp"),
                                  [[3.0, 4.0]])


# ---------------------------------------------------------------------------
# plan-level ANN
# ---------------------------------------------------------------------------
def _corpus(n):
    return Table({"text": _texts(n)})


def _queries():
    return Table({"q": ["topic 3 body", "passage 17"]})


def test_plan_forced_ivf_full_probe_matches_exact_plan():
    ctx = SemanticContext(provider=MockProvider())
    corpus = _corpus(120)
    nlist = default_nlist(120)
    exact = (Pipeline(ctx, _queries(), "queries")
             .vector_topk("s", EMB, "q", corpus, k=5)
             .collect())
    ivf = (Pipeline(ctx, _queries(), "queries")
           .vector_topk("s", EMB, "q", corpus, k=5, ann="ivf",
                        nlist=nlist, nprobe=nlist)
           .collect())
    assert ivf.column("text") == exact.column("text")
    np.testing.assert_allclose(ivf.column("s"), exact.column("s"),
                               atol=1e-6)


def test_plan_ann_auto_selects_by_corpus_size():
    big = SemanticContext(provider=MockProvider())
    pipe = (Pipeline(big, _queries(), "queries")
            .vector_topk("s", EMB, "q", _corpus(2000), k=5, ann="auto"))
    plan = pipe._plan()
    node = [n for n in plan.nodes if n.op == "vector_topk"][0]
    assert node.info["ann_resolved"] == "ivf"
    assert node.info["ann_nprobe"] < node.info["ann_nlist"]
    assert any(rw.startswith("ann_select") for rw in plan.rewrites)

    small = SemanticContext(provider=MockProvider())
    pipe2 = (Pipeline(small, _queries(), "queries")
             .vector_topk("s", EMB, "q", _corpus(60), k=5, ann="auto"))
    node2 = [n for n in pipe2._plan().nodes if n.op == "vector_topk"][0]
    assert node2.info["ann_resolved"] == "exact"


def test_plan_without_ann_unchanged():
    ctx = SemanticContext(provider=MockProvider())
    pipe = (Pipeline(ctx, _queries(), "queries")
            .vector_topk("s", EMB, "q", _corpus(2000), k=5))
    plan = pipe._plan()
    assert not any(rw.startswith("ann_select") for rw in plan.rewrites)
    assert "ann" not in pipe.nodes[1].info


def test_explain_renders_both_scan_frontiers():
    ctx = SemanticContext(provider=MockProvider())
    pipe = (Pipeline(ctx, _queries(), "queries")
            .vector_topk("s", EMB, "q", _corpus(2000), k=5, ann="auto"))
    text = pipe.explain()
    assert "ann[ivf" in text                   # optimized: IVF chosen
    assert "ann[exact" in text                 # naive: exact frontier
    assert "ivf_flops=" in text and "exact_flops=" in text
    assert "est_recall=" in text
    assert any(ln.strip().startswith("- ann_select")
               for ln in text.splitlines())
    # the optimized plan's priced scan is strictly cheaper
    plan = pipe._plan()
    naive = plan.naive_node_costs[1]["scan_flops"]
    opt = plan.optimized_node_costs[1]["scan_flops"]
    assert opt < naive


def test_plan_ann_param_validation():
    ctx = SemanticContext(provider=MockProvider())
    with pytest.raises(ValueError):
        Pipeline(ctx, _queries()).vector_topk(
            "s", EMB, "q", _corpus(8), k=2, ann="fancy")
    with pytest.raises(ValueError):
        Pipeline(ctx, _queries()).vector_topk(
            "s", EMB, "q", _corpus(8), k=2, recall_target=0.9)
    with pytest.raises(ValueError):
        Pipeline(ctx, _queries()).hybrid_topk(
            "s", EMB, "q", _corpus(8), k=2, ann="ivf", recall_target=1.5)


def test_hybrid_topk_with_ann_matches_exact_at_full_probe():
    corpus = _corpus(90)
    nlist = default_nlist(90)

    def run(**kw):
        ctx = SemanticContext(provider=MockProvider())
        return (Pipeline(ctx, _queries(), "queries")
                .hybrid_topk("s", EMB, "q", corpus, k=4, candidate_k=12,
                             **kw)
                .collect()).rows()

    assert run(ann="ivf", nlist=nlist, nprobe=nlist) == run()


# ---------------------------------------------------------------------------
# BM25 score_many
# ---------------------------------------------------------------------------
def test_bm25_score_many_bit_identical():
    docs = ["the cat sat on the mat", "dogs and cats", "",
            "quantum cat physics", "mat weaving dogs", "cat cat dog"]
    bm = BM25Index.build(docs)
    qs = ["cat mat", "dog", "", "cat cat physics", "zebra unknown"]
    many = bm.score_many(qs)
    assert many.shape == (5, 6)
    for i, q in enumerate(qs):
        assert many[i].tobytes() == bm.score(q).tobytes()
    assert bm.score_many([]).shape == (0, 6)
    assert BM25Index.build([]).score_many(["x"]).shape == (1, 0)


def test_bm25_topk_node_uses_batched_scoring():
    corpus = _corpus(30)
    qs = Table({"q": ["topic 1", "topic 2", "passage 5 body"]})
    ctx = SemanticContext(provider=MockProvider())
    t = (Pipeline(ctx, qs, "queries")
         .bm25_topk("b", "q", corpus, k=4)
         .collect())
    bm = BM25Index.build([str(x) for x in corpus.column("text")])
    exp = []
    for q in qs.column("q"):
        s = bm.score(str(q))
        order = np.argsort(-s, kind="stable")[:4]
        exp += [corpus.column("text")[i] for i in order]
    assert t.column("text") == exp
