"""Cross-operator speculative pipelining tests (ISSUE 10).

The tentpole contract: every speculation shape the optimizer knows —
partial filter-chain prefixes, map-past-filter (``llm_spec_map``) and
retrieval-aware rerank (``spec_rerank``) — produces output tables
bit-identical to serial execution, across ``speculate=False``/
``"auto"``/``"always"``, including overflow-poisoned rows and chunks
cancelled mid-flight.  Verified property-based (hypothesis) plus
deterministic spot checks.

Also covered here:

  * the generalized ``SpeculativeJoin`` primitive: bounded runner
    fan-out, the in-flight row budget, cancellation semantics
    (cancelled work NEVER reaches the provider), mandatory tasks, and
    the ``spec_dispatched``/``spec_cancelled``/``spec_wasted_rows``
    counters;
  * satellite regression: speculative runs feed the
    ``SelectivityStore`` exactly like serial ones (mask densities from
    speculated members are recorded, so later decisions see them);
  * decision plumbing: objective-aware waste caps, waste-cap
    rejections, and the ``explain()`` "Speculation:" section for the
    new shapes.
"""

import re
import threading
import time

import pytest

from repro.core import (MockProvider, RequestScheduler, SemanticContext,
                        SpecTask, SpeculativeJoin)
from repro.core.batching import ContextOverflowError
from repro.engine import Pipeline, Table

try:        # property tests need the optional hypothesis dependency
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
    SMALL = settings(max_examples=20, deadline=None)
    TINY = settings(max_examples=8, deadline=None)
except ImportError:
    HAVE_HYPOTHESIS = False


def _behaviour(kind, prefix, rows):
    """Content-based filter verdicts (``has P<k>`` passes rows carrying
    the ``P<k>`` marker); a BOOM row poisons its batch with a context
    overflow (the splitter isolates it; it decodes to False).  Map and
    rerank kinds fall through to the provider's content-hash answers,
    which are deterministic per tuple — serial and speculative runs see
    identical per-tuple results regardless of batch composition."""
    if kind != "filter":
        return None
    if any("BOOM" in r for r in rows):
        raise ContextOverflowError("poisoned row in batch")
    marker = re.search(r"has (P\d+)", prefix).group(1)
    return [f"{i}: "
            f"{'true' if marker in r and 'GIBBER' not in r else 'false'}"
            for i, r in enumerate(rows)]


def _member_model(k: int, **kw) -> dict:
    base = {"model": f"pm{k}", "context_window": 100_000,
            "max_output_tokens": 8, "max_concurrency": 8}
    base.update(kw)
    return base


CHAT = {"model": "chat", "context_window": 100_000,
        "max_output_tokens": 16, "max_concurrency": 8}
EMB = {"model": "e", "embedding_dim": 16, "context_window": 4096}


def _texts(rows, n_filters):
    out = []
    for i, (passes, kind) in enumerate(rows):
        markers = " ".join(f"P{k}" for k in range(n_filters)
                           if passes[k])
        inject = {"ok": "", "boom": " BOOM"}[kind]
        out.append(f"r{i} doc {markers}{inject}")
    return out


def _map_pipeline(ctx, table, n_filters, map_op="llm_complete"):
    pipe = Pipeline(ctx, table, "docs")
    for k in range(n_filters):
        pipe = pipe.llm_filter(_member_model(k), {"prompt": f"has P{k}"},
                               ["text"])
    return getattr(pipe, map_op)("m_out", CHAT, {"prompt": "summarize"},
                                 ["text"])


def _collect_modes(build, modes=(False, "auto", "always"), **collect_kw):
    """Collect one plan under each speculate mode on a fresh context;
    returns {mode: (rows, executed ops)}."""
    out = {}
    for mode in modes:
        with RequestScheduler(max_workers=8) as sched:
            ctx = SemanticContext(provider=MockProvider(_behaviour),
                                  scheduler=sched)
            pipe = build(ctx)
            t = pipe.collect(speculate=mode, **collect_kw)
            out[mode] = (t.rows(), [n.op for n in pipe._executed_nodes])
    return out


# ---------------------------------------------------------------------------
# shape: map past filter
# ---------------------------------------------------------------------------
def test_spec_map_bit_identical_and_verifies():
    texts = _texts([((i % 2 == 0, True), "boom" if i == 7 else "ok")
                    for i in range(20)], 2)
    table = Table({"text": texts})
    res = _collect_modes(
        lambda ctx: _map_pipeline(ctx, table, 1), verify="strict")
    assert res[False][0] == res["auto"][0] == res["always"][0]
    assert "llm_spec_map" in res["always"][1]
    assert all(op != "llm_spec_map" for op in res[False][1])


def test_spec_map_absorbs_spec_chain_members():
    # a chain of 2 filters + map: the map rule composes with chain
    # speculation and the absorbed members' masks stay reconstructible
    texts = _texts([((i % 2 == 0, i % 3 != 0), "ok") for i in range(18)],
                   2)
    table = Table({"text": texts})
    res = _collect_modes(lambda ctx: _map_pipeline(ctx, table, 2),
                         modes=(False, "always"), verify="strict")
    assert res[False][0] == res["always"][0]
    assert "llm_spec_map" in res["always"][1]


def test_spec_map_writes_discarded_rows_to_cache():
    # completions for masked-out rows are discarded from the output but
    # land in the prediction cache: a later unfiltered map over the
    # same tuples must hit the cache instead of the provider
    texts = [f"r{i} doc {'P0' if i % 2 else ''}" for i in range(12)]
    table = Table({"text": texts})
    with RequestScheduler(max_workers=8) as sched:
        ctx = SemanticContext(provider=MockProvider(_behaviour),
                              scheduler=sched, speculate="always")
        pipe = _map_pipeline(ctx, table, 1)
        pipe.collect()
        calls_after_spec = ctx.provider.stats.calls
        out2 = (Pipeline(ctx, table, "docs")
                .llm_complete("m_out", CHAT, {"prompt": "summarize"},
                              ["text"])
                .collect(speculate=False))
        assert len(out2) == 12
        # every tuple was speculated on, so the full map is cache-only
        assert ctx.provider.stats.calls == calls_after_spec


def test_spec_map_rejected_by_tight_cap_runs_serially():
    table = Table({"text": [f"r{i} doc P1" for i in range(40)]})
    ctx = SemanticContext(provider=MockProvider(_behaviour),
                          enable_cache=False, enable_dedup=False,
                          max_batch=4, speculate_waste_cap=0.05)
    ctx.record_selectivity("inline:has P0", 1, 100)     # ~1% pass
    pipe = _map_pipeline(ctx, table, 1)
    plan = pipe._plan(True)
    assert any("rejected(speculate map past filter:" in rw
               and "exceeds cap" in rw for rw in plan.rewrites)
    assert all(n.op != "llm_spec_map" for n in plan.nodes)
    out = pipe.collect(speculate=True)
    assert len(out) == 0


def test_map_cap_objective_flip():
    # the same marginal plan flips with the scheduling objective: the
    # latency objective widens the waste cap 1.25x, cost narrows it
    # 0.8x — some cap in between accepts under latency only
    from repro.engine.optimizer import SPEC_CAP_OBJECTIVE_MULT
    table = Table({"text": [(f"r{i} doc P1 P0" if i % 2 else
                             f"r{i} doc P1") for i in range(32)]})

    def decide(objective, cap):
        ctx = SemanticContext(provider=MockProvider(_behaviour),
                              enable_cache=False, enable_dedup=False,
                              max_batch=4, speculate_waste_cap=cap)
        ctx.record_selectivity("inline:has P0", 50, 100)
        pipe = _map_pipeline(ctx, table, 1)
        plan = pipe._plan(True, objective)
        (d,) = [x for x in plan.spec_decisions if x.kind == "map"]
        return d

    mults = SPEC_CAP_OBJECTIVE_MULT
    assert mults["latency"] > 1.0 > mults["cost"]
    flipped = False
    for cap in (x / 100.0 for x in range(1, 40)):
        d_lat, d_cost = decide("latency", cap), decide("cost", cap)
        assert d_lat.wasted_requests == d_cost.wasted_requests
        if d_lat.chosen and not d_cost.chosen:
            assert "exceeds cap" in d_cost.reason
            flipped = True
            break
    assert flipped, "no cap flips the decision between objectives"


# ---------------------------------------------------------------------------
# shape: partial chain prefix
# ---------------------------------------------------------------------------
def test_partial_chain_speculates_cheap_prefix_only():
    # members 0/1 are calibrated cheap, member 2 is calibrated very
    # slow AND serialized (concurrency 1): speculating it over the full
    # input costs 4 waves x 5 s, so the best split keeps it serial on
    # survivors (1 wave) while members 0/1 fan out
    texts = [f"r{i} doc {'P0' if i % 10 == 0 else ''} P1 P2"
             for i in range(24)]
    table = Table({"text": texts})

    def build(ctx):
        pipe = Pipeline(ctx, table, "docs")
        for k in range(2):
            pipe = pipe.llm_filter(_member_model(k),
                                   {"prompt": f"has P{k}"}, ["text"])
        return pipe.llm_filter(_member_model(2, max_concurrency=1),
                               {"prompt": "has P2"}, ["text"])

    ctx = SemanticContext(provider=MockProvider(_behaviour),
                          enable_cache=False, enable_dedup=False,
                          max_batch=6)
    for k, lat in ((0, 0.01), (1, 0.01), (2, 5.0)):
        ctx.record_calibration(f"pm{k}@0", requests=8, retries=0,
                               tuples=48, latencies=[lat] * 8)
    ctx.record_selectivity("inline:has P0", 10, 100)
    pipe = build(ctx)
    plan = pipe._plan(True)
    (d,) = plan.spec_decisions
    assert d.chosen
    assert d.split == 2 and len(d.members) == 3
    assert "spec prefix 2" in str(d)
    assert any("prefix=2" in rw for rw in plan.rewrites)
    (spec,) = [n for n in plan.nodes if n.op == "llm_spec_chain"]
    assert spec.info["split"] == 2

    # and the split execution is bit-identical to serial
    ref = build(SemanticContext(provider=MockProvider(_behaviour))) \
        .collect(speculate=False)
    out = pipe.collect(speculate=True, verify="strict")
    assert out.rows() == ref.rows()


def test_full_speculation_still_chosen_when_tail_is_cheap():
    texts = [f"r{i} doc P0 P1 P2" for i in range(24)]
    table = Table({"text": texts})
    ctx = SemanticContext(provider=MockProvider(_behaviour),
                          enable_cache=False, enable_dedup=False,
                          max_batch=6)
    for k in range(3):
        ctx.record_calibration(f"pm{k}@0", requests=8, retries=0,
                               tuples=48, latencies=[0.05] * 8)
    pipe = Pipeline(ctx, table, "docs")
    for k in range(3):
        pipe = pipe.llm_filter(_member_model(k), {"prompt": f"has P{k}"},
                               ["text"])
    plan = pipe._plan(True)
    (d,) = plan.spec_decisions
    assert d.chosen and d.split == 3
    assert "spec prefix" not in str(d)


# ---------------------------------------------------------------------------
# shape: retrieval-aware rerank
# ---------------------------------------------------------------------------
def _retrieval_fixture(n=30):
    topics = ("joins", "indexes", "vectors")
    corpus = Table({"content": [f"doc {i} about {topics[i % 3]} text"
                                for i in range(n)]})
    queries = Table({"q": ["join algorithms", "vector search"],
                     "qid": [0, 1]})
    return corpus, queries


def _rerank_pipeline(ctx, corpus, queries, k=4, candidate_k=8):
    return (Pipeline(ctx, queries, "queries")
            .hybrid_topk("score", EMB, "q", corpus, k=k,
                         doc_col="content", candidate_k=candidate_k)
            .llm_rerank(CHAT, {"prompt": "most relevant"}, ["content"],
                        by="q"))


@pytest.mark.parametrize("k,candidate_k,n", [(4, 8, 30), (3, None, 12),
                                             (6, 12, 48)])
def test_spec_rerank_bit_identical_and_verifies(k, candidate_k, n):
    corpus, queries = _retrieval_fixture(n)
    res = _collect_modes(
        lambda ctx: _rerank_pipeline(ctx, corpus, queries, k,
                                     candidate_k),
        verify="strict")
    assert res[False][0] == res["auto"][0] == res["always"][0]
    assert "spec_rerank" in res["always"][1]
    assert all(op != "spec_rerank" for op in res[False][1])


def test_spec_rerank_requires_prediction_cache():
    corpus, queries = _retrieval_fixture(12)
    ctx = SemanticContext(provider=MockProvider(_behaviour),
                          enable_cache=False, speculate="always")
    pipe = _rerank_pipeline(ctx, corpus, queries)
    plan = pipe._plan("always")
    assert any("rejected(speculate rerank: prediction cache" in rw
               for rw in plan.rewrites)
    assert all(n.op != "spec_rerank" for n in plan.nodes)


def test_spec_rerank_rejects_score_reading_rerank():
    corpus, queries = _retrieval_fixture(12)
    ctx = SemanticContext(provider=MockProvider(_behaviour),
                          speculate="always")
    pipe = (Pipeline(ctx, queries, "queries")
            .hybrid_topk("score", EMB, "q", corpus, k=4,
                         doc_col="content", candidate_k=8)
            .llm_rerank(CHAT, {"prompt": "most relevant"},
                        ["content", "score"], by="q"))
    plan = pipe._plan("always")
    assert any("fused score/rank columns" in rw for rw in plan.rewrites)
    assert all(n.op != "spec_rerank" for n in plan.nodes)


def test_spec_rerank_warmup_prefills_window_cache():
    # when the BM25 prediction matches the fused candidate list, the
    # authoritative rerank's windows are cache hits: total chat calls
    # match a pre-warmed serial run
    corpus, queries = _retrieval_fixture(30)
    with RequestScheduler(max_workers=8) as sched:
        ctx = SemanticContext(provider=MockProvider(_behaviour),
                              scheduler=sched, speculate="always")
        pipe = _rerank_pipeline(ctx, corpus, queries)
        out = pipe.collect()
        (spec,) = [n for n in pipe._executed_nodes
                   if n.op == "spec_rerank"]
    assert len(out) == 8
    # the explain section prices the warmup
    text = pipe.explain()
    assert "rerank over retrieval" in text
    assert "Speculation:" in text


# ---------------------------------------------------------------------------
# property: every shape, bit for bit, across modes
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    @SMALL
    @given(
        n_filters=st.integers(1, 3),
        map_op=st.sampled_from(["llm_complete", "llm_complete_json"]),
        max_batch=st.sampled_from([3, 5, 0]),
        rows=st.lists(
            st.tuples(st.tuples(*[st.booleans()] * 3),
                      st.sampled_from(["ok", "ok", "ok", "boom"])),
            min_size=0, max_size=14))
    def test_property_spec_map_modes_identical(n_filters, map_op,
                                               max_batch, rows):
        # small max_batch makes several speculative chunks per map, so
        # all-dead chunks exercise mid-flight cancellation; BOOM rows
        # poison batches on the filter side
        table = Table({"text": _texts(rows, n_filters)})
        results = {}
        for mode in (False, "auto", "always"):
            with RequestScheduler(max_workers=8) as sched:
                kw = {"max_batch": max_batch} if max_batch else {}
                ctx = SemanticContext(
                    provider=MockProvider(_behaviour), scheduler=sched,
                    **kw)
                pipe = _map_pipeline(ctx, table, n_filters, map_op)
                results[mode] = pipe.collect(speculate=mode,
                                             verify="strict").rows()
        assert results[False] == results["auto"] == results["always"]

    @SMALL
    @given(
        n_filters=st.integers(2, 4),
        split_lat=st.lists(st.sampled_from([0.01, 0.5, 3.0]),
                           min_size=4, max_size=4),
        rows=st.lists(
            st.tuples(st.tuples(*[st.booleans()] * 4),
                      st.sampled_from(["ok", "ok", "boom"])),
            min_size=0, max_size=12))
    def test_property_partial_chain_modes_identical(n_filters,
                                                    split_lat, rows):
        # random member latencies drive the prefix-split search through
        # different splits; outputs must not depend on the split chosen
        table = Table({"text": _texts(rows, n_filters)})
        results = {}
        for mode in (False, "auto", "always"):
            with RequestScheduler(max_workers=8) as sched:
                ctx = SemanticContext(provider=MockProvider(_behaviour),
                                      scheduler=sched, max_batch=4)
                for k in range(n_filters):
                    ctx.record_calibration(
                        f"pm{k}@0", requests=8, retries=0, tuples=32,
                        latencies=[split_lat[k]] * 8)
                pipe = Pipeline(ctx, table, "docs")
                for k in range(n_filters):
                    pipe = pipe.llm_filter(_member_model(k),
                                           {"prompt": f"has P{k}"},
                                           ["text"])
                results[mode] = pipe.collect(speculate=mode,
                                             verify="strict").rows()
        assert results[False] == results["auto"] == results["always"]

    @TINY
    @given(n_docs=st.integers(6, 24), k=st.integers(2, 5),
           deep=st.booleans())
    def test_property_spec_rerank_modes_identical(n_docs, k, deep):
        corpus, queries = _retrieval_fixture(n_docs)
        candidate_k = min(2 * k, n_docs) if deep else None
        results = {}
        for mode in (False, "always"):
            with RequestScheduler(max_workers=8) as sched:
                ctx = SemanticContext(provider=MockProvider(_behaviour),
                                      scheduler=sched)
                pipe = _rerank_pipeline(ctx, corpus, queries, k,
                                        candidate_k)
                results[mode] = pipe.collect(speculate=mode,
                                             verify="strict").rows()
        assert results[False] == results["always"]


# ---------------------------------------------------------------------------
# satellite: speculative runs feed the SelectivityStore
# ---------------------------------------------------------------------------
def test_speculated_chain_records_mask_densities():
    texts = [f"r{i} doc {'P0' if i % 2 else ''} {'P1' if i % 3 else ''}"
             for i in range(16)]
    table = Table({"text": texts})
    with RequestScheduler(max_workers=8) as sched:
        ctx = SemanticContext(provider=MockProvider(_behaviour),
                              scheduler=sched, speculate="always")
        pipe = Pipeline(ctx, table, "docs")
        for k in range(2):
            pipe = pipe.llm_filter(_member_model(k),
                                   {"prompt": f"has P{k}"}, ["text"])
        pipe.collect()
        assert any(n.op == "llm_spec_chain"
                   for n in pipe._executed_nodes)
    # every member recorded its density over the FULL input (the
    # speculative run evaluates all 16 rows per member)
    for k in range(2):
        passed, total = ctx.selectivity_stats[f"inline:has P{k}"]
        assert total == 16
        assert passed == sum(1 for t in texts if f"P{k}" in t)


def test_speculated_map_records_filter_density():
    texts = [f"r{i} doc {'P0' if i % 4 == 0 else ''}" for i in range(16)]
    table = Table({"text": texts})
    with RequestScheduler(max_workers=8) as sched:
        ctx = SemanticContext(provider=MockProvider(_behaviour),
                              scheduler=sched, speculate="always")
        pipe = _map_pipeline(ctx, table, 1)
        pipe.collect()
        assert any(n.op == "llm_spec_map" for n in pipe._executed_nodes)
    passed, total = ctx.selectivity_stats["inline:has P0"]
    assert (passed, total) == (4, 16)


# ---------------------------------------------------------------------------
# SpeculativeJoin: cancellation, budgets, counters
# ---------------------------------------------------------------------------
def test_join_cancelled_tasks_never_run():
    # one runner => strictly ordered starts; task 0 cancels everything
    # downstream while it runs, so no later thunk may execute
    join = SpeculativeJoin(max_runners=1)
    ran = []

    def first():
        for i in range(1, 5):
            assert join.cancel(i)
        ran.append(0)
        return "first"

    tasks = [SpecTask(first, rows=1)]
    tasks += [SpecTask(lambda i=i: ran.append(i), rows=1, label=f"t{i}")
              for i in range(1, 5)]
    results = join.run(tasks)
    assert ran == [0]
    assert results[0] == "first"
    assert results[1:] == [None] * 4
    assert join.cancelled == [1, 2, 3, 4]


def test_join_cancelled_work_never_reaches_provider():
    # pipeline-shaped stress: the "provider" records every call; the
    # mandatory mask task cancels all speculative chunks before they
    # start (single runner serializes admission)
    provider_calls = []
    join = SpeculativeJoin(max_runners=1)

    def mask():
        for j in range(1, 9):
            join.cancel(j)
        provider_calls.append("mask")
        return [False] * 8

    tasks = [SpecTask(mask, rows=8, mandatory=True)]
    tasks += [SpecTask(lambda j=j: provider_calls.append(f"chunk{j}"),
                       rows=1, label=f"chunk{j}") for j in range(1, 9)]
    results = join.run(tasks)
    assert provider_calls == ["mask"]
    assert results[0] == [False] * 8
    assert join.cancelled == list(range(1, 9))


def test_join_counters_on_scheduler_stats():
    with RequestScheduler(max_workers=4) as sched:
        join = SpeculativeJoin(sched, max_runners=1)

        def first():
            join.cancel(2)
            return "a"

        results = join.run([SpecTask(first, rows=2),
                            SpecTask(lambda: "b", rows=2),
                            SpecTask(lambda: "c", rows=2)])
        assert results == ["a", "b", None]
        assert sched.stats.spec_dispatched == 2
        assert sched.stats.spec_cancelled == 1
        join.note_wasted(7)
        join.note_wasted(0)        # no-op
        assert sched.stats.spec_wasted_rows == 7


def test_join_mandatory_tasks_ignore_cancellation():
    join = SpeculativeJoin(max_runners=1)

    def first():
        join.cancel(1)
        join.cancel(2)
        return 0

    results = join.run([SpecTask(first, rows=1),
                        SpecTask(lambda: 1, rows=1, mandatory=True),
                        SpecTask(lambda: 2, rows=1)])
    assert results == [0, 1, None]
    assert join.cancelled == [2]


def test_join_bounds_concurrent_runners():
    active, peak = [0], [0]
    lock = threading.Lock()

    def task():
        with lock:
            active[0] += 1
            peak[0] = max(peak[0], active[0])
        time.sleep(0.02)
        with lock:
            active[0] -= 1
        return True

    join = SpeculativeJoin(max_runners=3)
    results = join.run([SpecTask(task, rows=1) for _ in range(12)])
    assert results == [True] * 12
    assert peak[0] <= 3


def test_join_bounds_inflight_rows():
    # rows cap 10, tasks of 8 rows: admission must serialize them (two
    # tasks in flight would hold 16 > 10); a single oversized task is
    # still admitted when nothing is in flight (progress guarantee)
    active, peak = [0], [0]
    lock = threading.Lock()

    def task():
        with lock:
            active[0] += 1
            peak[0] = max(peak[0], active[0])
        time.sleep(0.02)
        with lock:
            active[0] -= 1
        return True

    join = SpeculativeJoin(max_runners=4, max_inflight_rows=10)
    assert join.run([SpecTask(task, rows=8) for _ in range(6)]) \
        == [True] * 6
    assert peak[0] == 1
    join2 = SpeculativeJoin(max_runners=2, max_inflight_rows=4)
    assert join2.run([SpecTask(task, rows=100)]) == [True]


def test_join_error_fails_fast_and_cancels_rest():
    join = SpeculativeJoin(max_runners=1)
    ran = []

    def boom():
        ran.append("boom")
        raise RuntimeError("member failed")

    with pytest.raises(RuntimeError, match="member failed"):
        join.run([SpecTask(boom, rows=1),
                  SpecTask(lambda: ran.append("late"), rows=1)])
    assert ran == ["boom"]


def test_scheduler_stats_counters_flow_from_pipeline():
    # an always-speculated map run reports dispatches; with a filter
    # that keeps some rows per chunk, every chunk is dispatched and the
    # dead rows land in spec_wasted_rows deterministically
    texts = [f"r{i} doc {'P0' if i % 2 == 0 else ''}" for i in range(16)]
    table = Table({"text": texts})
    with RequestScheduler(max_workers=8) as sched:
        ctx = SemanticContext(provider=MockProvider(_behaviour),
                              scheduler=sched, speculate="always",
                              max_batch=4)
        pipe = _map_pipeline(ctx, table, 1)
        out = pipe.collect()
        stats = sched.stats
        assert len(out) == 8
        assert stats.spec_dispatched >= 4       # the four map chunks
        # alternating P0 rows leave survivors in every chunk, so no
        # chunk is cancellable and the 8 dead rows are pure waste
        assert stats.spec_cancelled == 0
        assert stats.spec_wasted_rows == 8
