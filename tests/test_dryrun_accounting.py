"""Validate the dry-run's scan-cost probe methodology.

XLA counts while-loop bodies once (the motivating observation, re-verified
here), and the probe decomposition  cost(base) + sum_i (R_i-1)*body_i
must agree with a fully-unrolled lowering of the same model.

Runs in a subprocess because the 8-device host platform flag must be set
before jax initialises.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, "src")
    import jax
    from repro.configs import get_smoke_config
    from repro.models.config import ShapeCell
    from repro.launch import dryrun as D
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_smoke_config("gemma3-12b").replace(
        num_layers=12, shard_multiple=4)
    cell = ShapeCell("t", 32, 4, "train")

    probed = D.probed_costs(cfg, cell, mesh)

    unrolled_cfg = cfg.replace(unroll_layers=True, unroll_inner=True)
    truth = D.lower_and_analyze(unrolled_cfg, cell, mesh, want_memory=False)

    scanned = D.lower_and_analyze(cfg, cell, mesh, want_memory=False)

    print(json.dumps({
        "probed_flops": probed["flops_per_dev"],
        "true_flops": truth["flops_per_dev"],
        "scanned_flops": scanned["flops_per_dev"],
    }))
""")


@pytest.mark.slow
def test_probe_decomposition_matches_unrolled():
    out = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.join(
        os.path.dirname(__file__), ".."), capture_output=True, text=True,
        timeout=1200)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    # probe accounting within 2% of ground truth
    assert abs(rec["probed_flops"] - rec["true_flops"]) \
        / rec["true_flops"] < 0.02, rec
    # and the scanned program indeed under-counts (the motivating bug)
    assert rec["scanned_flops"] < 0.6 * rec["true_flops"], rec
