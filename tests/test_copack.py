"""Cross-node batch co-packing + calibration-aware headroom tests, plus
regression tests for the three PR bugfixes:

  * ``plan_batches.est_tokens`` counted expected OUTPUT tokens although
    it is documented/consumed as estimated prompt tokens per request;
  * sidecar stores staged atomic replaces through
    ``path.with_suffix(".tmp")``, which mangles multi-dot paths and can
    collide across sidecars sharing a prefix;
  * selectivity observations were averaged forever, so a shifted data
    distribution never re-learned.
"""

import json
import threading
import time

import pytest

from repro.core import (MockProvider, PredictionCache, RequestScheduler,
                        SelectivityStore, SemanticContext,
                        headroom_factor, llm_complete, llm_multi,
                        reset_global_catalog)
from repro.core.batching import ContextOverflowError, plan_batches
from repro.core.cache import (CalibrationStore, HEADROOM_MIN,
                              HEADROOM_MIN_OBS, SELECTIVITY_WINDOW,
                              bound_observations)
from repro.core.resources import ModelResource
from repro.engine import Pipeline, Table, copack_identity


def _resource(**kw) -> ModelResource:
    base = dict(name="m", version=1, arch="mock", context_window=4096,
                max_output_tokens=8, max_concurrency=4)
    base.update(kw)
    return ModelResource(**base)


# ---------------------------------------------------------------------------
# bugfix: est_tokens must be PROMPT tokens (no expected-output padding)
# ---------------------------------------------------------------------------
def test_plan_batches_est_tokens_exclude_output_tokens():
    plan = plan_batches([10, 10, 10], prefix_tokens=0,
                        context_window=10_000, max_output_tokens=100)
    assert plan.batches == [[0, 1, 2]]
    assert plan.est_tokens == [30]      # was 330 with the output bug


def test_plan_batches_output_tokens_still_shape_the_budget():
    # per tuple: 10 prompt + 50 output = 60 budget weight; window 121
    # fits two tuples (120), not three — output tokens still gate
    # admission even though they are excluded from est_tokens
    plan = plan_batches([10, 10, 10], prefix_tokens=0, context_window=121,
                        max_output_tokens=50)
    assert plan.batches == [[0, 1], [2]]
    assert plan.est_tokens == [20, 10]


def test_plan_batches_headroom_shrinks_budget():
    costs = [10] * 12                   # weight 12/tuple with output 2
    full = plan_batches(costs, prefix_tokens=0, context_window=144,
                        max_output_tokens=2)
    half = plan_batches(costs, prefix_tokens=0, context_window=144,
                        max_output_tokens=2, headroom=0.5)
    assert len(full.batches) == 1
    assert len(half.batches) == 2
    assert max(len(b) for b in half.batches) \
        < max(len(b) for b in full.batches)


# ---------------------------------------------------------------------------
# bugfix: sidecar temp files derive from the FULL filename
# ---------------------------------------------------------------------------
def test_sidecar_save_does_not_clobber_sibling_tmp(tmp_path):
    # with_suffix(".tmp") on "x.sel" staged through "x.tmp" — destroying
    # any sibling file of that name (e.g. another sidecar's staging)
    sentinel = tmp_path / "x.tmp"
    sentinel.write_text("do not touch")
    store = SelectivityStore(str(tmp_path / "x.sel"))
    store.save({"p@1": [1, 2]})
    assert sentinel.read_text() == "do not touch"
    assert store.load() == {"p@1": [1, 2]}

    cal = CalibrationStore(str(tmp_path / "x.cal"))
    cal.save({"m@1": {"requests": 1, "retries": 0, "tuples": 2,
                      "latency_s": [0.1]}})
    assert sentinel.read_text() == "do not touch"
    assert cal.load()["m@1"]["requests"] == 1


def test_multidot_sidecar_paths_roundtrip(tmp_path):
    # the default sidecar naming: <cache>.jsonl.selectivity.json
    store = SelectivityStore(str(tmp_path / "cache.jsonl.selectivity.json"))
    store.save({"p@1": [3, 10]})
    assert store.load() == {"p@1": [3, 10]}
    assert not (tmp_path / "cache.jsonl.selectivity.tmp").exists()
    assert not (tmp_path / "cache.tmp").exists()


def test_prediction_cache_compact_uses_fullname_tmp(tmp_path):
    sentinel = tmp_path / "cache.tmp"
    sentinel.write_text("unrelated")
    cache = PredictionCache(persist_path=str(tmp_path / "cache.jsonl"))
    cache.put("k", "v")
    cache.compact()
    assert sentinel.read_text() == "unrelated"
    assert PredictionCache(
        persist_path=str(tmp_path / "cache.jsonl")).get("k") == (True, "v")


# ---------------------------------------------------------------------------
# bugfix: selectivity drift — bounded observation window re-learns
# ---------------------------------------------------------------------------
def test_bound_observations_caps_total():
    assert bound_observations(10, 100) == (10, 100)
    p, t = bound_observations(9000, 10_000)
    assert t == SELECTIVITY_WINDOW
    assert p == round(9000 * SELECTIVITY_WINDOW / 10_000)


def test_selectivity_relearns_after_distribution_shift():
    ctx = SemanticContext(provider=MockProvider())
    # long history at 90% pass rate, then the data shifts to 10%
    ctx.record_selectivity("p@1", 900, 1000)
    for _ in range(30):
        ctx.record_selectivity("p@1", 10, 100)
    est = ctx.expected_selectivity("p@1")
    # forever-averaging would still report (900+300)/4000 = 0.30
    assert est < 0.2, f"windowed estimate did not re-learn: {est}"
    passed, total = ctx.selectivity_stats["p@1"]
    assert total <= SELECTIVITY_WINDOW


def test_selectivity_store_bounds_legacy_oversized_entries(tmp_path):
    path = tmp_path / "s.json"
    path.write_text(json.dumps(
        {"stats": {"p@1": [90_000, 100_000]}}))
    loaded = SelectivityStore(str(path)).load()
    assert loaded["p@1"][1] == SELECTIVITY_WINDOW
    assert abs(loaded["p@1"][0] / loaded["p@1"][1] - 0.9) < 0.01


# ---------------------------------------------------------------------------
# calibration-aware headroom
# ---------------------------------------------------------------------------
def test_headroom_factor_thresholds():
    assert headroom_factor(0, 0) == 1.0
    assert headroom_factor(HEADROOM_MIN_OBS, 0) == 1.0
    # below the observation threshold the rate is not trusted
    assert headroom_factor(2, 1) == 1.0
    assert headroom_factor(8, 2) == pytest.approx(0.8)
    # floored: a catastrophically overflowing model still plans half
    assert headroom_factor(1, 100) == HEADROOM_MIN


def test_headroom_read_path_from_calibration_sidecar(tmp_path):
    reset_global_catalog()
    cache_path = str(tmp_path / "cache.jsonl")
    # a prior session recorded a 25% overflow-retry rate for m@0
    CalibrationStore(cache_path + ".calibration.json").save(
        {"m@0": {"requests": 30, "retries": 10, "tuples": 300,
                 "latency_s": [0.01]}})
    ctx = SemanticContext(
        cache=PredictionCache(persist_path=cache_path),
        provider=MockProvider())
    assert ctx.batch_headroom("m@0") == pytest.approx(0.75)
    assert ctx.batch_headroom("unknown@9") == 1.0

    rows = [{"t": f"row number {i} with some body text"}
            for i in range(40)]
    model = {"model": "m", "context_window": 700, "max_output_tokens": 8}
    ctrl = SemanticContext(provider=MockProvider())
    llm_complete(ctrl, model, {"prompt": "p"}, rows)
    llm_complete(ctx, model, {"prompt": "p"}, rows)
    # headroom plans strictly smaller batches up front
    assert max(ctx.last_report().batch_sizes) \
        < max(ctrl.last_report().batch_sizes)


def test_headroom_avoids_overflow_retries_across_sessions(tmp_path):
    """The feedback loop end-to-end: session 1 overflows (token
    estimates undercount serialization framing on a tight window) and
    records retries; session 2 loads the sidecar, plans with headroom,
    and pays strictly fewer split-and-requeue retries."""
    reset_global_catalog()
    cache_path = str(tmp_path / "cache.jsonl")
    model = {"model": "tight", "context_window": 260,
             "max_output_tokens": 2}

    def run(tag):
        ctx = SemanticContext(
            cache=PredictionCache(persist_path=cache_path),
            provider=MockProvider(), enable_dedup=False)
        rows = [{"t": f"{tag} row {i} and padding padding {i}"}
                for i in range(48)]
        with ctx:
            llm_complete(ctx, model, {"prompt": "p"}, rows)
        rep = ctx.last_report()
        assert all(v is not None for v in rep.batch_sizes)
        return rep

    first = run("alpha")
    assert first.retries > 0, \
        "seed workload must overflow for the feedback test to bite"
    second = run("beta")
    assert second.retries < first.retries


def test_calibration_counters_bounded():
    ctx = SemanticContext(provider=MockProvider())
    for _ in range(40):
        ctx.record_calibration("m@1", requests=200, retries=10,
                               tuples=2000, latencies=[0.01])
    rec = ctx.calibration_stats["m@1"]
    from repro.core.cache import CALIBRATION_COUNT_WINDOW
    assert rec["requests"] + rec["retries"] <= CALIBRATION_COUNT_WINDOW + 1
    # the rate survives the rescale
    assert rec["retries"] / (rec["requests"] + rec["retries"]) \
        == pytest.approx(10 / 210, rel=0.05)


# ---------------------------------------------------------------------------
# co-packing: scheduler-level equivalence
# ---------------------------------------------------------------------------
def _submit_packed_pair(sched, calls, fail_merged_over=None):
    """Two jobs sharing a pack identity, each with one part-filled tail
    batch.  Returns (job_a, job_b, rows_a, rows_b)."""
    model = _resource(context_window=1000)
    rows_a = [f"a{i}" for i in range(4)]
    rows_b = [f"b{i}" for i in range(4)]

    def pack_call(rows):
        if fail_merged_over is not None and len(rows) > fail_merged_over:
            raise ContextOverflowError("merged too large")
        calls.append(list(rows))
        return [f"r:{r}" for r in rows]

    def make_run(rows):
        def run(positions):
            return pack_call([rows[p] for p in positions])
        return run

    jobs = []
    for rows, tag in ((rows_a, "a"), (rows_b, "b")):
        jobs.append(sched.submit_map(
            model, [f"key-{r}" for r in rows], [20] * len(rows),
            prefix_tokens=100, run=make_run(rows), single_flight=False,
            pack_key="shared-prefix", pack_rows=rows,
            pack_call=pack_call))
    return jobs[0], jobs[1], rows_a, rows_b


def test_copack_merges_tails_into_one_request():
    calls = []
    with RequestScheduler(pack_linger_s=0.5) as sched:
        ja, jb, rows_a, rows_b = _submit_packed_pair(sched, calls)
        va, sa = ja.result(timeout=10)
        vb, sb = jb.result(timeout=10)
    assert va == [f"r:{r}" for r in rows_a]
    assert vb == [f"r:{r}" for r in rows_b]
    assert len(calls) == 1, "tails must merge into ONE provider request"
    assert sorted(calls[0]) == sorted(rows_a + rows_b)
    assert sched.stats.packed_requests == 1
    assert sched.stats.packed_batches == 2
    # the request is attributed once; the rider counts it as packed
    assert sa.requests + sb.requests == 1
    assert sa.packed + sb.packed == 1


def test_copack_lone_tail_flushes_after_linger():
    calls = []
    model = _resource()

    def pack_call(rows):
        calls.append(list(rows))
        return [f"r:{r}" for r in rows]

    rows = ["x0", "x1"]
    with RequestScheduler(pack_linger_s=0.05) as sched:
        job = sched.submit_map(
            model, ["k0", "k1"], [10, 10], prefix_tokens=10,
            run=lambda ps: pack_call([rows[p] for p in ps]),
            single_flight=False, pack_key="p", pack_rows=rows,
            pack_call=pack_call)
        vals, stats = job.result(timeout=10)
    assert vals == ["r:x0", "r:x1"]
    assert len(calls) == 1
    assert sched.stats.packed_requests == 0


def test_copack_merged_overflow_unmerges():
    calls = []
    with RequestScheduler(pack_linger_s=0.5) as sched:
        ja, jb, rows_a, rows_b = _submit_packed_pair(
            sched, calls, fail_merged_over=6)
        va, sa = ja.result(timeout=10)
        vb, sb = jb.result(timeout=10)
    assert va == [f"r:{r}" for r in rows_a]
    assert vb == [f"r:{r}" for r in rows_b]
    # merged attempt overflowed -> un-merged into per-job batches
    assert sorted(map(sorted, calls)) \
        == sorted(map(sorted, [rows_a, rows_b]))
    assert sa.retries + sb.retries == 1
    assert sa.requests == sb.requests == 1


def test_copack_full_tail_not_parked():
    # a tail above the fill threshold dispatches immediately: packing
    # only pays when there is real headroom to merge into
    calls = []
    model = _resource(context_window=210)
    rows = [f"x{i}" for i in range(4)]

    def pack_call(batch):
        calls.append(list(batch))
        return [f"r:{r}" for r in batch]

    t0 = time.monotonic()
    with RequestScheduler(pack_linger_s=5.0) as sched:
        job = sched.submit_map(
            model, [f"k{i}" for i in range(4)], [40] * 4,
            prefix_tokens=10, run=lambda ps: pack_call([rows[p]
                                                        for p in ps]),
            single_flight=False, pack_key="p", pack_rows=rows,
            pack_call=pack_call)
        vals, _ = job.result(timeout=10)
    assert vals == [f"r:{r}" for r in rows]
    assert time.monotonic() - t0 < 4.0, \
        "a near-full tail must not wait out the packing linger"


# ---------------------------------------------------------------------------
# co-packing: pipeline-level equivalence + determinism
# ---------------------------------------------------------------------------
def _copack_table(n=22):
    return Table({
        "a": [f"first column text number {i} with body" for i in range(n)],
        "b": [f"second column text number {i} with body"
              for i in range(n)],
    })


_COPACK_MODEL = {"model": "cp", "context_window": 100_000,
                 "max_output_tokens": 8, "max_concurrency": 8}
# max_batch 16 over 22 rows -> each node plans [16, 6]: a full batch
# plus a part-filled tail; the two 6-row tails co-pack into one request
_COPACK_MAX_BATCH = 16


def _copack_ctx(**kw):
    return SemanticContext(provider=MockProvider(),
                           max_batch=_COPACK_MAX_BATCH, **kw)


def _copack_pipe(ctx, table):
    # two map nodes, SAME model + prompt + kind (shared metaprompt
    # prefix) over DIFFERENT columns (disjoint cache keys)
    return (Pipeline(ctx, table, "docs")
            .llm_complete("s1", _COPACK_MODEL, {"prompt": "summarize"},
                          ["a"])
            .llm_complete("s2", _COPACK_MODEL, {"prompt": "summarize"},
                          ["b"]))


def test_copack_identity_mirrors_map_core():
    ctx = SemanticContext(provider=MockProvider())
    pipe = _copack_pipe(ctx, _copack_table())
    ids = [copack_identity(ctx, n) for n in pipe.nodes]
    assert ids[0] is None                       # scan
    assert ids[1] == ids[2] != None             # noqa: E711 shared prefix
    assert ids[1][2] == "complete"
    other = Pipeline(ctx, _copack_table(), "d").llm_complete(
        "s3", _COPACK_MODEL, {"prompt": "different"}, ["a"])
    assert copack_identity(ctx, other.nodes[-1]) != ids[1]


def test_copack_pipeline_fewer_requests_same_rows():
    reset_global_catalog()
    table = _copack_table()
    ctx_serial = _copack_ctx()
    rows_serial = _copack_pipe(ctx_serial, table) \
        .collect(optimize=False).rows()

    results = {}
    for copack in (False, True):
        with RequestScheduler(pack_linger_s=0.5) as sched:
            ctx = _copack_ctx(scheduler=sched, copack=copack)
            rows = _copack_pipe(ctx, table).collect(optimize=False).rows()
            results[copack] = (rows, ctx.provider.stats.calls,
                               sched.stats.packed_requests,
                               sum(r.packed for r in ctx.reports))
    rows_off, calls_off, packed_off, rep_packed_off = results[False]
    rows_on, calls_on, packed_on, rep_packed_on = results[True]
    assert rows_off == rows_serial == rows_on, \
        "co-packing must be bit-identical to unpacked execution"
    assert calls_off == ctx_serial.provider.stats.calls
    assert calls_on < calls_off, \
        "co-packing must issue strictly fewer provider requests"
    assert packed_off == 0 and packed_on >= 1
    assert rep_packed_off == 0 and rep_packed_on >= 1


@pytest.mark.slow
def test_copack_deterministic_under_concurrency():
    reset_global_catalog()
    table = _copack_table()
    ctx_serial = _copack_ctx()
    expect = _copack_pipe(ctx_serial, table).collect(optimize=False).rows()
    for _ in range(5):
        with RequestScheduler(pack_linger_s=0.5) as sched:
            ctx = _copack_ctx(scheduler=sched)
            rows = _copack_pipe(ctx, table).collect(optimize=False).rows()
        assert rows == expect


def test_copack_escape_hatch_matches_serial_counts():
    reset_global_catalog()
    table = _copack_table()
    ctx_serial = _copack_ctx()
    _copack_pipe(ctx_serial, table).collect(optimize=False)
    with RequestScheduler() as sched:
        ctx = _copack_ctx(scheduler=sched, copack=False)
        _copack_pipe(ctx, table).collect(optimize=False)
    assert ctx.provider.stats.calls == ctx_serial.provider.stats.calls


def test_explain_reports_packed_request_estimate():
    reset_global_catalog()
    with RequestScheduler() as sched:
        ctx = _copack_ctx(scheduler=sched)
        pipe = _copack_pipe(ctx, _copack_table())
        text = pipe.explain()
        plan = pipe._plan()
    assert plan.optimized_cost.packed_requests > 0
    assert plan.optimized_cost.packed_requests \
        < plan.optimized_cost.requests
    assert "packed_req=" in text


def test_copack_same_name_different_caps_do_not_merge():
    # inline specs sharing a name all resolve to version 0; the identity
    # must still distinguish them — a merged request executes under ONE
    # job's model object, so differing output caps would truncate the
    # rider's rows
    ctx = SemanticContext(provider=MockProvider())
    small = dict(_COPACK_MODEL, max_output_tokens=8)
    big = dict(_COPACK_MODEL, max_output_tokens=256)
    pipe = (Pipeline(ctx, _copack_table(), "docs")
            .llm_complete("s1", small, {"prompt": "summarize"}, ["a"])
            .llm_complete("s2", big, {"prompt": "summarize"}, ["b"]))
    ids = [copack_identity(ctx, n) for n in pipe.nodes[1:]]
    assert None not in ids
    assert ids[0] != ids[1]

    reset_global_catalog()
    table = _copack_table()

    def build(c):
        return (Pipeline(c, table, "docs")
                .llm_complete("s1", small, {"prompt": "summarize"}, ["a"])
                .llm_complete("s2", big, {"prompt": "summarize"}, ["b"]))

    ctx_serial = _copack_ctx()
    rows_serial = build(ctx_serial).collect(optimize=False).rows()
    with RequestScheduler(pack_linger_s=0.5) as sched:
        ctx = _copack_ctx(scheduler=sched)
        rows = build(ctx).collect(optimize=False).rows()
        assert sched.stats.packed_requests == 0
    assert rows == rows_serial
    assert ctx.provider.stats.calls == ctx_serial.provider.stats.calls


# ---------------------------------------------------------------------------
# latency-first scheduling: rider expectations + deadline-aware flush
# ---------------------------------------------------------------------------
def test_copack_last_tail_out_flushes_immediately():
    # both expected submitters registered: the merged pack dispatches
    # the moment the second tail arrives, not after the 5s linger
    calls = []
    key = (_resource(context_window=1000).ref, "shared-prefix")
    t0 = time.monotonic()
    with RequestScheduler(pack_linger_s=5.0) as sched:
        sched.pack_expect(key, 2)
        ja, jb, rows_a, rows_b = _submit_packed_pair(sched, calls)
        va, _ = ja.result(timeout=10)
        vb, _ = jb.result(timeout=10)
    assert time.monotonic() - t0 < 4.0, \
        "last-tail-out did not flush: merged pack waited out the linger"
    assert va == [f"r:{r}" for r in rows_a]
    assert vb == [f"r:{r}" for r in rows_b]
    assert len(calls) == 1
    assert sorted(calls[0]) == sorted(rows_a + rows_b)
    assert sched.stats.packed_requests == 1


def test_copack_sole_expected_tail_skips_parking():
    # a lone tail from the LAST expected submitter has no one to wait
    # for: it dispatches immediately instead of parking
    calls = []
    model = _resource()
    rows = ["x0", "x1"]

    def pack_call(batch):
        calls.append(list(batch))
        return [f"r:{r}" for r in batch]

    t0 = time.monotonic()
    with RequestScheduler(pack_linger_s=5.0) as sched:
        sched.pack_expect((model.ref, "p"), 1)
        job = sched.submit_map(
            model, ["k0", "k1"], [10, 10], prefix_tokens=10,
            run=lambda ps: pack_call([rows[p] for p in ps]),
            single_flight=False, pack_key="p", pack_rows=rows,
            pack_call=pack_call)
        vals, _ = job.result(timeout=10)
    assert vals == ["r:x0", "r:x1"]
    assert time.monotonic() - t0 < 4.0
    assert len(calls) == 1
    assert sched.stats.packed_requests == 0


def test_copack_retire_flushes_lone_parked_tail():
    # regression (copack_end bugfix): when the group closes with a
    # registered submitter that never dispatched, the surviving parked
    # tail must flush immediately, not wait out the deadline
    calls = []
    model = _resource()
    rows = ["x0", "x1"]

    def pack_call(batch):
        calls.append(list(batch))
        return [f"r:{r}" for r in batch]

    key = (model.ref, "p")
    t0 = time.monotonic()
    with RequestScheduler(pack_linger_s=5.0) as sched:
        sched.pack_expect(key, 2)
        job = sched.submit_map(
            model, ["k0", "k1"], [10, 10], prefix_tokens=10,
            run=lambda ps: pack_call([rows[p] for p in ps]),
            single_flight=False, pack_key="p", pack_rows=rows,
            pack_call=pack_call)
        time.sleep(0.05)            # the tail parks, rider outstanding
        sched.pack_retire(key, 1)   # ...the rider never dispatches
        vals, _ = job.result(timeout=10)
    assert vals == ["r:x0", "r:x1"]
    assert time.monotonic() - t0 < 4.0, \
        "retiring the last expectation must flush the parked pack"
    assert len(calls) == 1
    assert sched.stats.packed_requests == 0


def test_copack_overflow_remainder_repacks():
    # an overflow-split remainder is exactly a part-filled tail: it
    # merges into a pending same-identity pack instead of paying a
    # sparse request of its own
    calls = []
    model = _resource(context_window=1000)
    rows_b = [f"b{i}" for i in range(4)]
    rows_a = [f"a{i}" for i in range(8)]
    failed = []

    def pack_call(batch):
        if len(batch) == 8 and not failed:
            failed.append(True)
            raise ContextOverflowError("merged too large")
        calls.append(list(batch))
        return [f"r:{r}" for r in batch]

    def make_run(rows):
        def run(positions):
            return pack_call([rows[p] for p in positions])
        return run

    with RequestScheduler(pack_linger_s=0.5) as sched:
        # job B: light 4-row tail parks (weight 112 of budget 900)
        jb = sched.submit_map(
            model, [f"kb{i}" for i in range(4)], [20] * 4,
            prefix_tokens=100, run=make_run(rows_b),
            single_flight=False, pack_key="p", pack_rows=rows_b,
            pack_call=pack_call)
        # job A: one near-full 8-row batch (weight 784 > 0.85 * 900 —
        # not parked) overflows once, splits 7+1; the 1-row remainder
        # rides B's parked pack
        ja = sched.submit_map(
            model, [f"ka{i}" for i in range(8)], [90] * 8,
            prefix_tokens=100, run=make_run(rows_a),
            single_flight=False, pack_key="p", pack_rows=rows_a,
            pack_call=pack_call)
        va, sa = ja.result(timeout=10)
        vb, _ = jb.result(timeout=10)
    assert va == [f"r:{r}" for r in rows_a]
    assert vb == [f"r:{r}" for r in rows_b]
    assert sched.stats.repacked_tails >= 1
    assert sa.retries == 1
    assert any(set(c) & set(rows_a) and set(c) & set(rows_b)
               for c in calls), \
        "the overflow remainder did not merge with the parked tail"


def test_llm_multi_copack_bit_identical_demux():
    # fused multi-output dispatches co-pack on the full rendered
    # multi-task prompt; the merged request demuxes bit-identically to
    # serial execution across every sub-output
    reset_global_catalog()
    from repro.core import build_multi_task
    subtasks = [{"kind": "filter", "prompt": {"prompt": "keep?"}},
                {"kind": "complete", "prompt": {"prompt": "summarize"}}]
    n = 22
    rows_a = [{"a": f"first text number {i} with body"}
              for i in range(n)]
    rows_b = [{"b": f"second text number {i} with body"}
              for i in range(n)]

    serial = SemanticContext(provider=MockProvider(),
                             max_batch=_COPACK_MAX_BATCH)
    expect_a = llm_multi(serial, _COPACK_MODEL, subtasks, rows_a)
    expect_b = llm_multi(serial, _COPACK_MODEL, subtasks, rows_b)

    with RequestScheduler(pack_linger_s=5.0) as sched:
        ctx = SemanticContext(provider=MockProvider(), scheduler=sched,
                              max_batch=_COPACK_MAX_BATCH)
        model = ctx.resolve_model(_COPACK_MODEL)
        texts = [ctx.resolve_prompt(st["prompt"])[0] for st in subtasks]
        ident = (id(ctx.provider), model, "multi", ctx.serialization,
                 build_multi_task([st["kind"] for st in subtasks],
                                  texts))
        out = [None, None]

        def worker(slot, rows):
            out[slot] = llm_multi(ctx, _COPACK_MODEL, subtasks, rows)

        t0 = time.monotonic()
        ctx.copack_begin({ident: 2})
        try:
            threads = [threading.Thread(target=worker, args=(0, rows_a)),
                       threading.Thread(target=worker, args=(1, rows_b))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        finally:
            ctx.copack_end({ident: 2})
        elapsed = time.monotonic() - t0
    assert out[0] == expect_a
    assert out[1] == expect_b
    assert sched.stats.packed_requests >= 1
    assert elapsed < 4.0, \
        "last-tail-out must beat the 5s linger for fused dispatches"


def test_copack_identity_covers_fused_nodes():
    # the optimizer's fused nodes expose the SAME identity llm_multi
    # mints, so structurally identical fusions can ride one request
    from repro.core import build_multi_task
    ctx = SemanticContext(provider=MockProvider())
    table = _copack_table()

    def build(col):
        return (Pipeline(ctx, table, "docs")
                .llm_filter(_COPACK_MODEL, {"prompt": "keep?"}, [col])
                .llm_complete("s", _COPACK_MODEL,
                              {"prompt": "summarize"}, [col]))

    na = build("a")._plan().nodes[1]
    nb = build("b")._plan().nodes[1]
    assert na.op == "llm_fused" == nb.op
    ida, idb = copack_identity(ctx, na), copack_identity(ctx, nb)
    assert ida is not None and ida == idb
    assert ida[2] == "multi"
    texts = [ctx.resolve_prompt(p)[0] for p in na.info["prompts"]]
    assert ida[4] == build_multi_task(na.info["kinds"], texts)
    # a structurally different fusion (other prompt) must not alias
    other = (Pipeline(ctx, table, "docs")
             .llm_filter(_COPACK_MODEL, {"prompt": "drop?"}, ["a"])
             .llm_complete("s", _COPACK_MODEL, {"prompt": "summarize"},
                           ["a"]))._plan().nodes[1]
    assert copack_identity(ctx, other) != ida


def test_copack_concurrent_distinct_prefixes_do_not_merge():
    # different prompts -> different prefix identities -> no merging,
    # and request counts match the serial path exactly
    reset_global_catalog()
    table = _copack_table()

    def build(ctx):
        return (Pipeline(ctx, table, "docs")
                .llm_complete("s1", _COPACK_MODEL, {"prompt": "one"},
                              ["a"])
                .llm_complete("s2", _COPACK_MODEL, {"prompt": "two"},
                              ["b"]))

    ctx_serial = _copack_ctx()
    rows_serial = build(ctx_serial).collect(optimize=False).rows()
    with RequestScheduler() as sched:
        ctx = _copack_ctx(scheduler=sched)
        rows = build(ctx).collect(optimize=False).rows()
        assert sched.stats.packed_requests == 0
    assert rows == rows_serial
    assert ctx.provider.stats.calls == ctx_serial.provider.stats.calls
