import os
import sys

# smoke tests and benches must see ONE device — the 512-device override is
# dryrun.py-only (see system design notes).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
