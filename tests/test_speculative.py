"""Speculative filter-chain dispatch tests.

The core contract: speculative execution of an ``llm_filter`` chain —
all members evaluated concurrently over the chain INPUT, masks ANDed —
produces a bit-identical surviving tuple stream and bit-identical
per-member masks vs serial chain execution, across chain lengths,
selectivities, and failure injections (overflow-poisoned tuples,
malformed provider output).  Verified property-based (hypothesis).

Also covered here: the calibrated speculation decision (waste cap,
waves/wall comparison, explain() reporting) and the lifecycle of the
``SelectivityStore``/``CalibrationStore`` sidecars (pruning on resource
re-version, debounced flush on context exit, corrupt-sidecar recovery).
"""

import json
import re

import numpy as np
import pytest

from repro.core import (CalibrationStore, MockProvider, PredictionCache,
                        RequestScheduler, SemanticContext,
                        reset_global_catalog)
from repro.core import functions as F
from repro.core.batching import ContextOverflowError
from repro.core.resources import Catalog
from repro.engine import Pipeline, Table

try:        # property tests need the optional hypothesis dependency;
            # the deterministic tests below run either way
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
    SMALL = settings(max_examples=25, deadline=None)
except ImportError:
    HAVE_HYPOTHESIS = False


def _marker_behaviour(kind, prefix, rows):
    """Deterministic content-based filter verdicts: prompt ``has P<k>``
    passes rows carrying the ``P<k>`` marker.  Failure injections are
    content-based too, so serial and speculative execution see the same
    per-tuple dispositions regardless of batch composition:

      * a ``BOOM`` row poisons any batch containing it with a context
        overflow — the adaptive splitter isolates it and NULLs it
        (decodes to False);
      * a ``GIBBER`` row gets a malformed verdict (decodes to False).
    """
    if kind != "filter":
        return None
    if any("BOOM" in r for r in rows):
        raise ContextOverflowError("poisoned row in batch")
    marker = re.search(r"has (P\d+)", prefix).group(1)
    out = []
    for i, r in enumerate(rows):
        if "GIBBER" in r:
            out.append(f"{i}: maybe?!")
        else:
            out.append(f"{i}: {'true' if marker in r else 'false'}")
    return out


def _member_model(k: int, **kw) -> dict:
    # distinct model per member: same-model chains would fuse into one
    # multi-task pass before the speculation rule ever sees them
    base = {"model": f"pm{k}", "context_window": 100_000,
            "max_output_tokens": 8, "max_concurrency": 8}
    base.update(kw)
    return base


def _chain_pipeline(ctx, table, n_filters, **model_kw):
    pipe = Pipeline(ctx, table, "docs")
    for k in range(n_filters):
        pipe = pipe.llm_filter(_member_model(k, **model_kw),
                               {"prompt": f"has P{k}"}, ["text"])
    return pipe


def _serial_reference(table, n_filters):
    """Serial chain execution via the raw semantic functions: member k
    sees only the survivors of members < k."""
    ctx = SemanticContext(provider=MockProvider(_marker_behaviour))
    surv = table
    masks = []
    for k in range(n_filters):
        tuples = [{"text": r["text"]} for r in surv.rows()]
        mask = F.llm_filter(ctx, _member_model(k), {"prompt": f"has P{k}"},
                            tuples)
        masks.append(mask)
        surv = surv.filter_mask(mask)
    return surv, masks


# ---------------------------------------------------------------------------
# property: speculative == serial, bit for bit
# ---------------------------------------------------------------------------
def _check_equivalence(n_filters, rows):
    """Shared harness: build the table from (pass-bits, failure-kind,
    dup) row descriptors, run serial and speculative execution, and
    assert bit-identical survivors and per-member masks."""
    texts = []
    for i, (passes, kind, dup) in enumerate(rows):
        tag = "" if dup else f"r{i} "
        markers = " ".join(f"P{k}" for k in range(n_filters) if passes[k])
        inject = {"ok": "", "boom": " BOOM", "gibber": " GIBBER"}[kind]
        texts.append(f"{tag}doc {markers}{inject}")
    table = Table({"text": texts})

    ref, serial_masks = _serial_reference(table, n_filters)

    with RequestScheduler(max_workers=8) as sched:
        ctx = SemanticContext(provider=MockProvider(_marker_behaviour),
                              scheduler=sched, speculate="always")
        pipe = _chain_pipeline(ctx, table, n_filters)
        out = pipe.collect()

    assert out.rows() == ref.rows()

    spec_nodes = [n for n in pipe._executed_nodes
                  if n.op == "llm_spec_chain"]
    assert len(spec_nodes) == 1, "chain was not speculated"
    full = spec_nodes[0].info["member_masks"]
    assert len(full) == n_filters
    # each member's full-input mask, restricted to the tuples the serial
    # chain would actually have shown it, must match the serial mask
    alive = list(range(len(texts)))
    for k in range(n_filters):
        assert [full[k][i] for i in alive] == serial_masks[k]
        alive = [i for i in alive if full[k][i]]
    assert [r["text"] for r in out.rows()] == [texts[i] for i in alive]


if HAVE_HYPOTHESIS:
    @SMALL
    @given(
        n_filters=st.integers(2, 4),
        rows=st.lists(
            st.tuples(st.tuples(*[st.booleans()] * 4),
                      st.sampled_from(["ok", "ok", "ok", "boom",
                                       "gibber"]),
                      st.booleans()),      # True -> duplicate-prone text
            min_size=0, max_size=16))
    def test_speculative_chain_equals_serial(n_filters, rows):
        _check_equivalence(n_filters, rows)


@pytest.mark.parametrize("n_filters,rows", [
    # mixed pass patterns, no failures
    (2, [((True, True, False, False), "ok", False),
         ((False, True, False, False), "ok", False),
         ((True, False, False, False), "ok", False)]),
    # overflow-poisoned and malformed rows interleaved with duplicates
    (3, [((True, True, True, False), "ok", False),
         ((True, True, True, False), "boom", False),
         ((True, True, True, False), "gibber", False),
         ((True, False, True, False), "ok", True),
         ((True, False, True, False), "ok", True),
         ((False, False, False, False), "boom", True)]),
    # empty input stream
    (2, []),
    # everything eliminated by the first member
    (4, [((False, True, True, True), "ok", False)] * 5),
])
def test_speculative_chain_equals_serial_fixed_cases(n_filters, rows):
    # deterministic spot checks of the same harness — these run even
    # without the optional hypothesis dependency
    _check_equivalence(n_filters, rows)


def test_speculative_chain_without_scheduler_matches_serial():
    # the mask-join runs members on dedicated threads, so speculation
    # works (and stays equivalent) even on a scheduler-less context
    texts = [f"r{i} doc {'P0' if i % 2 else ''} {'P1' if i % 3 else ''}"
             for i in range(12)]
    table = Table({"text": texts})
    ref, _ = _serial_reference(table, 2)
    ctx = SemanticContext(provider=MockProvider(_marker_behaviour))
    out = _chain_pipeline(ctx, table, 2).collect(speculate="always")
    assert out.rows() == ref.rows()


def test_optimize_false_ignores_speculation():
    table = Table({"text": [f"r{i} doc P0 P1" for i in range(6)]})
    ctx = SemanticContext(provider=MockProvider(_marker_behaviour),
                          speculate="always")
    pipe = _chain_pipeline(ctx, table, 2)
    out = pipe.collect(optimize=False)
    assert all(n.op != "llm_spec_chain" for n in pipe._executed_nodes)
    assert len(out) == 6


# ---------------------------------------------------------------------------
# the speculation decision (auto mode)
# ---------------------------------------------------------------------------
def _decision_ctx(**kw):
    return SemanticContext(provider=MockProvider(_marker_behaviour),
                           enable_cache=False, enable_dedup=False, **kw)


def test_auto_speculates_when_waves_win():
    # uncalibrated: decision falls back to the waves comparison — a
    # 2-filter chain at high concurrency is 2 serial waves vs 1
    table = Table({"text": [f"r{i} doc P0 P1" for i in range(20)]})
    ctx = _decision_ctx(max_batch=5)
    pipe = _chain_pipeline(ctx, table, 2)
    plan = pipe._plan(True)
    assert [d.chosen for d in plan.spec_decisions] == [True]
    d = plan.spec_decisions[0]
    assert d.spec_waves < d.serial_waves
    assert d.serial_wall_s == 0.0 and d.spec_wall_s == 0.0
    spec_ops = [n.op for n in plan.nodes]
    assert "llm_spec_chain" in spec_ops


def test_auto_rejects_when_waste_exceeds_cap():
    # a near-perfectly selective first filter makes speculation waste
    # almost every later request; a tight cap must reject the chain
    table = Table({"text": [f"r{i} doc P1" for i in range(40)]})
    ctx = _decision_ctx(max_batch=4, speculate_waste_cap=0.3)
    ctx.record_selectivity("inline:has P0", 1, 100)     # ~1% pass rate
    pipe = _chain_pipeline(ctx, table, 2)
    plan = pipe._plan(True)
    assert [d.chosen for d in plan.spec_decisions] == [False]
    assert "exceeds cap" in plan.spec_decisions[0].reason
    assert all(n.op != "llm_spec_chain" for n in plan.nodes)
    # the rejected chain still executes serially and correctly
    out = pipe.collect(speculate=True)
    assert len(out) == 0


def test_auto_uses_calibrated_wall_when_available():
    # calibration for every member model flips the decision from waves
    # to observed-latency wall estimates, reported on the decision
    table = Table({"text": [f"r{i} doc P0 P1" for i in range(20)]})
    ctx = _decision_ctx(max_batch=5)
    for k in range(2):
        ctx.record_calibration(f"pm{k}@0", requests=8, retries=0,
                               tuples=40, latencies=[0.05] * 8)
    plan = _chain_pipeline(ctx, table, 2)._plan(True)
    d = plan.spec_decisions[0]
    assert d.chosen
    assert d.spec_wall_s > 0 and d.serial_wall_s > d.spec_wall_s
    assert "calibrated wall" in d.reason
    assert plan.optimized_cost.wall_s > 0
    assert plan.optimized_cost.wasted_requests == d.wasted_requests


def test_explain_reports_speculation_section():
    table = Table({"text": [f"r{i} doc P0 P1" for i in range(20)]})
    with RequestScheduler() as sched:
        ctx = _decision_ctx(max_batch=5, scheduler=sched)
        pipe = _chain_pipeline(ctx, table, 2)
        pipe.collect(speculate=True)
        text = pipe.explain()
    assert "Speculation:" in text
    assert "serial_waves=" in text and "spec_waves=" in text
    assert "wasted<=" in text
    assert "SPECULATE" in text
    # per-member execution reports render under the spec-chain node
    assert "member[0]:" in text and "member[1]:" in text


def test_retry_rate_inflates_calibrated_request_estimate():
    table = Table({"text": [f"r{i} doc P0" for i in range(20)]})
    base = _decision_ctx(max_batch=5)
    pipe = Pipeline(base, table).llm_filter(
        _member_model(0), {"prompt": "has P0"}, ["text"])
    clean = pipe._plan(False).optimized_cost.requests

    noisy = _decision_ctx(max_batch=5)
    noisy.record_calibration("pm0@0", requests=10, retries=5, tuples=50,
                             latencies=[0.01] * 10)
    pipe2 = Pipeline(noisy, table).llm_filter(
        _member_model(0), {"prompt": "has P0"}, ["text"])
    inflated = pipe2._plan(False).optimized_cost.requests
    assert inflated > clean


# ---------------------------------------------------------------------------
# CalibrationStore lifecycle
# ---------------------------------------------------------------------------
def test_calibration_store_roundtrip_and_corruption(tmp_path):
    store = CalibrationStore(str(tmp_path / "c.json"))
    assert store.load() == {}
    rec = {"m@1": {"requests": 4, "retries": 1, "tuples": 20,
                   "latency_s": [0.1, 0.2]}}
    store.save(rec)
    assert store.load() == rec
    (tmp_path / "c.json").write_text("{definitely not json")
    assert store.load() == {}
    # invalid records are dropped, valid ones kept
    (tmp_path / "c.json").write_text(json.dumps({"models": {
        "good@1": {"requests": 1, "retries": 0, "tuples": 2,
                   "latency_s": [0.5]},
        "bad1": {"requests": -3, "retries": 0, "tuples": 0,
                 "latency_s": []},
        "bad2": {"requests": 1, "retries": 0, "tuples": 1,
                 "latency_s": "oops"},
    }}))
    assert set(store.load()) == {"good@1"}


def test_calibration_persists_across_sessions(tmp_path):
    reset_global_catalog()
    cache_path = str(tmp_path / "cache.jsonl")
    rows = [{"t": f"row {i}"} for i in range(8)]
    model = {"model": "m", "context_window": 8192, "max_output_tokens": 8}
    with SemanticContext(
            cache=PredictionCache(persist_path=cache_path)) as ctx1:
        F.llm_complete(ctx1, model, {"prompt": "p"}, rows)
        assert ctx1.calibrated_latency("m@0") is not None
    assert (tmp_path / "cache.jsonl.calibration.json").exists()

    ctx2 = SemanticContext(cache=PredictionCache(persist_path=cache_path))
    assert ctx2.calibrated_latency("m@0") is not None
    assert ctx2.calibration_stats["m@0"]["requests"] >= 1


def test_calibration_pruned_on_model_version_bump(tmp_path):
    reset_global_catalog()
    cache_path = str(tmp_path / "cache.jsonl")
    catalog = Catalog()
    catalog.create_model("m", arch="mock")
    with SemanticContext(
            catalog=catalog,
            cache=PredictionCache(persist_path=cache_path)) as ctx1:
        ctx1.record_calibration("m@1", requests=3, retries=0, tuples=9,
                                latencies=[0.1, 0.1, 0.1])

    catalog.update_model("m", context_window=9999)      # now m@2
    ctx2 = SemanticContext(catalog=catalog,
                           cache=PredictionCache(persist_path=cache_path))
    assert "m@1" not in ctx2.calibration_stats
    assert ctx2.calibrated_latency("m@1") is None
    # inline-spec refs (version 0, not in the catalog) survive pruning
    with SemanticContext(
            catalog=catalog,
            cache=PredictionCache(persist_path=cache_path)) as ctx3:
        ctx3.record_calibration("inline-model@0", requests=1, retries=0,
                                tuples=2, latencies=[0.2])
    ctx4 = SemanticContext(catalog=catalog,
                           cache=PredictionCache(persist_path=cache_path))
    assert "inline-model@0" in ctx4.calibration_stats


def test_calibration_latency_window_bounded(tmp_path):
    from repro.core.cache import CALIBRATION_WINDOW
    ctx = SemanticContext()
    for _ in range(5):
        ctx.record_calibration("m@1", requests=100, retries=0,
                               tuples=100, latencies=[0.01] * 100)
    assert len(ctx.calibration_stats["m@1"]["latency_s"]) \
        == CALIBRATION_WINDOW
    assert ctx.calibration_stats["m@1"]["requests"] == 500


def test_calibrated_latency_percentiles():
    ctx = SemanticContext()
    ctx.record_calibration("m@1", requests=4, retries=0, tuples=8,
                           latencies=[0.1, 0.2, 0.3, 0.4])
    assert ctx.calibrated_latency("m@1") == pytest.approx(0.25)
    assert ctx.calibrated_latency("m@1", pct=100) == pytest.approx(0.4)
    assert ctx.calibrated_latency("missing@1") is None
    assert ctx.calibrated_retry_rate("missing@1") == 0.0


# ---------------------------------------------------------------------------
# debounced flush on context exit + corrupt-sidecar recovery
# ---------------------------------------------------------------------------
def test_debounced_stats_flush_on_context_exit(tmp_path):
    reset_global_catalog()
    cache_path = str(tmp_path / "cache.jsonl")
    sel_path = tmp_path / "cache.jsonl.selectivity.json"
    cal_path = tmp_path / "cache.jsonl.calibration.json"
    with SemanticContext(
            cache=PredictionCache(persist_path=cache_path)) as ctx:
        # first write lands immediately (debounce window starts), the
        # second is deferred inside the interval
        ctx.record_selectivity("p@1", 1, 2)
        ctx.record_selectivity("p@1", 1, 2)
        ctx.record_calibration("m@1", requests=1, retries=0, tuples=2,
                               latencies=[0.1])
        ctx.record_calibration("m@1", requests=1, retries=0, tuples=2,
                               latencies=[0.2])
        assert json.loads(sel_path.read_text())["stats"]["p@1"] == [1, 2]
        assert json.loads(cal_path.read_text())["models"]["m@1"][
            "requests"] == 1
    # context exit force-flushes both deferred observations
    assert json.loads(sel_path.read_text())["stats"]["p@1"] == [2, 4]
    assert json.loads(cal_path.read_text())["models"]["m@1"][
        "requests"] == 2


def test_corrupt_sidecars_recover_to_empty(tmp_path):
    reset_global_catalog()
    cache_path = str(tmp_path / "cache.jsonl")
    (tmp_path / "cache.jsonl.selectivity.json").write_text("<not json>")
    (tmp_path / "cache.jsonl.calibration.json").write_text("[1, 2, 3]")
    ctx = SemanticContext(cache=PredictionCache(persist_path=cache_path))
    assert ctx.selectivity_stats == {}
    assert ctx.calibration_stats == {}
    # and the session can record + overwrite the corrupt files
    with ctx:
        ctx.record_selectivity("p@1", 1, 4)
        ctx.record_calibration("m@1", requests=1, retries=0, tuples=1,
                               latencies=[0.1])
    ctx2 = SemanticContext(cache=PredictionCache(persist_path=cache_path))
    assert ctx2.selectivity_stats == {"p@1": [1, 4]}
    assert ctx2.calibration_stats["m@1"]["requests"] == 1


# ---------------------------------------------------------------------------
# report plumbing
# ---------------------------------------------------------------------------
def test_execution_reports_carry_latencies():
    ctx = SemanticContext(provider=MockProvider())
    F.llm_complete(ctx, {"model": "m", "context_window": 8192,
                         "max_output_tokens": 8},
                   {"prompt": "p"}, [{"t": f"row {i}"} for i in range(6)])
    rep = ctx.last_report()
    assert rep.requests >= 1
    assert len(rep.latencies) == rep.requests
    assert all(isinstance(x, float) and x >= 0 for x in rep.latencies)
    assert np.isfinite(ctx.calibrated_latency("m@0"))


@pytest.mark.parametrize("scheduled", [False, True])
def test_embedding_dispatch_feeds_calibration(scheduled):
    # both embedding dispatch paths (serial loop and scheduler) must
    # fold their stats into the calibration sidecar like the chat path
    sched = RequestScheduler() if scheduled else None
    try:
        ctx = SemanticContext(provider=MockProvider(), scheduler=sched)
        F.llm_embedding(ctx, {"model": "e", "embedding_dim": 8},
                        [f"passage {i}" for i in range(5)])
    finally:
        if sched is not None:
            sched.shutdown()
    assert ctx.calibration_stats["e@0"]["requests"] >= 1
    assert np.isfinite(ctx.calibrated_latency("e@0"))
