"""Property-based tests (hypothesis) on the system's invariants."""

import math

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the optional hypothesis dependency")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (MockProvider, PredictionCache, SemanticContext,
                        combanz, combmed, combmnz, combsum, execute_serial,
                        llm_complete, plan_batches, rrf)
from repro.core.batching import ContextOverflowError
from repro.core.metaprompt import serialize_tuple
from repro.retrieval import BM25Index

SMALL = settings(max_examples=40, deadline=None)


# --------------------------------------------------------------------------
# adaptive batching invariants
# --------------------------------------------------------------------------
@SMALL
@given(costs=st.lists(st.integers(1, 300), min_size=1, max_size=100),
       ctx_window=st.integers(50, 2000),
       out_tokens=st.integers(1, 50))
def test_batch_plan_partition(costs, ctx_window, out_tokens):
    """Every tuple lands in exactly one batch, order-preserving."""
    plan = plan_batches(costs, prefix_tokens=10, context_window=ctx_window,
                        max_output_tokens=out_tokens)
    flat = [i for b in plan.batches for i in b]
    assert flat == list(range(len(costs)))
    # no batch except singletons exceeds the budget
    budget = ctx_window - 10
    for b in plan.batches:
        if len(b) > 1:
            assert sum(costs[i] + out_tokens for i in b) <= budget


@SMALL
@given(n=st.integers(1, 60), cap=st.integers(1, 400))
def test_adaptive_backoff_terminates_and_covers(n, cap):
    """Provider rejects batches over ``cap`` tokens; the 10% backoff must
    still assign a result (or NULL) to every tuple."""
    costs = [13] * n

    def call(batch):
        if len(batch) * 20 > cap:
            raise ContextOverflowError("too big")
        return [f"v{i}" for i in batch]

    results, stats = execute_serial(list(range(n)), costs, prefix_tokens=0,
                                    context_window=10_000,
                                    max_output_tokens=7, call=call)
    if 20 > cap:
        assert all(r is None for r in results)
        assert stats.nulls == n
    else:
        assert all(r is not None for r in results)


# --------------------------------------------------------------------------
# dedup + cache semantics
# --------------------------------------------------------------------------
@SMALL
@given(vals=st.lists(st.sampled_from(["a", "b", "c", "d"]), min_size=1,
                     max_size=40))
def test_dedup_equals_no_dedup(vals):
    tuples = [{"v": v} for v in vals]
    model = {"model": "m", "context_window": 100_000,
             "max_output_tokens": 4}
    prompt = {"prompt": "classify"}
    ctx1 = SemanticContext(enable_dedup=True)
    ctx2 = SemanticContext(enable_dedup=False, enable_cache=False)
    r1 = llm_complete(ctx1, model, prompt, tuples)
    r2 = llm_complete(ctx2, model, prompt, tuples)
    assert r1 == r2
    assert ctx1.reports[-1].n_unique == len(set(vals))


@SMALL
@given(vals=st.lists(st.text(alphabet="xyz", min_size=1, max_size=4),
                     min_size=1, max_size=20))
def test_cache_hit_equals_recompute(vals):
    tuples = [{"v": v} for v in vals]
    model = {"model": "m", "context_window": 100_000,
             "max_output_tokens": 4}
    prompt = {"prompt": "classify"}
    ctx = SemanticContext()
    first = llm_complete(ctx, model, prompt, tuples)
    calls_before = ctx.provider.stats.calls
    second = llm_complete(ctx, model, prompt, tuples)
    assert second == first
    assert ctx.provider.stats.calls == calls_before     # all hits, no calls


def test_cache_lru_eviction():
    c = PredictionCache(capacity=3)
    for i in range(5):
        c.put(f"k{i}", i)
    assert c.get("k0") == (False, None)
    assert c.get("k4") == (True, 4)


# --------------------------------------------------------------------------
# fusion properties
# --------------------------------------------------------------------------
scores = st.lists(st.floats(0, 10, allow_nan=False), min_size=2,
                  max_size=30)


@SMALL
@given(s=scores)
def test_fusion_permutation_consistency(s):
    """Fusing a column with itself preserves the ranking order."""
    a = np.asarray(s)
    for fn in (combsum, combmnz, combanz, combmed):
        f = fn(a, a)
        assert np.all(np.argsort(-f, kind="stable")
                      == np.argsort(-fn(a, a), kind="stable"))


@SMALL
@given(s=scores)
def test_rrf_rank_monotonic(s):
    """Higher single-retriever score can never lower the RRF score."""
    a = np.asarray(s)
    f = rrf(a)
    order = np.argsort(-a, kind="stable")
    fo = f[order]
    assert np.all(np.diff(fo) <= 1e-12)


@SMALL
@given(s=scores)
def test_combsum_commutative(s):
    a = np.asarray(s)
    b = a[::-1].copy()
    assert np.allclose(combsum(a, b), combsum(b, a))


# --------------------------------------------------------------------------
# BM25 properties
# --------------------------------------------------------------------------
docs_strategy = st.lists(
    st.lists(st.sampled_from("apple banana cherry join query".split()),
             min_size=1, max_size=12).map(" ".join),
    min_size=1, max_size=15)


@SMALL
@given(docs=docs_strategy)
def test_bm25_nonnegative_and_zero_without_overlap(docs):
    idx = BM25Index.build(docs)
    s = idx.score("join query")
    assert (s >= 0).all()
    s2 = idx.score("zebra")
    assert np.allclose(s2, 0.0)


@SMALL
@given(docs=docs_strategy)
def test_bm25_tf_monotonic(docs):
    """A doc containing the query term scores >= one that doesn't,
    all else equal (same length)."""
    docs = list(docs) + ["join join join", "apple apple apple"]
    idx = BM25Index.build(docs)
    s = idx.score("join")
    assert s[len(docs) - 2] > s[len(docs) - 1]


# --------------------------------------------------------------------------
# serialization determinism (cache-key stability)
# --------------------------------------------------------------------------
@SMALL
@given(d=st.dictionaries(st.sampled_from(["a", "b", "c"]),
                         st.text(max_size=8), min_size=1, max_size=3),
       fmt=st.sampled_from(["xml", "json", "markdown"]))
def test_serialization_deterministic(d, fmt):
    assert serialize_tuple(d, fmt) == serialize_tuple(dict(d), fmt)
