"""Training substrate: optimization, checkpointing, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.train import run as train_run
from repro.models import model as M
from repro.training import HParams, adamw_init, make_train_step
from repro.training.checkpoint import CheckpointManager
from repro.training.data import (DataConfig, StragglerWatchdog,
                                 SyntheticTokenPipeline)


def test_loss_decreases():
    cfg = get_smoke_config("olmo-1b").replace(remat=False)
    hp = HParams(lr=1e-3, warmup_steps=2, total_steps=30)
    step = jax.jit(make_train_step(cfg, hp), donate_argnums=(0, 1))
    data = SyntheticTokenPipeline(DataConfig(cfg.vocab_size, 32, 4))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    cfg = get_smoke_config("olmo-1b").replace(remat=False)
    data = SyntheticTokenPipeline(DataConfig(cfg.vocab_size, 16, 8))
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    outs = {}
    for accum in (1, 4):
        hp = HParams(lr=1e-3, warmup_steps=1, total_steps=10,
                     accum_steps=accum)
        step = jax.jit(make_train_step(cfg, hp))
        p2, _, m = step(params, adamw_init(params), batch)
        outs[accum] = (float(m["total_loss"]),
                       np.asarray(jax.tree.leaves(p2)[0], np.float32))
    assert abs(outs[1][0] - outs[4][0]) < 5e-3
    np.testing.assert_allclose(outs[1][1], outs[4][1], atol=5e-3)


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": np.arange(6.0).reshape(2, 3)},
             "opt": {"step": np.int32(7),
                     "stages": [{"a": np.ones(3)}, {"a": np.zeros(2)}]}}
    mgr.save(7, state)
    out = mgr.restore_latest()
    assert out["opt"]["step"] == 7
    np.testing.assert_array_equal(out["params"]["w"], state["params"]["w"])
    assert isinstance(out["opt"]["stages"], list)          # list roundtrip
    # keep-N gc
    for s in (8, 9, 10):
        mgr.save(s, state)
    assert mgr.list_steps() == [9, 10]


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp file (simulated crash mid-save) is never restored."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": np.ones(2)})
    (tmp_path / "step_0000000002.tmp.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1
    out = mgr.restore_latest()
    np.testing.assert_array_equal(out["x"], np.ones(2))


@pytest.mark.slow
def test_fault_tolerance_resume_is_bitwise(tmp_path):
    """Kill at step 7, resume -> same final loss as the uninterrupted run."""
    args = ["--arch", "olmo-1b", "--smoke", "--steps", "12",
            "--global-batch", "2", "--seq-len", "16",
            "--ckpt-every", "4", "--log-every", "100"]
    losses_full = train_run(args + ["--ckpt-dir", str(tmp_path / "a")])

    with pytest.raises(SystemExit):
        train_run(args + ["--ckpt-dir", str(tmp_path / "b"),
                          "--die-at-step", "7"])
    losses_resumed = train_run(args + ["--ckpt-dir", str(tmp_path / "b"),
                                       "--resume", "auto"])
    # resumed run restarts from step 4 (last checkpoint); its final losses
    # must equal the uninterrupted run's bitwise
    np.testing.assert_array_equal(np.asarray(losses_full[-4:]),
                                  np.asarray(losses_resumed[-4:]))


def test_data_pipeline_deterministic_and_elastic():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=3)
    one_host = SyntheticTokenPipeline(cfg, 0, 1).batch_at(5)
    shards = [SyntheticTokenPipeline(cfg, h, 4).batch_at(5)
              for h in range(4)]
    glued = np.concatenate([s["tokens"] for s in shards])
    np.testing.assert_array_equal(one_host["tokens"], glued)
    # same step re-requested -> identical (resumability)
    again = SyntheticTokenPipeline(cfg, 0, 1).batch_at(5)
    np.testing.assert_array_equal(one_host["tokens"], again["tokens"])


def test_straggler_watchdog_flags_outlier():
    import time
    wd = StragglerWatchdog(threshold=3.0)
    for i in range(10):
        wd.start()
        time.sleep(0.002)
        assert not wd.stop()
    wd.start()
    time.sleep(0.05)
    assert wd.stop()
    assert wd.flagged_steps
