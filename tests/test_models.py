"""Per-architecture smoke tests + prefill/decode cache equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.configs.specs import input_specs, make_batch
from repro.models import model as M
from repro.models.config import SHAPES, ShapeCell

ARCHS = list_archs()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one forward + loss on CPU, shapes + no NaNs."""
    cfg = get_smoke_config(arch).replace(remat=False)
    cell = ShapeCell("smoke", 32, 2, "train")
    batch = make_batch(cfg, cell)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    logits, aux = M.forward_train(cfg, params, batch)
    S_expected = 32 if cfg.frontend != "vision" else 32
    assert logits.shape[0] == 2
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits).all())
    loss, metrics = M.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(loss) > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_grad_step(arch):
    """One backward pass produces finite grads for every leaf."""
    cfg = get_smoke_config(arch).replace(remat=True)
    batch = make_batch(cfg, ShapeCell("smoke", 16, 2, "train"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    (loss, _), grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    finite = jax.tree.map(lambda g: bool(jnp.isfinite(g).all()), grads)
    assert all(jax.tree.leaves(finite))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch).replace(remat=False, capacity_factor=16.0)
    batch = make_batch(cfg, ShapeCell("smoke", 16, 2, "prefill"))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    logits_full, _ = M.forward_train(cfg, params, batch)
    S_txt = batch["tokens"].shape[1]
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S_txt - 3]
    lg, cache, pos = M.prefill(cfg, params, pre, 24)
    prefix = cfg.num_prefix_tokens if cfg.frontend == "vision" else 0
    errs = [float(jnp.max(jnp.abs(
        lg[:, -1] - logits_full[:, prefix + S_txt - 4])))]
    for i in range(3):
        tok = batch["tokens"][:, S_txt - 3 + i:S_txt - 2 + i]
        lg, cache = M.decode_step(cfg, params, tok, cache,
                                  jnp.int32(pos + i))
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0] - logits_full[:, prefix + S_txt - 3 + i]))))
    # bf16 params: tied-embedding logits round at ~0.01-0.03 absolute
    assert max(errs) < 5e-2


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_param_counts_plausible():
    """Analytic param counts should be in the ballpark of the model names."""
    expect = {"olmo-1b": (0.9e9, 1.6e9), "granite-8b": (7e9, 9.5e9),
              "mixtral-8x7b": (42e9, 50e9), "qwen1.5-32b": (28e9, 36e9),
              "falcon-mamba-7b": (6e9, 9e9), "gemma3-12b": (10e9, 14e9),
              "deepseek-moe-16b": (14e9, 20e9),
              "recurrentgemma-9b": (8e9, 11.5e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).num_params()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_moe_capacity_drops_are_bounded():
    """With cf=1.25 some tokens may drop, but normal batches keep most."""
    cfg = get_smoke_config("mixtral-8x7b").replace(remat=False)
    batch = make_batch(cfg, ShapeCell("smoke", 64, 2, "train"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    logits, aux = M.forward_train(cfg, params, batch)
    assert bool(jnp.isfinite(logits).all())
    assert float(aux) > 0          # load-balance aux loss reported


def test_input_specs_cover_all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for cell in SHAPES.values():
            specs = input_specs(cfg, cell)
            assert "tokens" in specs
            if cell.kind == "decode":
                assert specs["tokens"].shape == (cell.global_batch, 1)
