"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rg_lru.ops import rg_lru
from repro.kernels.rg_lru.ref import rg_lru_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.topk_sim.ops import topk_sim
from repro.kernels.topk_sim.ref import topk_sim_ref

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOLS[jnp.bfloat16] if dtype == jnp.bfloat16 else TOLS[jnp.float32]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KH,hd,causal,window,bq,bk",
    [
        (2, 64, 4, 2, 32, True, 0, 16, 16),
        (1, 96, 8, 8, 16, True, 0, 32, 16),
        (2, 48, 4, 1, 16, True, 16, 16, 16),     # MQA + sliding window
        (1, 80, 6, 2, 64, False, 0, 16, 32),     # bidirectional (encoder)
        (1, 33, 4, 2, 16, True, 0, 16, 16),      # ragged -> padding path
    ])
def test_flash_attention(rng, B, S, H, KH, hd, causal, window, bq, bk,
                         dtype):
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, KH, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, KH, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KH,hd,window,bs",
                         [(3, 100, 8, 4, 32, 0, 32),
                          (2, 64, 4, 4, 16, 16, 16),
                          (1, 257, 8, 2, 64, 0, 64)])
def test_decode_attention(rng, B, S, H, KH, hd, window, bs, dtype):
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), dtype)
    kc = jnp.asarray(rng.standard_normal((B, S, KH, hd)), dtype)
    vc = jnp.asarray(rng.standard_normal((B, S, KH, hd)), dtype)
    pos = jnp.asarray(rng.integers(0, S, B), jnp.int32)
    out = decode_attention(q, kc, vc, pos, window=window, block_s=bs)
    ref = decode_attention_ref(q, kc, vc, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,di,N,chunk,bd",
                         [(2, 80, 48, 8, 16, 16),
                          (1, 128, 64, 16, 32, 64),
                          (2, 33, 24, 4, 16, 8)])
def test_ssm_scan(rng, B, S, di, N, chunk, bd, dtype):
    x = jnp.asarray(rng.standard_normal((B, S, di)), dtype)
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, di))) * 0.1, dtype)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), dtype)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), dtype)
    Al = jnp.asarray(np.log(np.abs(rng.standard_normal((di, N))) + 0.5),
                     jnp.float32)
    D = jnp.asarray(rng.standard_normal((di,)), jnp.float32)
    out = ssm_scan(x, dt, Bm, Cm, Al, D, chunk=chunk, block_d=bd)
    ref = ssm_scan_ref(x, dt, Bm, Cm, Al, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5 * _tol(dtype), rtol=5 * _tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,di,chunk,bd",
                         [(2, 80, 48, 16, 16), (1, 200, 32, 64, 32)])
def test_rg_lru(rng, B, S, di, chunk, bd, dtype):
    a = jnp.asarray(rng.uniform(0.5, 0.999, (B, S, di)), dtype)
    b = jnp.asarray(rng.standard_normal((B, S, di)), dtype)
    out = rg_lru(a, b, chunk=chunk, block_d=bd)
    ref = rg_lru_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5 * _tol(dtype), rtol=5 * _tol(dtype))


@pytest.mark.parametrize("N,D,Q,k,bn", [(1000, 32, 5, 10, 64),
                                        (513, 16, 3, 7, 128),
                                        (64, 8, 1, 64, 16),
                                        (5, 8, 2, 9, 64),       # k > N
                                        (1, 4, 2, 3, 64)])      # 1-doc
def test_topk_sim(rng, N, D, Q, k, bn):
    c = jnp.asarray(rng.standard_normal((N, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((Q, D)), jnp.float32)
    s, i = topk_sim(c, q, k, block_n=bn)
    s_ref, i_ref = topk_sim_ref(c, q, min(k, N))
    assert s.shape == (Q, min(k, N))            # k capped at N
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-5,
                               rtol=1e-5)
    assert (np.asarray(i) == np.asarray(i_ref)).all()


def test_topk_sim_empty_corpus_and_queries(rng):
    c = jnp.zeros((0, 8), jnp.float32)
    q = jnp.asarray(rng.standard_normal((3, 8)), jnp.float32)
    s, i = topk_sim(c, q, 5)
    assert s.shape == (3, 0) and i.shape == (3, 0)
    s, i = topk_sim(jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
                    jnp.zeros((0, 8), jnp.float32), 2)
    assert s.shape == (0, 2) and i.shape == (0, 2)


def test_topk_sim_interpret_default_is_backend_aware():
    from repro.kernels.topk_sim.kernel import resolve_interpret
    # explicit settings win; None resolves per backend (the CI host is
    # CPU-only, where no compiled Pallas lowering exists)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    expected = jax.default_backend() == "cpu"
    assert resolve_interpret(None) is expected


@pytest.mark.slow
def test_model_with_pallas_matches_reference(rng):
    """The use_pallas=True model path equals the pure-jnp path end to end."""
    from repro.configs import get_smoke_config
    from repro.configs.specs import make_batch
    from repro.models import model as M
    from repro.models.config import ShapeCell

    for arch in ["olmo-1b", "falcon-mamba-7b", "recurrentgemma-9b"]:
        cfg = get_smoke_config(arch).replace(remat=False)
        batch = make_batch(cfg, ShapeCell("s", 32, 2, "train"))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        ref_logits, _ = M.forward_train(cfg, params, batch)
        pl_logits, _ = M.forward_train(cfg.replace(use_pallas=True), params,
                                       batch)
        # smoke configs run in bf16 — kernel/ref differ by rounding only;
        # accumulated bf16 rounding across layers reaches a few ulp on
        # logits of magnitude ~2, so 6e-2 abs (seed atol=3e-2 flaked at
        # 0.0401 on 5/16384 elements)
        np.testing.assert_allclose(np.asarray(pl_logits, np.float32),
                                   np.asarray(ref_logits, np.float32),
                                   atol=6e-2, rtol=6e-2)
