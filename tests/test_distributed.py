"""Distribution substrate tests that need >1 device: run in a subprocess
with 8 host-platform devices (the 512-device override is dryrun-only).

Covers:
  * elastic re-shard: checkpoint saved under mesh (2,4) restores and keeps
    training under mesh (4,2) with identical loss trajectory;
  * sharded corpus top-k: numerics match the single-device oracle and the
    compiled HLO keeps the corpus sharded (no full all-gather of it).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_mesh
    from repro.models import model as M
    from repro.models import sharding as S
    from repro.training import HParams, adamw_init, make_train_step, opt_specs
    from repro.training.checkpoint import CheckpointManager
    from repro.training.data import DataConfig, SyntheticTokenPipeline

    # granite: rmsnorm everywhere, so the checkpoint tree has no empty
    # subtrees (olmo's non-parametric LN has {} params, which npz drops)
    cfg = get_smoke_config("granite-8b").replace(remat=False,
                                                 shard_multiple=4)
    hp = HParams(lr=1e-3, warmup_steps=1, total_steps=10)
    data = SyntheticTokenPipeline(DataConfig(cfg.vocab_size, 16, 8))

    def build(mesh):
        policy = S.MeshPolicy(mesh, cfg, 8)
        pspecs = S.param_specs(cfg, mesh)
        sds = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
        ospecs = opt_specs(pspecs, sds, mesh)
        bspecs = S.batch_specs(cfg, mesh, 8, "train")
        psh = S.to_shardings(mesh, pspecs)
        osh = S.to_shardings(mesh, ospecs)
        step = jax.jit(make_train_step(cfg, hp, policy),
                       in_shardings=(psh, osh,
                                     S.to_shardings(mesh, bspecs)),
                       out_shardings=(psh, osh, None))
        return step, pspecs, ospecs

    def put(tree, mesh, specs):
        return jax.tree.map(
            lambda a, s: jax.device_put(
                jnp.asarray(a), jax.sharding.NamedSharding(mesh, s)),
            tree, specs, is_leaf=lambda x: not isinstance(x, (dict, list)))

    losses = {}
    # reference: uninterrupted run on mesh A
    mesh_a = make_mesh((2, 4), ("data", "model"))
    step_a, pspecs_a, ospecs_a = build(mesh_a)
    params = put(M.init_params(cfg, jax.random.PRNGKey(0)), mesh_a, pspecs_a)
    opt = put(adamw_init(params), mesh_a, ospecs_a)
    ref = []
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step_a(params, opt, batch)
        ref.append(float(m["loss"]))
    losses["ref"] = ref

    # elastic: 3 steps on mesh A -> checkpoint -> restore on mesh B (4,2)
    params = put(M.init_params(cfg, jax.random.PRNGKey(0)), mesh_a, pspecs_a)
    opt = put(adamw_init(params), mesh_a, ospecs_a)
    for i in range(3):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, m = step_a(params, opt, batch)
    mgr = CheckpointManager("/tmp/elastic_ck", keep=1)
    mgr.save(3, {"params": params, "opt": opt})

    mesh_b = make_mesh((4, 2), ("data", "model"))
    step_b, pspecs_b, ospecs_b = build(mesh_b)
    state = mgr.restore_latest()
    params_b = put(state["params"], mesh_b, pspecs_b)
    opt_b = put(state["opt"], mesh_b, ospecs_b)
    cont = []
    for i in range(3, 6):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params_b, opt_b, m = step_b(params_b, opt_b, batch)
        cont.append(float(m["loss"]))
    losses["elastic"] = cont
    print(json.dumps(losses))
""")

SHARDED_TOPK = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_mesh
    from repro.retrieval.distributed import make_sharded_topk
    from repro.kernels.topk_sim.ref import topk_sim_ref

    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    corpus = jnp.asarray(rng.standard_normal((4096, 32)), jnp.float32)
    queries = jnp.asarray(rng.standard_normal((5, 32)), jnp.float32)
    fn = make_sharded_topk(mesh, k=10)
    lowered = fn.lower(corpus, queries)
    txt = lowered.compile().as_text()
    s, i = fn(corpus, queries)
    s_ref, i_ref = topk_sim_ref(corpus, queries, 10)
    ok_scores = bool(np.allclose(np.asarray(s), np.asarray(s_ref),
                                 atol=1e-5))
    ok_idx = bool((np.asarray(i) == np.asarray(i_ref)).all())
    # the corpus itself must stay sharded: no 4096x32 f32 all-gather
    corpus_gathered = "f32[4096,32]{1,0} all-gather" in txt
    print(json.dumps({"scores": ok_scores, "idx": ok_idx,
                      "corpus_gathered": corpus_gathered}))
""")


def _run(script, timeout=900):
    out = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_elastic_reshard_continues_training():
    losses = _run(ELASTIC)
    import numpy as np
    # continuing on a different mesh reproduces the reference trajectory;
    # 1e-3 rel: the (4,2) mesh reduces in a different order than (2,4), so
    # bf16 matmul accumulation drifts a few e-4 per step (seed rtol=2e-4
    # flaked at 2.5e-4)
    np.testing.assert_allclose(losses["elastic"], losses["ref"][3:],
                               rtol=1e-3, atol=1e-3)


@pytest.mark.slow
def test_sharded_topk_matches_oracle_and_stays_sharded():
    rec = _run(SHARDED_TOPK)
    assert rec["scores"] and rec["idx"]
    assert not rec["corpus_gathered"], "corpus was all-gathered"
