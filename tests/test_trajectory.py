"""Trajectory aggregator tests: BENCH_*.json snapshots fold into a
labelled series, same-label runs replace their entry, and --check
fails exactly on gated-metric regressions beyond the tolerance."""

import json
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "benchmarks"))

import trajectory  # noqa: E402


@pytest.fixture
def bench_dir(tmp_path, monkeypatch):
    monkeypatch.setattr(trajectory, "BENCH_DIR", tmp_path)
    monkeypatch.setattr(trajectory, "TRAJECTORY_PATH",
                        tmp_path / "TRAJECTORY.json")
    return tmp_path


def _write_bench(bench_dir, name, doc):
    (bench_dir / f"BENCH_{name}.json").write_text(json.dumps(doc))


def test_aggregate_flattens_and_labels(bench_dir):
    _write_bench(bench_dir, "speculative",
                 {"speedup": 2.9, "filter_map": {"wall_ratio": 0.47}})
    _write_bench(bench_dir, "ann", {"recall_at_k": 0.96})
    assert trajectory.aggregate("7") == 0
    doc = json.loads((bench_dir / "TRAJECTORY.json").read_text())
    assert [e["label"] for e in doc["series"]] == ["7"]
    benches = doc["series"][0]["benches"]
    assert benches["speculative"]["speedup"] == 2.9
    assert benches["speculative"]["filter_map.wall_ratio"] == 0.47
    assert benches["ann"]["recall_at_k"] == 0.96


def test_same_label_replaces_entry(bench_dir):
    _write_bench(bench_dir, "ann", {"recall_at_k": 0.90})
    trajectory.aggregate("7")
    _write_bench(bench_dir, "ann", {"recall_at_k": 0.96})
    trajectory.aggregate("7")
    doc = json.loads((bench_dir / "TRAJECTORY.json").read_text())
    assert len(doc["series"]) == 1
    assert doc["series"][0]["benches"]["ann"]["recall_at_k"] == 0.96


def test_series_grows_across_labels(bench_dir):
    _write_bench(bench_dir, "ann", {"recall_at_k": 0.90})
    trajectory.aggregate("7")
    _write_bench(bench_dir, "ann", {"recall_at_k": 0.96})
    trajectory.aggregate("8")
    doc = json.loads((bench_dir / "TRAJECTORY.json").read_text())
    assert [e["label"] for e in doc["series"]] == ["7", "8"]


def test_aggregate_without_benches_fails(bench_dir):
    assert trajectory.aggregate("7") == 1


def test_check_passes_within_tolerance(bench_dir):
    _write_bench(bench_dir, "speculative",
                 {"speedup": 2.9, "filter_map": {"wall_ratio": 0.47},
                  "rerank": {"wall_ratio": 0.51}})
    trajectory.aggregate("7")
    # 10% drift in the bad direction stays under the default 25%
    _write_bench(bench_dir, "speculative",
                 {"speedup": 2.7, "filter_map": {"wall_ratio": 0.50},
                  "rerank": {"wall_ratio": 0.55}})
    assert trajectory.check() == 0


def test_check_fails_on_higher_metric_drop(bench_dir):
    _write_bench(bench_dir, "speculative",
                 {"speedup": 2.9, "filter_map": {"wall_ratio": 0.47},
                  "rerank": {"wall_ratio": 0.51}})
    trajectory.aggregate("7")
    _write_bench(bench_dir, "speculative",
                 {"speedup": 1.0, "filter_map": {"wall_ratio": 0.47},
                  "rerank": {"wall_ratio": 0.51}})
    assert trajectory.check() == 1


def test_check_fails_on_lower_metric_growth(bench_dir):
    _write_bench(bench_dir, "speculative",
                 {"speedup": 2.9, "filter_map": {"wall_ratio": 0.47},
                  "rerank": {"wall_ratio": 0.51}})
    trajectory.aggregate("7")
    _write_bench(bench_dir, "speculative",
                 {"speedup": 2.9, "filter_map": {"wall_ratio": 0.90},
                  "rerank": {"wall_ratio": 0.51}})
    assert trajectory.check() == 1


def test_check_tolerance_env_override(bench_dir, monkeypatch):
    _write_bench(bench_dir, "speculative",
                 {"speedup": 2.9, "filter_map": {"wall_ratio": 0.47},
                  "rerank": {"wall_ratio": 0.51}})
    trajectory.aggregate("7")
    _write_bench(bench_dir, "speculative",
                 {"speedup": 2.0, "filter_map": {"wall_ratio": 0.47},
                  "rerank": {"wall_ratio": 0.51}})
    assert trajectory.check() == 1      # 31% drop vs default 25%
    monkeypatch.setenv("BENCH_SPECULATIVE_TOL", "0.5")
    assert trajectory.check() == 0


def test_check_skips_new_bench_and_metric(bench_dir):
    # baseline predates the ann bench and the rerank metric: neither
    # gates until the next aggregate records them
    _write_bench(bench_dir, "speculative", {"speedup": 2.9})
    trajectory.aggregate("7")
    _write_bench(bench_dir, "speculative",
                 {"speedup": 2.9, "filter_map": {"wall_ratio": 0.9},
                  "rerank": {"wall_ratio": 0.9}})
    _write_bench(bench_dir, "ann", {"recall_at_k": 0.1})
    assert trajectory.check() == 0


def test_check_fails_on_vanished_gated_metric(bench_dir):
    _write_bench(bench_dir, "speculative",
                 {"speedup": 2.9, "filter_map": {"wall_ratio": 0.47},
                  "rerank": {"wall_ratio": 0.51}})
    trajectory.aggregate("7")
    _write_bench(bench_dir, "speculative", {"speedup": 2.9})
    assert trajectory.check() == 1


def test_check_without_baseline_is_noop(bench_dir):
    _write_bench(bench_dir, "speculative", {"speedup": 2.9})
    assert trajectory.check() == 0


def test_unreadable_bench_skipped(bench_dir):
    (bench_dir / "BENCH_broken.json").write_text("{not json")
    _write_bench(bench_dir, "ann", {"recall_at_k": 0.96})
    assert trajectory.aggregate("7") == 0
    doc = json.loads((bench_dir / "TRAJECTORY.json").read_text())
    assert set(doc["series"][0]["benches"]) == {"ann"}


def test_corrupt_trajectory_starts_fresh(bench_dir):
    (bench_dir / "TRAJECTORY.json").write_text("][")
    _write_bench(bench_dir, "ann", {"recall_at_k": 0.96})
    assert trajectory.aggregate("7") == 0
    doc = json.loads((bench_dir / "TRAJECTORY.json").read_text())
    assert [e["label"] for e in doc["series"]] == ["7"]


def test_real_trajectory_baseline_is_committed():
    # the CI gate compares against THIS file; an empty or missing
    # baseline silently disables every gate
    path = REPO / "benchmarks" / "TRAJECTORY.json"
    doc = json.loads(path.read_text())
    assert doc["series"], "committed TRAJECTORY.json has no snapshots"
    last = doc["series"][-1]["benches"]
    for bench, metrics in trajectory.GATED_METRICS.items():
        assert bench in last, f"baseline missing bench {bench}"
        for metric_path, _ in metrics:
            assert metric_path in last[bench], \
                f"baseline missing gated metric {bench}.{metric_path}"
