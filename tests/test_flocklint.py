"""flocklint rule tests: each rule fires on a minimal offending
source, respects pragmas, and the real tree under ``src/`` is clean
(the CI lint gate must stay green)."""

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import flocklint  # noqa: E402


def _lint(source, rel="repro/core/scheduler.py"):
    rel = Path(rel)
    return flocklint.lint_source(source, rel, rel)


def _codes(violations):
    return [v.code for v in violations]


# ---------------------------------------------------------------------------
# FLKL101: wall-clock
# ---------------------------------------------------------------------------
def test_time_time_flagged_everywhere():
    src = "import time\nt0 = time.time()\n"
    assert _codes(_lint(src, "repro/launch/serve.py")) == ["FLKL101"]


def test_time_time_as_default_factory_flagged():
    src = ("import time\nfrom dataclasses import field\n"
           "x = field(default_factory=time.time)\n")
    assert _codes(_lint(src, "repro/serving/engine.py")) == ["FLKL101"]


def test_monotonic_not_flagged():
    src = "import time\nt0 = time.monotonic()\n"
    assert _lint(src, "repro/launch/serve.py") == []


def test_pragma_same_line():
    src = ("import time\n"
           "ts = time.time()  # flocklint: ignore[FLKL101]\n")
    assert _lint(src, "repro/core/resources.py") == []


def test_pragma_preceding_line():
    src = ("import time\n"
           "# wall-clock manifest stamp  # flocklint: ignore[FLKL101]\n"
           "ts = time.time()\n")
    assert _lint(src, "repro/core/resources.py") == []


def test_pragma_wrong_code_does_not_suppress():
    src = ("import time\n"
           "ts = time.time()  # flocklint: ignore[FLKL105]\n")
    assert _codes(_lint(src, "repro/core/resources.py")) == ["FLKL101"]


# ---------------------------------------------------------------------------
# FLKL102: blocking call under a scheduler lock
# ---------------------------------------------------------------------------
def test_dispatch_under_lock_flagged():
    src = ("def f(self, pending, rows):\n"
           "    with self._lock:\n"
           "        out = pending.call(rows)\n")
    assert _codes(_lint(src)) == ["FLKL102"]


def test_sleep_under_lock_flagged():
    src = ("import time\n"
           "def f(self):\n"
           "    with self._pack_lock:\n"
           "        time.sleep(0.1)\n")
    assert _codes(_lint(src)) == ["FLKL102"]


def test_dispatch_outside_lock_ok():
    src = ("def f(self, pending, rows):\n"
           "    with self._lock:\n"
           "        self._executing += 1\n"
           "    out = pending.call(rows)\n")
    assert _lint(src) == []


def test_condition_wait_under_lock_ok():
    # Condition.wait releases the lock while blocked — not a violation
    src = ("def f(self):\n"
           "    with self._lock:\n"
           "        self._cond.wait()\n")
    assert _lint(src) == []


def test_nested_function_under_lock_ok():
    # a function DEFINED under a lock does not run under it
    src = ("def f(self, job, batch):\n"
           "    with self._lock:\n"
           "        def later():\n"
           "            return job.run(batch)\n"
           "        self._thunk = later\n")
    assert _lint(src) == []


def test_rule_scoped_to_scheduler():
    src = ("def f(self, pending, rows):\n"
           "    with self._lock:\n"
           "        out = pending.call(rows)\n")
    assert _lint(src, "repro/engine/pipeline.py") == []


# ---------------------------------------------------------------------------
# FLKL103: lock order
# ---------------------------------------------------------------------------
def test_nested_locks_without_declaration_flagged():
    src = ("def f(self, job):\n"
           "    with self._lock:\n"
           "        with job._lock:\n"
           "            job.n += 1\n")
    assert _codes(_lint(src)) == ["FLKL103"]


def test_nested_locks_following_declared_order_ok():
    src = ("# flocklint: lock-order: _lock < job._lock\n"
           "def f(self, job):\n"
           "    with self._lock:\n"
           "        with job._lock:\n"
           "            job.n += 1\n")
    assert _lint(src) == []


def test_nested_locks_violating_declared_order_flagged():
    src = ("# flocklint: lock-order: _lock < job._lock\n"
           "def f(self, job):\n"
           "    with job._lock:\n"
           "        with self._lock:\n"
           "            self.n += 1\n")
    assert _codes(_lint(src)) == ["FLKL103"]


def test_undeclared_lock_in_nesting_flagged():
    src = ("# flocklint: lock-order: _lock < job._lock\n"
           "def f(self, other):\n"
           "    with self._lock:\n"
           "        with other._mystery_lock:\n"
           "            pass\n")
    assert _codes(_lint(src)) == ["FLKL103"]


# ---------------------------------------------------------------------------
# FLKL104: atomic sidecar staging
# ---------------------------------------------------------------------------
def test_with_suffix_tmp_flagged():
    src = 'tmp = path.with_suffix(".tmp")\n'
    assert _codes(_lint(src, "repro/core/cache.py")) == ["FLKL104"]


def test_os_rename_flagged():
    src = "import os\nos.rename(a, b)\n"
    assert _codes(_lint(src, "repro/retrieval/store.py")) == ["FLKL104"]


def test_full_name_tmp_and_replace_ok():
    src = ('tmp = path.with_name(path.name + ".tmp")\n'
           "tmp.replace(path)\n")
    assert _lint(src, "repro/core/cache.py") == []


def test_rule_scoped_to_core_and_retrieval():
    src = 'tmp = path.with_suffix(".tmp")\n'
    assert _lint(src, "repro/launch/dryrun.py") == []


# ---------------------------------------------------------------------------
# FLKL105: broad except
# ---------------------------------------------------------------------------
def test_bare_except_flagged():
    src = "try:\n    f()\nexcept:\n    pass\n"
    assert _codes(_lint(src, "repro/core/cache.py")) == ["FLKL105"]


def test_broad_exception_flagged():
    src = "try:\n    f()\nexcept Exception:\n    pass\n"
    assert _codes(_lint(src, "repro/engine/pipeline.py")) == ["FLKL105"]


def test_base_exception_in_tuple_flagged():
    src = "try:\n    f()\nexcept (ValueError, BaseException):\n    pass\n"
    assert _codes(_lint(src, "repro/retrieval/vector.py")) == ["FLKL105"]


def test_narrow_except_ok():
    src = ("try:\n    f()\nexcept (ImportError, AttributeError):\n"
           "    pass\n")
    assert _lint(src, "repro/core/cache.py") == []


def test_broad_except_with_pragma_ok():
    src = ("try:\n    f()\n"
           "# re-raised on the caller  # flocklint: ignore[FLKL105]\n"
           "except BaseException as exc:\n    raise\n")
    assert _lint(src, "repro/core/scheduler.py") == []


def test_broad_except_outside_scope_ok():
    src = "try:\n    f()\nexcept Exception:\n    pass\n"
    assert _lint(src, "repro/launch/dryrun.py") == []


# ---------------------------------------------------------------------------
# FLKL106: ad-hoc thread spawning
# ---------------------------------------------------------------------------
def test_thread_in_engine_flagged():
    src = ("import threading\n"
           "th = threading.Thread(target=f)\n")
    assert _codes(_lint(src, "repro/engine/retrieval_ops.py")) \
        == ["FLKL106"]


def test_thread_in_core_flagged():
    src = ("import threading\n"
           "th = threading.Thread(target=f)\n")
    assert _codes(_lint(src, "repro/core/functions.py")) == ["FLKL106"]


def test_thread_in_scheduler_ok():
    # core/scheduler.py IS the sanctioned home for thread spawning
    src = ("import threading\n"
           "th = threading.Thread(target=worker)\n")
    assert _lint(src, "repro/core/scheduler.py") == []


def test_thread_outside_scope_ok():
    src = ("import threading\n"
           "th = threading.Thread(target=f)\n")
    assert _lint(src, "repro/launch/serve.py") == []
    assert _lint(src, "repro/retrieval/vector.py") == []


def test_thread_with_pragma_ok():
    src = ("import threading\n"
           "# joined below  # flocklint: ignore[FLKL106]\n"
           "th = threading.Thread(target=f)\n")
    assert _lint(src, "repro/engine/pipeline.py") == []


def test_non_thread_threading_calls_ok():
    src = ("import threading\n"
           "lock = threading.Lock()\n"
           "cond = threading.Condition()\n"
           "ev = threading.Event()\n")
    assert _lint(src, "repro/engine/pipeline.py") == []


# ---------------------------------------------------------------------------
# the real tree is clean — this is the CI gate
# ---------------------------------------------------------------------------
def test_src_tree_has_zero_violations():
    violations = []
    for path in sorted((REPO / "src").rglob("*.py")):
        rel = flocklint._rel_to_package(path)
        violations.extend(
            flocklint.lint_source(path.read_text(encoding="utf-8"),
                                  path, rel))
    assert violations == [], "\n".join(str(v) for v in violations)


def test_cli_exit_codes(tmp_path):
    clean = tmp_path / "clean.py"
    clean.write_text("import time\nt = time.monotonic()\n")
    dirty = tmp_path / "dirty.py"
    dirty.write_text("import time\nt = time.time()\n")
    assert flocklint.main([str(clean)]) == 0
    assert flocklint.main([str(dirty)]) == 1
