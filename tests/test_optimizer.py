"""Plan-optimizer tests: golden plans, naive/optimized equivalence, and
edge cases for the parsing + batching helpers the optimizer relies on.

The equivalence tests use a content-based MockProvider behaviour so the
same tuple gets the same answer regardless of which request (single-task
or fused multi-task) carries it — that is exactly the determinism a real
provider gives a temperature-0 prompt, and it lets us assert optimized
plans return identical rows while issuing strictly fewer requests.
"""

import json
import re

import pytest

from repro.core import (MockProvider, SemanticContext, execute_serial,
                        llm_multi, plan_batches, reset_global_catalog)
from repro.core.batching import ContextOverflowError
from repro.core.functions import _parse_permutation, _parse_rows
from repro.engine import Pipeline, Table, optimize_plan

_ROW_CONTENT = re.compile(r"<text>(.*?)</text>")
_TASK = re.compile(r"\bt(\d+) \[(filter|complete|complete_json)\]")


def _content(row: str) -> str:
    m = _ROW_CONTENT.search(row)
    return m.group(1) if m else row


def _semantic_behaviour(kind, prefix, rows):
    """Deterministic content-based answers: filter=true iff 'join' in the
    text column; complete echoes the text; complete_json wraps it."""
    def one(kind, text):
        if kind == "filter":
            return "true" if "join" in text else "false"
        if kind == "complete_json":
            return json.dumps({"topic": text.split()[0] if text else ""})
        return f"summary({text})"

    if kind == "multi":
        tasks = _TASK.findall(prefix)
        out = []
        for i, r in enumerate(rows):
            text = _content(r)
            obj = {}
            for tag, tkind in tasks:
                v = one(tkind, text)
                obj[f"t{tag}"] = (v == "true" if tkind == "filter"
                                  else json.loads(v)
                                  if tkind == "complete_json" else v)
            out.append(f"{i}: {json.dumps(obj)}")
        return out
    if kind in ("filter", "complete", "complete_json"):
        return [f"{i}: {one(kind, _content(r))}"
                for i, r in enumerate(rows)]
    return None


def _ctx(**kw):
    reset_global_catalog()
    return SemanticContext(provider=MockProvider(_semantic_behaviour), **kw)


@pytest.fixture
def table():
    rows = 12
    return Table({
        "id": list(range(rows)),
        "text": [f"paper {i} about {'join' if i % 3 == 0 else 'index'} "
                 f"structures" for i in range(rows)],
        "year": [2000 + i for i in range(rows)],
    })


MODEL = {"model": "m", "context_window": 4096, "max_output_tokens": 8}


def _ops(pipe):
    return [n.op for n in pipe._plan().nodes]


# ---------------------------------------------------------------------------
# golden-plan regressions: the rewrite decisions are locked in
# ---------------------------------------------------------------------------
def test_golden_pushdown_limit_and_order_by(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_complete("summary", MODEL, {"prompt": "summarize"},
                          ["text"])
            .order_by("year", desc=True)
            .limit(3))
    assert _ops(pipe) == ["scan", "order_by", "limit", "llm_complete"]
    plan = pipe.explain()
    assert plan.splitlines()[0] == "Pipeline plan (as written):"
    assert "Rewrites applied:" in plan
    assert "pushdown(order_by before llm_complete)" in plan
    assert "pushdown(limit before llm_complete)" in plan
    # the limit cut the estimated LLM exposure from 12 rows to 3
    opt = pipe._plan()
    assert opt.naive_cost.rows_into_llm == 12
    assert opt.optimized_cost.rows_into_llm == 3
    assert opt.optimized_cost.tokens < opt.naive_cost.tokens


def test_golden_fusion_filter_complete_json(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_filter(MODEL, {"prompt": "about joins?"}, ["text"])
            .llm_complete("summary", MODEL, {"prompt": "summarize"},
                          ["text"])
            .llm_complete_json("meta", MODEL, {"prompt": "extract topic"},
                               ["text"]))
    assert _ops(pipe) == ["scan", "llm_fused"]
    plan = pipe.explain()
    assert "fusion(llm_filter+llm_complete+llm_complete_json)" in plan
    fused = pipe._plan().nodes[1]
    assert fused.info["kinds"] == ["filter", "complete", "complete_json"]
    assert fused.info["outs"] == ["summary", "meta"]
    # 3 single-op passes -> 1 fused pass
    opt = pipe._plan()
    assert opt.optimized_cost.requests < opt.naive_cost.requests


def test_golden_filter_chain_reorder(table):
    ctx = _ctx()
    # record pass rates: 'rare' keeps 10%, 'common' keeps 90% — with equal
    # token costs the optimizer must run 'rare' first
    ctx.record_selectivity("inline:rare?", 1, 10)
    ctx.record_selectivity("inline:common?", 9, 10)
    m2 = {"model": "m2", "context_window": 4096, "max_output_tokens": 8}
    pipe = (Pipeline(ctx, table, "papers")
            .llm_filter(MODEL, {"prompt": "common?"}, ["text"])
            .llm_filter(m2, {"prompt": "rare?"}, ["text"]))
    nodes = pipe._plan().nodes
    assert [n.info["prompt"]["prompt"] for n in nodes[1:]] == \
        ["rare?", "common?"]
    plan = pipe.explain()
    assert "reorder_filters(chain of 2 by cost per eliminated tuple)" in \
        plan
    assert "rejected(" not in plan


def test_golden_explain_shows_both_plans_with_estimates(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_complete("summary", MODEL, {"prompt": "summarize"},
                          ["text"])
            .limit(2))
    plan = pipe.explain()
    lines = plan.splitlines()
    assert lines[0] == "Pipeline plan (as written):"
    assert "Optimized plan:" in lines
    assert sum(l.startswith("  estimated: requests=") for l in lines) == 2
    assert any("est[rows->" in l and "req=" in l and "tok=" in l
               for l in lines)


# ---------------------------------------------------------------------------
# safety: rewrites that must NOT fire
# ---------------------------------------------------------------------------
def test_opaque_relational_filter_not_pushed_past_map(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_complete("summary", MODEL, {"prompt": "summarize"},
                          ["text"])
            .filter(lambda r: "join" in r["summary"]))   # reads the output!
    assert _ops(pipe) == ["scan", "llm_complete", "filter"]


def test_declared_filter_on_output_column_not_pushed(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_complete("summary", MODEL, {"prompt": "summarize"},
                          ["text"])
            .filter(lambda r: "join" in r["summary"], cols=["summary"]))
    assert _ops(pipe) == ["scan", "llm_complete", "filter"]


def test_limit_not_pushed_past_llm_filter(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_filter(MODEL, {"prompt": "about joins?"}, ["text"])
            .limit(2))
    assert _ops(pipe) == ["scan", "llm_filter", "limit"]


def test_no_fusion_across_models_or_columns(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_filter(MODEL, {"prompt": "about joins?"}, ["text"])
            .llm_complete("summary", {"model": "other"},
                          {"prompt": "summarize"}, ["text"])
            .llm_complete_json("meta", {"model": "other"},
                               {"prompt": "extract"}, ["text", "year"]))
    assert _ops(pipe) == ["scan", "llm_filter", "llm_complete",
                          "llm_complete_json"]


def test_no_fusion_when_inline_model_limits_differ(table):
    # same model name, but the completion needs a bigger output budget —
    # fusing would run it under the filter's limits
    ctx = _ctx()
    small = {"model": "m", "context_window": 512, "max_output_tokens": 8}
    big = {"model": "m", "context_window": 8192, "max_output_tokens": 256}
    pipe = (Pipeline(ctx, table, "papers")
            .llm_filter(small, {"prompt": "about joins?"}, ["text"])
            .llm_complete("summary", big, {"prompt": "summarize"},
                          ["text"]))
    assert _ops(pipe) == ["scan", "llm_filter", "llm_complete"]


def test_fusion_rejected_when_filter_is_highly_selective(table):
    # a 1%-selective filter means the naive plan completes ~0 rows; the
    # fused pass would complete all of them — the cost gate must refuse
    ctx = _ctx()
    ctx.record_selectivity("inline:almost nothing?", 1, 100)
    pipe = (Pipeline(ctx, table, "papers")
            .llm_filter(MODEL, {"prompt": "almost nothing?"}, ["text"])
            .llm_complete("summary", MODEL, {"prompt": "summarize"},
                          ["text"]))
    assert _ops(pipe) == ["scan", "llm_filter", "llm_complete"]
    assert any(rw.startswith("rejected(fusion")
               for rw in pipe._plan().rewrites)


def test_filter_reorder_keeps_already_optimal_chain(table):
    # cheap+selective filter already first: the plan must not get worse,
    # either by the rank metric or after the cost gate
    ctx = _ctx()
    ctx.record_selectivity("inline:cheap?", 2, 10)
    ctx.record_selectivity("inline:pricey?", 1, 10)
    wide = {"model": "m2", "context_window": 4096, "max_output_tokens": 8}
    pipe = (Pipeline(ctx, table, "papers")
            .llm_filter(MODEL, {"prompt": "cheap?"}, ["text"])
            .llm_filter(wide, {"prompt": "pricey?" + "x" * 2000},
                        ["text", "year"]))
    opt = pipe._plan()
    applied = [rw for rw in opt.rewrites if not rw.startswith("rejected")]
    assert ([n.info["prompt"]["prompt"] for n in opt.nodes[1:]][0]
            == "cheap?") or not applied
    from repro.engine.optimizer import _cost_rank
    assert _cost_rank(opt.optimized_cost) <= _cost_rank(opt.naive_cost)


def test_callable_order_by_key_not_pushed(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_complete("summary", MODEL, {"prompt": "summarize"},
                          ["text"])
            .order_by(lambda r: r["year"]))
    assert _ops(pipe) == ["scan", "llm_complete", "order_by"]


# ---------------------------------------------------------------------------
# equivalence: identical rows, strictly fewer requests
# ---------------------------------------------------------------------------
def _rows_of(t: Table):
    return t.rows()


def _run_both(make_pipe):
    """Execute the same logical plan naive and optimized on fresh
    contexts; returns (naive_rows, opt_rows, naive_requests,
    opt_requests)."""
    ctx_n = _ctx(enable_cache=False)
    out_n = make_pipe(ctx_n).collect(optimize=False)
    ctx_o = _ctx(enable_cache=False)
    out_o = make_pipe(ctx_o).collect()
    return (_rows_of(out_n), _rows_of(out_o),
            ctx_n.provider.stats.calls, ctx_o.provider.stats.calls)


def test_equivalence_pushdown(table):
    def make(ctx):
        return (Pipeline(ctx, table, "papers")
                .filter(lambda r: r["year"] < 2010, cols=["year"])
                .llm_complete("summary", MODEL, {"prompt": "summarize"},
                              ["text"])
                .order_by("year")
                .limit(4))
    rows_n, rows_o, req_n, req_o = _run_both(make)
    assert rows_n == rows_o
    assert req_o <= req_n


def test_equivalence_fusion_identical_rows_fewer_requests(table):
    def make(ctx):
        return (Pipeline(ctx, table, "papers")
                .llm_filter(MODEL, {"prompt": "about joins?"}, ["text"])
                .llm_complete("summary", MODEL, {"prompt": "summarize"},
                              ["text"])
                .llm_complete_json("meta", MODEL,
                                   {"prompt": "extract topic"}, ["text"]))
    rows_n, rows_o, req_n, req_o = _run_both(make)
    assert rows_n == rows_o
    assert req_o < req_n            # strictly fewer provider requests


def test_equivalence_filter_reorder(table):
    def make(ctx):
        ctx.record_selectivity("inline:about joins?", 1, 3)
        return (Pipeline(ctx, table, "papers")
                .llm_filter(MODEL, {"prompt": "text present?"}, ["text"])
                .llm_filter({"model": "m2", "context_window": 4096},
                            {"prompt": "about joins?"}, ["text"]))
    rows_n, rows_o, req_n, req_o = _run_both(make)
    assert sorted(r["id"] for r in rows_n) == \
        sorted(r["id"] for r in rows_o)
    assert req_o <= req_n


def test_escape_hatch_runs_plan_as_written(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_complete("summary", MODEL, {"prompt": "summarize"},
                          ["text"])
            .limit(3))
    pipe.collect(optimize=False)
    assert [n.op for n in pipe._executed_nodes] == \
        ["scan", "llm_complete", "limit"]
    pipe.collect()
    assert [n.op for n in pipe._executed_nodes] == \
        ["scan", "limit", "llm_complete"]


# ---------------------------------------------------------------------------
# llm_multi unit behaviour
# ---------------------------------------------------------------------------
def test_llm_multi_decodes_every_kind(table):
    ctx = _ctx()
    tuples = [{"text": t} for t in table.column("text")[:4]]
    flt, summ, meta = llm_multi(
        ctx, MODEL,
        [{"kind": "filter", "prompt": {"prompt": "about joins?"}},
         {"kind": "complete", "prompt": {"prompt": "summarize"}},
         {"kind": "complete_json", "prompt": {"prompt": "topic"}}],
        tuples)
    assert [isinstance(b, bool) for b in flt] == [True] * 4
    assert all(isinstance(s, str) for s in summ)
    assert all(isinstance(m, dict) for m in meta)
    assert ctx.reports[-1].function == "multi"
    assert ctx.reports[-1].requests == 1


def test_llm_multi_rejects_unfusable_kind():
    ctx = _ctx()
    with pytest.raises(ValueError):
        llm_multi(ctx, MODEL,
                  [{"kind": "rerank", "prompt": {"prompt": "x"}}],
                  [{"text": "a"}])


def test_llm_multi_records_filter_selectivity(table):
    ctx = _ctx()
    tuples = [{"text": t} for t in table.column("text")]
    llm_multi(ctx, MODEL,
              [{"kind": "filter", "prompt": {"prompt": "about joins?"}}],
              tuples)
    # 'join' appears in every third row of the fixture
    assert ctx.expected_selectivity("inline:about joins?") == \
        pytest.approx(4 / 12)


# ---------------------------------------------------------------------------
# edge cases: _parse_rows / _parse_permutation / plan_batches
# ---------------------------------------------------------------------------
def test_parse_rows_empty_and_malformed():
    assert _parse_rows([], 0) == []
    assert _parse_rows([], 3) == [None, None, None]
    assert _parse_rows(["garbage", ":", "x: y"], 2) == [None, None]


def test_parse_rows_out_of_range_and_whitespace():
    out = _parse_rows(["0:  hello ", "7: ignored", "1:world"], 2)
    assert out == ["hello", "world"]


def test_parse_rows_last_assignment_wins():
    assert _parse_rows(["0: a", "0: b"], 1) == ["b"]


def test_parse_permutation_garbage_and_duplicates():
    assert _parse_permutation("", 3) == [0, 1, 2]
    assert _parse_permutation("no digits here", 2) == [0, 1]
    assert _parse_permutation("2, 2, 0", 3) == [2, 0, 1]
    assert _parse_permutation("9, 1", 3) == [1, 0, 2]


def test_plan_batches_empty_input():
    plan = plan_batches([], prefix_tokens=10, context_window=100,
                        max_output_tokens=4)
    assert plan.batches == [] and plan.est_tokens == []


def test_plan_batches_max_batch_one():
    plan = plan_batches([5, 5, 5], prefix_tokens=0, context_window=1000,
                        max_output_tokens=2, max_batch=1)
    assert plan.batches == [[0], [1], [2]]


def test_plan_batches_oversized_singleton_isolated():
    # a tuple bigger than the budget still gets its own batch (the
    # adaptive runner turns it into NULL at execution time)
    plan = plan_batches([500, 5], prefix_tokens=10, context_window=100,
                        max_output_tokens=4)
    assert plan.batches[0] == [0]
    assert all(i in [j for b in plan.batches for j in b] for i in (0, 1))


def test_execute_serial_overflow_shrink_path():
    calls = []

    def call(batch):
        calls.append(list(batch))
        if len(batch) > 2:
            raise ContextOverflowError("too big")
        return [f"v{i}" for i in batch]

    results, stats = execute_serial(list(range(10)), [1] * 10,
                                    prefix_tokens=0, context_window=10_000,
                                    max_output_tokens=1, call=call)
    assert results == [f"v{i}" for i in range(10)]
    assert stats.retries > 0 and stats.nulls == 0
    assert all(len(b) <= 2 for b in calls[-stats.requests:])
    # successful requests record their wall latency (calibration feed)
    assert len(stats.latencies) == stats.requests


def test_execute_serial_single_tuple_overflow_is_null():
    def call(batch):
        raise ContextOverflowError("always")

    results, stats = execute_serial([0], [1], prefix_tokens=0,
                                    context_window=10, max_output_tokens=1,
                                    call=call)
    assert results == [None]
    assert stats.nulls == 1


def test_run_adaptive_alias_removed():
    # the PR 3 deprecation ran its course: the compat alias is gone and
    # the executor lives only in scheduler.execute_serial
    from repro.core import batching
    assert not hasattr(batching, "run_adaptive")
    results, stats = execute_serial([0, 1], [1, 1], prefix_tokens=0,
                                    context_window=10_000,
                                    max_output_tokens=1,
                                    call=lambda b: [f"v{i}" for i in b])
    assert results == ["v0", "v1"]
    assert stats.requests == 1
