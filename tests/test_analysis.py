"""Static plan analyzer tests (engine/analysis.py).

Covers the three analyzer layers:

  * golden diagnostic-code tests — one per FLK rule, asserting the
    stable code, severity, and that ``Pipeline.check()`` raises (or
    not) accordingly;
  * the zero-provider-request guarantee — an invalid plan is rejected
    by ``check()`` / ``collect(verify="strict")`` before ANY provider
    call;
  * rewrite-soundness obligations — ``collect(verify="strict")``
    discharges every obligation the optimizer emits across a
    representative plan corpus (pushdown, fusion, filter reorder,
    corpus pruning, ann_select, embed dedupe, speculative chains), and
    a tampered plan is caught as FLK010.
"""

import json
import re

import pytest

from repro.core import (MockProvider, SemanticContext,
                        reset_global_catalog)
from repro.engine import (Pipeline, PlanValidationError, Table,
                          analyze_plan, infer_schema, verify_rewrites)

MODEL = {"model": "m", "context_window": 4096, "max_output_tokens": 8}
EMB = {"model": "e", "embedding_dim": 16, "context_window": 4096}

_ROW_CONTENT = re.compile(r"<text>(.*?)</text>")
_TASK = re.compile(r"\bt(\d+) \[(filter|complete|complete_json)\]")


def _content(row):
    m = _ROW_CONTENT.search(row)
    return m.group(1) if m else row


def _behaviour(kind, prefix, rows):
    """Content-based deterministic answers (same contract as the
    optimizer equivalence tests): identical tuples get identical
    answers whatever request carries them."""
    def one(kind, text):
        if kind == "filter":
            return "true" if "join" in text else "false"
        if kind == "complete_json":
            return json.dumps({"topic": text.split()[0] if text else ""})
        return f"summary({text})"

    if kind == "multi":
        tasks = _TASK.findall(prefix)
        out = []
        for i, r in enumerate(rows):
            text = _content(r)
            obj = {}
            for tag, tkind in tasks:
                v = one(tkind, text)
                obj[f"t{tag}"] = (v == "true" if tkind == "filter"
                                  else json.loads(v)
                                  if tkind == "complete_json" else v)
            out.append(f"{i}: {json.dumps(obj)}")
        return out
    if kind in ("filter", "complete", "complete_json"):
        return [f"{i}: {one(kind, _content(r))}"
                for i, r in enumerate(rows)]
    return None


def _ctx(**kw):
    reset_global_catalog()
    return SemanticContext(provider=MockProvider(_behaviour), **kw)


def _calls(ctx):
    return ctx.provider.stats.snapshot()["calls"]


@pytest.fixture
def table():
    rows = 12
    return Table({
        "id": list(range(rows)),
        "text": [f"paper {i} about {'join' if i % 3 == 0 else 'index'} "
                 f"structures" for i in range(rows)],
        "year": [2000 + i for i in range(rows)],
    })


def _corpus(n=48):
    topics = ("joins", "indexes", "vectors")
    return Table({
        "content": [f"doc {i} about {topics[i % 3]} with a body of "
                    f"searchable text" for i in range(n)],
        "year": [2000 + i % 6 for i in range(n)],
    })


def _queries():
    return Table({"q": ["join algorithms", "vector search"],
                  "qid": [0, 1]})


def _codes(exc_or_diags):
    diags = getattr(exc_or_diags, "diagnostics", exc_or_diags)
    return [d.code for d in diags]


# ---------------------------------------------------------------------------
# golden diagnostic codes
# ---------------------------------------------------------------------------
def test_flk001_unresolved_model_ref(table):
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").llm_complete(
        "s", {"model_name": "ghost"}, {"prompt": "p"}, ["text"])
    with pytest.raises(PlanValidationError) as ei:
        pipe.check()
    assert _codes(ei.value) == ["FLK001"]
    assert "ghost" in str(ei.value)
    assert _calls(ctx) == 0


def test_registered_model_ref_resolves(table):
    ctx = _ctx()
    ctx.catalog.create_model("prod", arch="mock", context_window=4096,
                             max_output_tokens=8)
    pipe = Pipeline(ctx, table, "t").llm_complete(
        "s", {"model_name": "prod"}, {"prompt": "p"}, ["text"])
    assert pipe.check() == []


def test_flk002_unresolved_prompt_ref(table):
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").llm_complete(
        "s", MODEL, {"prompt_name": "ghost"}, ["text"])
    with pytest.raises(PlanValidationError) as ei:
        pipe.check()
    assert _codes(ei.value) == ["FLK002"]


def test_flk003_placeholder_without_column(table):
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").llm_complete(
        "s", MODEL, {"prompt": "summarize {body}"}, ["text"])
    with pytest.raises(PlanValidationError) as ei:
        pipe.check()
    assert _codes(ei.value) == ["FLK003"]
    assert "{body}" in str(ei.value)


def test_flk003_placeholder_bound_and_json_braces_exempt(table):
    ctx = _ctx()
    # {text} binds to a visible input column; JSON-shaped braces and
    # {{escaped}} braces are not placeholders
    pipe = Pipeline(ctx, table, "t").llm_complete(
        "s", MODEL,
        {"prompt": 'from {text} emit {"k": 1} and {{literal}}'},
        ["text"])
    assert pipe.check() == []


def test_flk003_catalog_prompt_placeholders_checked(table):
    ctx = _ctx()
    ctx.catalog.create_prompt("summarize", "condense {body}")
    pipe = Pipeline(ctx, table, "t").llm_complete(
        "s", MODEL, {"prompt_name": "summarize"}, ["text"])
    with pytest.raises(PlanValidationError) as ei:
        pipe.check()
    assert _codes(ei.value) == ["FLK003"]


def test_flk004_missing_input_column(table):
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").llm_complete(
        "s", MODEL, {"prompt": "p"}, ["text", "abstract"])
    with pytest.raises(PlanValidationError) as ei:
        pipe.check()
    assert _codes(ei.value) == ["FLK004"]
    assert "abstract" in str(ei.value)


def test_flk004_column_created_upstream_is_visible(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "t")
            .llm_complete("summary", MODEL, {"prompt": "p"}, ["text"])
            .llm_complete("meta", MODEL, {"prompt": "q"}, ["summary"]))
    assert pipe.check() == []


def test_flk005_bad_k(table):
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").vector_topk(
        "score", EMB, "text", _corpus(8), k=0, doc_col="content")
    with pytest.raises(PlanValidationError) as ei:
        pipe.check()
    assert _codes(ei.value) == ["FLK005"]


def test_flk005_bad_fusion(table):
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").hybrid_topk(
        "score", EMB, "text", _corpus(8), k=2, fusion="nope",
        doc_col="content")
    with pytest.raises(PlanValidationError) as ei:
        pipe.check()
    assert _codes(ei.value) == ["FLK005"]


def test_flk005_model_spec_type(table):
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").llm_complete(
        "s", "not-a-dict", {"prompt": "p"}, ["text"])
    with pytest.raises(PlanValidationError) as ei:
        pipe.check()
    assert _codes(ei.value) == ["FLK005"]


def test_flk005_nprobe_above_nlist_is_warning_only(table):
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").vector_topk(
        "score", EMB, "text", _corpus(8), k=2, doc_col="content",
        ann="ivf", nprobe=64, nlist=8)
    diags = pipe.check()          # strict: warnings do not raise
    assert _codes(diags) == ["FLK005"]
    assert diags[0].severity == "warning"


def test_flk006_retrieval_column_collision_matches_runtime():
    # parent already holds BOTH the doc column and its _doc rename —
    # the analyzer must flag statically what Table.lateral raises at
    # execution time
    ctx = _ctx()
    parent = Table({"q": ["join"], "content": ["x"],
                    "content_doc": ["y"]})
    pipe = Pipeline(ctx, parent, "t").vector_topk(
        "score", EMB, "q", _corpus(8), k=2, doc_col="content")
    with pytest.raises(PlanValidationError) as ei:
        pipe.check()
    assert "FLK006" in _codes(ei.value)
    with pytest.raises(ValueError):
        pipe.collect(optimize=False)


def test_retrieval_doc_rename_inferred():
    # single collision: corpus 'content' arrives as 'content_doc'
    ctx = _ctx()
    parent = Table({"q": ["join"], "content": ["mine"]})
    pipe = Pipeline(ctx, parent, "t").vector_topk(
        "score", EMB, "q", _corpus(8), k=2, doc_col="content")
    assert pipe.check() == []
    schemas = infer_schema(parent, pipe.nodes)
    out = schemas[-1]
    for col in ("q", "content", "content_doc", "score", "score_rank"):
        assert col in out
    got = pipe.collect(optimize=False)
    assert set(out.names) == set(got.column_names)


def test_inferred_schema_matches_execution_across_ops(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "t")
            .llm_filter(MODEL, {"prompt": "about joins?"}, ["text"])
            .llm_complete("summary", MODEL, {"prompt": "sum"}, ["text"])
            .llm_complete_json("meta", MODEL, {"prompt": "ex"}, ["text"])
            .order_by("year", desc=True)
            .limit(4))
    schemas = infer_schema(table, pipe.nodes)
    got = pipe.collect(optimize=False)
    assert list(schemas[-1].names) == got.column_names


def test_explain_renders_inferred_schema(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "t")
            .llm_complete("summary", MODEL, {"prompt": "sum"}, ["text"])
            .limit(2))
    text = pipe.explain()
    assert "Inferred schema (optimized plan):" in text
    assert "summary:str" in text


# ---------------------------------------------------------------------------
# zero provider requests on rejection
# ---------------------------------------------------------------------------
def test_invalid_plan_rejected_with_zero_provider_requests(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "t")
            .llm_filter(MODEL, {"prompt": "keep {missing}?"}, ["text"])
            .llm_complete("s", {"model_name": "ghost"},
                          {"prompt": "p"}, ["text"]))
    with pytest.raises(PlanValidationError) as ei:
        pipe.collect(verify="strict")
    assert set(_codes(ei.value)) == {"FLK003", "FLK001"}
    assert _calls(ctx) == 0
    with pytest.raises(PlanValidationError):
        pipe.check()
    assert _calls(ctx) == 0


def test_verify_warn_reports_and_proceeds(table):
    # prompts are free text (no substitution engine), so a dangling
    # placeholder is survivable: warn mode must flag it AND execute
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").llm_complete(
        "s", MODEL, {"prompt": "sum {missing}"}, ["text"])
    with pytest.warns(UserWarning, match="FLK003"):
        out = pipe.collect(verify="warn")
    assert len(out) == len(table)
    assert _calls(ctx) > 0


def test_verify_off_skips_analysis(table):
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").llm_complete(
        "s", MODEL, {"prompt": "sum {missing}"}, ["text"])
    out = pipe.collect()          # default verify="off": no rejection
    assert len(out) == len(table)


def test_bad_verify_value(table):
    ctx = _ctx()
    pipe = Pipeline(ctx, table, "t").limit(2)
    with pytest.raises(ValueError, match="verify"):
        pipe.collect(verify="paranoid")


# ---------------------------------------------------------------------------
# rewrite-soundness obligations, discharged in strict mode
# ---------------------------------------------------------------------------
def _strict_equals_naive(pipe_fn, expect_rule=None, **collect_kw):
    """Build the pipeline twice (fresh contexts), run naive and
    strict-verified optimized execution, and require identical rows
    plus (optionally) a specific rewrite to have fired."""
    naive = pipe_fn(_ctx()).collect(optimize=False)
    pipe = pipe_fn(_ctx())
    out = pipe.collect(verify="strict", **collect_kw)
    assert out.rows() == naive.rows()
    opt = pipe._plan(*([collect_kw["speculate"]]
                       if "speculate" in collect_kw else []))
    if expect_rule is not None:
        assert any(rw.startswith(expect_rule) for rw in opt.rewrites), \
            opt.rewrites
    assert opt.obligations, "optimizer emitted no obligations"
    return pipe, opt


def test_strict_discharges_pushdown(table):
    def build(ctx):
        return (Pipeline(ctx, table, "papers")
                .llm_complete("summary", MODEL, {"prompt": "summarize"},
                              ["text"])
                .order_by("year", desc=True)
                .limit(3))
    _strict_equals_naive(build, expect_rule="pushdown")


def test_strict_discharges_fusion(table):
    def build(ctx):
        return (Pipeline(ctx, table, "papers")
                .llm_filter(MODEL, {"prompt": "about joins?"}, ["text"])
                .llm_complete("summary", MODEL, {"prompt": "summarize"},
                              ["text"])
                .llm_complete_json("meta", MODEL,
                                   {"prompt": "extract topic"}, ["text"]))
    _strict_equals_naive(build, expect_rule="fusion")


def test_strict_discharges_filter_reorder(table):
    m2 = {"model": "m2", "context_window": 4096, "max_output_tokens": 8}

    def build(ctx):
        ctx.record_selectivity("inline:rare?", 1, 10)
        ctx.record_selectivity("inline:common?", 9, 10)
        return (Pipeline(ctx, table, "papers")
                .llm_filter(MODEL, {"prompt": "common?"}, ["text"])
                .llm_filter(m2, {"prompt": "rare?"}, ["text"]))
    _strict_equals_naive(build, expect_rule="reorder_filters")


def test_strict_discharges_prune_corpus():
    corpus = _corpus(60)
    flt = lambda r: r["year"] >= 2003

    def build(ctx):
        return (Pipeline(ctx, _queries(), "queries")
                .hybrid_topk("score", EMB, "q", corpus, k=5,
                             doc_col="content", candidate_k=10,
                             corpus_filter=flt,
                             corpus_filter_cols=["year"]))
    _strict_equals_naive(build, expect_rule="prune_corpus")


def test_strict_discharges_k_pushdown():
    # k_pushdown bounds the fused candidate lists (recall contract:
    # candidate_k >= k), which may legitimately perturb deep-rank
    # fusion scores — so strict mode must discharge the contract, not
    # assert bit-equality with the unbounded naive run
    corpus = _corpus(60)
    ctx = _ctx()
    pipe = (Pipeline(ctx, _queries(), "queries")
            .hybrid_topk("score", EMB, "q", corpus, k=3,
                         doc_col="content"))
    out = pipe.collect(verify="strict")
    assert len(out) == 2 * 3
    opt = pipe._plan()
    assert any(rw.startswith("k_pushdown") for rw in opt.rewrites)
    assert any(ob.kind == "recall_contract" for ob in opt.obligations)


def test_strict_discharges_forced_ivf():
    from repro.retrieval.ivf import default_nlist
    corpus = _corpus(120)
    nl = default_nlist(120)

    def build(ctx):
        # full probing: IVF is bit-identical to the exact scan, so the
        # naive/optimized row comparison stays exact
        return (Pipeline(ctx, _queries(), "queries")
                .vector_topk("score", EMB, "q", corpus, k=5,
                             doc_col="content", ann="ivf",
                             nlist=nl, nprobe=nl))
    _strict_equals_naive(build, expect_rule="ann_select")


def test_strict_discharges_ann_auto_without_execution():
    # big-corpus auto selection: discharge on the plan alone (the 2000
    # -row embed is not worth paying in the fast tier)
    ctx = _ctx()
    corpus = Table({"content": [f"passage {i} about topic {i % 9}"
                                for i in range(2000)]})
    pipe = (Pipeline(ctx, _queries(), "queries")
            .vector_topk("score", EMB, "q", corpus, k=5,
                         doc_col="content", ann="auto"))
    opt = pipe._plan()
    assert any(rw.startswith("ann_select") for rw in opt.rewrites)
    assert verify_rewrites(ctx, _queries(), pipe.nodes, opt) == []


def test_strict_discharges_shared_corpus_embed():
    corpus = _corpus(40)

    def build(ctx):
        return (Pipeline(ctx, _queries(), "queries")
                .vector_topk("s1", EMB, "q", corpus, k=2,
                             doc_col="content")
                .vector_topk("s2", EMB, "q", corpus, k=3,
                             doc_col="content"))
    pipe, opt = _strict_equals_naive(build)
    assert any(ob.kind == "index_shared" for ob in opt.obligations)


def test_strict_discharges_speculative_chain(table):
    # distinct models per member keep the chain out of fusion's reach,
    # matching the speculative-execution test harness
    m2 = {"model": "m2", "context_window": 4096, "max_output_tokens": 8}

    def build(ctx):
        return (Pipeline(ctx, table, "papers")
                .llm_filter(MODEL, {"prompt": "about joins?"}, ["text"])
                .llm_filter(m2, {"prompt": "recent?"}, ["text"]))
    pipe, opt = _strict_equals_naive(build, speculate="always")
    assert any(n.op == "llm_spec_chain" for n in opt.nodes)
    assert any(ob.payload.get("spec_chain") for ob in opt.obligations
               if ob.kind == "mask_equivalence")


# ---------------------------------------------------------------------------
# FLK010: a tampered plan fails obligation discharge
# ---------------------------------------------------------------------------
def test_flk010_tampered_commute_is_caught(table):
    import copy
    ctx = _ctx()
    # limit CAN hoist over llm_complete (pushdown fires) but NOT over a
    # filter — forging the obligation's semantic node to the filter
    # must fail the independent legality check
    pipe = (Pipeline(ctx, table, "papers")
            .llm_filter(MODEL, {"prompt": "about joins?"}, ["text"])
            .llm_complete("summary", MODEL, {"prompt": "sum"}, ["text"])
            .limit(3))
    opt = pipe._plan()
    assert any(rw.startswith("pushdown") for rw in opt.rewrites)
    assert verify_rewrites(ctx, table, pipe.nodes, opt) == []
    # forge the obligation: claim the limit was hoisted over a filter
    # whose ban set forbids it
    bad = copy.copy(opt)
    forged = []
    for ob in opt.obligations:
        if ob.kind == "commute":
            p = dict(ob.payload)
            p["sem_node"] = pipe.nodes[1]          # the llm_filter
            ob = type(ob)(ob.rule, "commute", p)
        forged.append(ob)
    bad.obligations = forged
    diags = verify_rewrites(ctx, table, pipe.nodes, bad)
    assert diags and all(d.code == "FLK010" for d in diags)


def test_flk010_dropped_filter_is_caught(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_filter(MODEL, {"prompt": "about joins?"}, ["text"])
            .llm_complete("summary", MODEL, {"prompt": "sum"}, ["text"]))
    opt = pipe._plan()
    assert verify_rewrites(ctx, table, pipe.nodes, opt) == []
    # an "optimized" plan that silently dropped the filter must fail
    # the mask-equivalence / schema obligations
    import copy
    bad = copy.copy(opt)
    bad.nodes = [n for n in opt.nodes if n.op != "llm_fused"]
    diags = verify_rewrites(ctx, table, pipe.nodes, bad)
    assert any(d.code == "FLK010" for d in diags)


def test_strict_collect_catches_tampered_plan(table):
    ctx = _ctx()
    pipe = (Pipeline(ctx, table, "papers")
            .llm_complete("summary", MODEL, {"prompt": "sum"}, ["text"])
            .limit(3))
    opt = pipe._plan()              # memoised: collect() reuses this
    for ob in opt.obligations:
        if ob.kind == "commute":
            ob.payload["sem_node"] = pipe.nodes[1]
            ob.payload["rel_op"] = "order_by"
    opt.obligations.append(type(opt.obligations[0])(
        "forged", "recall_contract",
        {"key": "nope", "k": 5, "candidate_k": 1}))
    with pytest.raises(PlanValidationError, match="FLK010"):
        pipe.collect(verify="strict")


# ---------------------------------------------------------------------------
# property test: random valid plans analyze + verify cleanly
# ---------------------------------------------------------------------------
_STEPS = ["filter_join", "filter_recent", "complete", "complete_json",
          "order_year", "order_id", "limit3", "limit5"]


def _apply(pipe, step, i):
    if step == "filter_join":
        return pipe.llm_filter(MODEL, {"prompt": "about joins?"},
                               ["text"])
    if step == "filter_recent":
        return pipe.llm_filter(MODEL, {"prompt": "recent work?"},
                               ["text"])
    if step == "complete":
        return pipe.llm_complete(f"c{i}", MODEL,
                                 {"prompt": f"summarize {i}"}, ["text"])
    if step == "complete_json":
        return pipe.llm_complete_json(f"j{i}", MODEL,
                                      {"prompt": f"extract {i}"},
                                      ["text"])
    if step == "order_year":
        return pipe.order_by("year", desc=True)
    if step == "order_id":
        return pipe.order_by("id")
    return pipe.limit(3 if step == "limit3" else 5)


def test_property_random_plans_analyze_and_verify():
    pytest.importorskip(
        "hypothesis",
        reason="property tests need the optional hypothesis dependency")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(steps=st.lists(st.sampled_from(_STEPS), min_size=1,
                          max_size=6),
           speculate=st.sampled_from([False, "always"]))
    def prop(steps, speculate):
        _check_random_plan(steps, speculate)

    prop()


def _check_random_plan(steps, speculate):
    ctx = _ctx()
    table = Table({
        "id": list(range(10)),
        "text": [f"paper {i} about {'join' if i % 2 else 'index'}"
                 for i in range(10)],
        "year": [2000 + i for i in range(10)],
    })
    pipe = Pipeline(ctx, table, "t")
    for i, s in enumerate(steps):
        pipe = _apply(pipe, s, i)
    # layer 1+2: valid plans produce no error diagnostics
    assert analyze_plan(ctx, table, pipe.nodes).errors == []
    # layer 3: every optimizer output discharges its obligations
    opt = pipe._plan(speculate)
    assert verify_rewrites(ctx, table, pipe.nodes, opt) == []
    # schema inference is total over optimized nodes too
    assert len(infer_schema(table, opt.nodes)) == len(opt.nodes)
