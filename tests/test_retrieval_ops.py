"""Retrieval-operator equivalence suite (ISSUE 5).

Pins the contracts of the first-class retrieval plan operators:

  * ``vector_topk`` / ``bm25_topk`` / ``hybrid_topk`` + ``llm_rerank``
    produce rows bit-identical to the imperative
    BM25Index/VectorIndex/fusion composition they replace;
  * the optimizer's corpus-filter pushdown (``prune_corpus``) embeds
    strictly fewer docs without changing a single output row, and
    query-side relational filters push below the LATERAL expansion;
  * ``IndexStore`` memoises built indexes across sessions (zero embed
    requests on reuse), recovers from a corrupt sidecar, prunes model
    re-versions, and stays bounded;
  * embed dispatches are batch-planned (no single mega-batch), feed the
    calibration sidecar, honour headroom, and co-pack deterministically
    under concurrent dispatch;
  * ``core.fusion`` edge cases: all-NaN columns, single retriever, rrf
    tie ranks, degenerate combmnz.
"""

import json
import threading

import numpy as np
import pytest

from repro.core import (MockProvider, PredictionCache, RequestScheduler,
                        SemanticContext, corpus_fingerprint, llm_embedding,
                        rrf)
from repro.core.cache import IndexStore
from repro.core.fusion import (combanz, combmed, combmnz, combsum,
                               fusion)
from repro.core.resources import Catalog
from repro.engine import Pipeline, Table
from repro.retrieval import BM25Index, VectorIndex, active_mesh, \
    ensure_index

EMB = {"model": "e", "embedding_dim": 16, "context_window": 4096}
CHAT = {"model": "m", "context_window": 8192, "max_output_tokens": 16}


def make_corpus(n=48):
    topics = ("joins", "indexes", "vectors")
    return Table({
        "content": [f"doc {i} about {topics[i % 3]} with a body of "
                    f"searchable text" for i in range(n)],
        "year": [2000 + i % 6 for i in range(n)],
    })


def queries_table():
    return Table({"q": ["join algorithms", "vector search"],
                  "qid": [0, 1]})


# ---------------------------------------------------------------------------
# fusion hardening (satellite)
# ---------------------------------------------------------------------------
def test_fusion_all_nan_column_contributes_nothing():
    a = np.array([3.0, 1.0, 2.0])
    nan = np.full(3, np.nan)
    np.testing.assert_allclose(rrf(a, nan), rrf(a))
    np.testing.assert_allclose(combsum(a, nan), a)
    np.testing.assert_allclose(combanz(a, nan), a)
    for fn in (rrf, combsum, combmnz, combmed, combanz):
        out = fn(nan, nan)
        assert not np.isnan(out).any()
        np.testing.assert_allclose(out, 0.0)


def test_fusion_single_retriever_input():
    a = np.array([0.5, 2.0, 1.0])
    for m in ("rrf", "combsum", "combmnz", "combmed", "combanz"):
        out = fusion(m, a)
        assert out.shape == a.shape
        assert not np.isnan(out).any()
        # fusion of one retriever preserves its ranking
        assert list(np.argsort(-out, kind="stable")) == [1, 2, 0]


def test_rrf_tied_scores_share_rank():
    f = rrf(np.array([5.0, 5.0, 3.0, 3.0, 1.0]))
    assert f[0] == f[1]
    assert f[2] == f[3]
    assert f[0] > f[2] > f[4]
    # competition ranks: the group AFTER a tie keeps its absolute rank
    np.testing.assert_allclose(f, [1 / 61, 1 / 61, 1 / 63, 1 / 63,
                                   1 / 65])


def test_rrf_independent_of_tie_reporting_order():
    a = np.array([2.0, 2.0, 2.0, 1.0])
    b = a[[2, 0, 1, 3]]
    np.testing.assert_allclose(rrf(a)[3], rrf(b)[3])
    assert len({x for x in rrf(a)[:3]}) == 1


def test_combmnz_zero_non_nan_rows_are_exact_zero():
    m1 = np.array([np.nan, 1.0])
    m2 = np.array([np.nan, 2.0])
    out = combmnz(m1, m2)
    assert out[0] == 0.0
    assert out[1] == pytest.approx(6.0)        # (1+2) * 2 non-zero


def test_fusion_input_validation():
    with pytest.raises(ValueError):
        fusion("rrf")                          # no columns at all
    with pytest.raises(ValueError):
        combsum(np.ones(3), np.ones(4))        # ragged
    for m in ("rrf", "combsum", "combmnz", "combmed", "combanz"):
        assert fusion(m, np.array([])).shape == (0,)


# ---------------------------------------------------------------------------
# operator equivalence vs the imperative composition
# ---------------------------------------------------------------------------
def _imperative_hybrid(ctx, corpus, query, k, c, doc_col="content"):
    """The pre-PR idiom (examples/hybrid_search.py): separate retriever
    calls, full-length NaN-holed score arrays, fusion, final argsort."""
    texts = [str(x) for x in corpus.column(doc_col)]
    n = len(texts)
    vi = VectorIndex(llm_embedding(ctx, EMB, texts))
    qv = llm_embedding(ctx, EMB, [query])
    v_s, v_idx = vi.topk(qv, c)
    bm = BM25Index.build(texts)
    b_scores = bm.score(query)
    b_top = np.argsort(-b_scores, kind="stable")[:c]
    col_b = np.full(n, np.nan)
    col_b[b_top] = b_scores[b_top]
    col_v = np.full(n, np.nan)
    col_v[v_idx[0]] = v_s[0]
    fused = rrf(col_b, col_v)
    order = np.argsort(-fused, kind="stable")[:k]
    return [int(i) for i in order], [float(fused[i]) for i in order]


def test_vector_topk_matches_imperative():
    corpus = make_corpus()
    ctx = SemanticContext(provider=MockProvider())
    t = (Pipeline(ctx, queries_table(), "queries")
         .vector_topk("score", EMB, "q", corpus, k=5, doc_col="content")
         .collect())
    assert len(t) == 10
    ctx2 = SemanticContext(provider=MockProvider())
    texts = [str(x) for x in corpus.column("content")]
    vi = VectorIndex(llm_embedding(ctx2, EMB, texts))
    qv = llm_embedding(ctx2, EMB,
                       [str(q) for q in queries_table().column("q")])
    s, i = vi.topk(qv, 5)
    assert t.column("content") == [texts[j] for r in range(2)
                                   for j in i[r]]
    np.testing.assert_allclose(t.column("score"),
                               [float(x) for r in range(2) for x in s[r]])
    assert t.column("score_rank") == [1, 2, 3, 4, 5] * 2


def test_bm25_topk_matches_imperative():
    corpus = make_corpus()
    ctx = SemanticContext(provider=MockProvider())
    t = (Pipeline(ctx, queries_table(), "queries")
         .bm25_topk("bscore", "q", corpus, k=4, doc_col="content")
         .collect())
    assert ctx.provider.stats.calls == 0       # no LLM at all
    texts = [str(x) for x in corpus.column("content")]
    bm = BM25Index.build(texts)
    expected_docs, expected_scores = [], []
    for q in queries_table().column("q"):
        s = bm.score(str(q))
        order = np.argsort(-s, kind="stable")[:4]
        expected_docs += [texts[i] for i in order]
        expected_scores += [float(s[i]) for i in order]
    assert t.column("content") == expected_docs
    np.testing.assert_allclose(t.column("bscore"), expected_scores)


def test_hybrid_topk_plus_rerank_bit_identical_to_imperative():
    corpus = make_corpus()
    k, c = 6, 12
    ctx = SemanticContext(provider=MockProvider())
    pipe = (Pipeline(ctx, queries_table(), "queries")
            .hybrid_topk("score", EMB, "q", corpus, k=k,
                         doc_col="content", candidate_k=c)
            .llm_rerank(CHAT, {"prompt": "most relevant"},
                        ["content"], by="q"))
    t = pipe.collect()

    from repro.core import llm_rerank as llm_rerank_fn
    ctx2 = SemanticContext(provider=MockProvider())
    texts = [str(x) for x in corpus.column("content")]
    exp_content, exp_scores = [], []
    for q in queries_table().column("q"):
        ids, scores = _imperative_hybrid(ctx2, corpus, str(q), k, c)
        docs = [{"content": texts[i]} for i in ids]
        perm = llm_rerank_fn(ctx2, CHAT, {"prompt": "most relevant"},
                             docs)
        exp_content += [texts[ids[p]] for p in perm]
        exp_scores += [scores[p] for p in perm]
    assert t.column("content") == exp_content
    np.testing.assert_allclose(t.column("score"), exp_scores)
    # the plan embeds BOTH queries in one dispatch where the imperative
    # loop pays one per query: never more embed requests than imperative
    emb_reqs = sum(r.requests for r in ctx.reports
                   if r.function == "embedding")
    emb_reqs2 = sum(r.requests for r in ctx2.reports
                    if r.function == "embedding")
    assert 0 < emb_reqs <= emb_reqs2


def test_hybrid_fusion_methods_dispatch():
    corpus = make_corpus(24)
    for method in ("combsum", "combmnz"):
        ctx = SemanticContext(provider=MockProvider())
        t = (Pipeline(ctx, queries_table(), "queries")
             .hybrid_topk("score", EMB, "q", corpus, k=3,
                          doc_col="content", fusion=method,
                          candidate_k=8)
             .collect())
        assert len(t) == 6
        assert not np.isnan(t.column("score")).any()


def test_retrieval_empty_query_table_keeps_schema():
    corpus = make_corpus(8)
    ctx = SemanticContext(provider=MockProvider())
    t = (Pipeline(ctx, Table({"q": [], "qid": []}), "queries")
         .hybrid_topk("score", EMB, "q", corpus, k=3, doc_col="content")
         .collect())
    assert len(t) == 0
    assert set(t.column_names) >= {"q", "content", "score", "score_rank"}


def test_doc_column_collision_gets_suffix():
    corpus = Table({"content": ["a b", "b c"], "qid": [7, 8]})
    ctx = SemanticContext(provider=MockProvider())
    t = (Pipeline(ctx, queries_table(), "queries")
         .bm25_topk("s", "q", corpus, k=1, doc_col="content")
         .collect())
    assert "qid_doc" in t.column_names          # corpus qid renamed
    assert t.column("qid") == [0, 1]            # parent qid intact


# ---------------------------------------------------------------------------
# optimizer: corpus-filter pushdown, query-filter pushdown, k-pushdown
# ---------------------------------------------------------------------------
def _embedded_texts(ctx):
    return sum(r.n_tuples for r in ctx.reports
               if r.function == "embedding")


def test_corpus_filter_pushdown_preserves_results():
    corpus = make_corpus(60)
    flt = lambda r: r["year"] >= 2003

    def run(optimize):
        ctx = SemanticContext(provider=MockProvider())
        pipe = (Pipeline(ctx, queries_table(), "queries")
                .hybrid_topk("score", EMB, "q", corpus, k=5,
                             doc_col="content", candidate_k=10,
                             corpus_filter=flt,
                             corpus_filter_cols=["year"]))
        t = pipe.collect(optimize=optimize)
        return t.rows(), _embedded_texts(ctx), pipe

    rows_naive, embeds_naive, _ = run(False)
    rows_opt, embeds_opt, pipe = run(True)
    assert rows_opt == rows_naive
    assert embeds_opt < embeds_naive
    assert any(rw.startswith("prune_corpus")
               for rw in pipe._plan().rewrites)
    assert all(r["year"] >= 2003 for r in rows_opt)


def test_corpus_filter_pushdown_vector_topk_preserves_results():
    corpus = make_corpus(40)
    flt = lambda r: "joins" in r["content"]

    def run(optimize):
        ctx = SemanticContext(provider=MockProvider())
        return (Pipeline(ctx, queries_table(), "queries")
                .vector_topk("score", EMB, "q", corpus, k=4,
                             doc_col="content", corpus_filter=flt,
                             corpus_filter_cols=["content"])
                .collect(optimize=optimize)).rows()

    assert run(True) == run(False)


def test_query_side_filter_pushes_below_retrieval():
    corpus = make_corpus(30)

    def build(ctx):
        return (Pipeline(ctx, queries_table(), "queries")
                .hybrid_topk("score", EMB, "q", corpus, k=4,
                             doc_col="content", candidate_k=8)
                .filter(lambda r: r["qid"] == 0, cols=["qid"]))

    ctx = SemanticContext(provider=MockProvider())
    pipe = build(ctx)
    rows_opt = pipe.collect().rows()
    assert any("pushdown(filter before hybrid_topk)" in rw
               for rw in pipe._plan().rewrites)
    ctx2 = SemanticContext(provider=MockProvider())
    rows_naive = build(ctx2).collect(optimize=False).rows()
    assert rows_opt == rows_naive
    # pushed-down plan embeds only the surviving query
    assert _embedded_texts(ctx) < _embedded_texts(ctx2)


def test_filter_on_retrieval_outputs_stays_above():
    corpus = make_corpus(30)
    ctx = SemanticContext(provider=MockProvider())
    pipe = (Pipeline(ctx, queries_table(), "queries")
            .bm25_topk("score", "q", corpus, k=5, doc_col="content")
            .filter(lambda r: r["score_rank"] <= 2,
                    cols=["score_rank"]))
    plan = pipe._plan()
    assert not any("pushdown(filter before bm25_topk)" in rw
                   for rw in plan.rewrites)
    t = pipe.collect()
    assert len(t) == 4                          # 2 queries x top-2


def test_k_pushdown_sets_candidate_depth():
    corpus = make_corpus(300)
    ctx = SemanticContext(provider=MockProvider())
    pipe = (Pipeline(ctx, queries_table(), "queries")
            .hybrid_topk("score", EMB, "q", corpus, k=4,
                         doc_col="content"))
    plan = pipe._plan()
    assert any(rw.startswith("k_pushdown(hybrid_topk") for rw in
               plan.rewrites)
    node = [n for n in plan.nodes if n.op == "hybrid_topk"][0]
    assert node.info["candidate_k"] == 32       # max(32, 4*4)
    t = pipe.collect()
    assert len(t) == 8
    # the logical plan is untouched (candidate_k stays engine-chosen)
    assert pipe.nodes[1].info["candidate_k"] is None


def test_shared_corpus_embeds_once_and_is_noted():
    corpus = make_corpus(36)
    ctx = SemanticContext(provider=MockProvider(), enable_cache=False)
    pipe = (Pipeline(ctx, queries_table(), "queries")
            .vector_topk("s1", EMB, "q", corpus, k=3, doc_col="content")
            .vector_topk("s2", EMB, "q", corpus, k=3, doc_col="content"))
    plan = pipe._plan()
    assert any(rw.startswith("dedupe_corpus_embed")
               for rw in plan.rewrites)
    # cost model charges the corpus embed once: second node is cheaper
    reqs = [c["requests"] for c in plan.optimized_node_costs[1:3]]
    assert reqs[1] < reqs[0]
    t = pipe.collect()
    # runtime: the session index registry served the second node's
    # corpus (the prediction cache is off, so reuse is the registry's
    # doing) — embedded texts are the corpus ONCE, the 2 query rows of
    # node 1, and node 2's 6 expanded query rows (2 queries x 3 docs)
    assert _embedded_texts(ctx) == len(corpus) + 2 + 6
    assert len(t) == 18                         # 6 rows x 3 docs each


def test_explain_reports_retrieval_cost():
    corpus = make_corpus(50)
    with RequestScheduler(pack_linger_s=0.2) as sched:
        ctx = SemanticContext(provider=MockProvider(), scheduler=sched)
        pipe = (Pipeline(ctx, queries_table(), "queries")
                .hybrid_topk("score", EMB, "q", corpus, k=4,
                             doc_col="content", candidate_k=8)
                .llm_rerank(CHAT, {"prompt": "rank"}, ["content"],
                            by="q"))
        text = pipe.explain()
    assert "scan_flops=" in text                # index-scan cost
    assert "req=" in text                       # embed request estimate
    assert "hybrid_topk" in text


def test_explain_embed_estimate_drops_after_index_is_built():
    corpus = make_corpus(40)
    ctx = SemanticContext(provider=MockProvider())

    def build():
        return (Pipeline(ctx, queries_table(), "queries")
                .vector_topk("score", EMB, "q", corpus, k=3,
                             doc_col="content"))

    before = build()._plan().optimized_node_costs[1]["requests"]
    build().collect()
    after = build()._plan().optimized_node_costs[1]["requests"]
    assert after < before                       # corpus index memoised


# ---------------------------------------------------------------------------
# IndexStore sidecar
# ---------------------------------------------------------------------------
def test_index_store_reuse_across_sessions(tmp_path):
    corpus = make_corpus(20)
    texts = [str(x) for x in corpus.column("content")]
    cache_path = str(tmp_path / "cache.jsonl")

    ctx1 = SemanticContext(
        provider=MockProvider(),
        cache=PredictionCache(persist_path=cache_path))
    idx1, src1 = ensure_index(ctx1, EMB, texts)
    assert src1 == "built"
    calls1 = ctx1.provider.stats.calls
    assert calls1 > 0

    # fresh session, fresh provider, fresh prediction cache object: the
    # vectors come from the index sidecar, zero provider calls
    ctx2 = SemanticContext(
        provider=MockProvider(),
        cache=PredictionCache(persist_path=str(tmp_path / "other.jsonl")),
        index_path=str(cache_path) + ".index.json")
    idx2, src2 = ensure_index(ctx2, EMB, texts)
    assert src2 == "store"
    assert ctx2.provider.stats.calls == 0
    np.testing.assert_array_equal(idx1.vectors, idx2.vectors)

    # and the session registry serves the third lookup
    _, src3 = ensure_index(ctx2, EMB, texts)
    assert src3 == "session"


def test_index_store_corruption_recovery(tmp_path):
    path = tmp_path / "idx.json"
    path.write_text("{not json")
    store = IndexStore(str(path))
    assert store.keys() == []
    store.put("e@0", "fp", np.ones((2, 4), np.float32))
    assert store.get("e@0", "fp").shape == (2, 4)
    # a half-valid file keeps the valid entries only
    path.write_text(json.dumps({"indexes": {
        "ok|fp": {"vectors": [[1.0, 2.0]]},
        "bad|fp": {"vectors": [[1.0], [2.0, 3.0]]},      # ragged
        "worse|fp": {"vectors": "nope"},
    }}))
    store2 = IndexStore(str(path))
    assert store2.keys() == ["ok|fp"]


def test_index_store_prunes_reversioned_models(tmp_path):
    store = IndexStore(str(tmp_path / "idx.json"))
    store.put("m@1", "fp", np.ones((1, 2), np.float32))
    store.put("inline@0", "fp2", np.ones((1, 2), np.float32))
    cat = Catalog()
    cat.create_model("m", arch="mock")
    cat.update_model("m", context_window=999)    # now m@2
    store.prune(cat)
    assert store.get("m@1", "fp") is None
    assert store.get("inline@0", "fp2") is not None


def test_index_store_capacity_bound(tmp_path):
    store = IndexStore(str(tmp_path / "idx.json"), capacity=2)
    for i in range(4):
        store.put("e@0", f"fp{i}", np.ones((1, 2), np.float32))
    assert len(store.keys()) == 2
    assert store.get("e@0", "fp3") is not None
    assert store.get("e@0", "fp0") is None


def test_index_roundtrip_is_bit_exact(tmp_path):
    rng = np.random.default_rng(0)
    v = rng.standard_normal((6, 8)).astype(np.float32)
    store = IndexStore(str(tmp_path / "idx.json"))
    store.put("e@0", "fp", v)
    reloaded = IndexStore(str(tmp_path / "idx.json")).get("e@0", "fp")
    np.testing.assert_array_equal(v, reloaded)


# ---------------------------------------------------------------------------
# llm_embedding: planned batches, headroom, calibration (satellite)
# ---------------------------------------------------------------------------
def test_embedding_dispatch_is_batch_planned():
    ctx = SemanticContext(provider=MockProvider())
    texts = [f"passage number {i} with a reasonably long body of text"
             for i in range(40)]
    model = {"model": "e", "embedding_dim": 8, "context_window": 200}
    llm_embedding(ctx, model, texts)
    rep = ctx.reports[-1]
    assert rep.requests > 1                     # no single mega-batch
    assert sum(rep.batch_sizes) == len(texts)
    assert len(rep.latencies) == rep.requests
    # calibration learned the embedding batch sizes
    rec = ctx.calibration_stats["e@0"]
    assert rec["requests"] == rep.requests
    assert rec["tuples"] == len(texts)


def test_embedding_respects_headroom():
    texts = [f"passage number {i} with a reasonably long body of text"
             for i in range(30)]
    model = {"model": "e", "embedding_dim": 8, "context_window": 400}
    ctx = SemanticContext(provider=MockProvider())
    llm_embedding(ctx, model, texts)
    full = ctx.reports[-1].batch_sizes
    ctx2 = SemanticContext(provider=MockProvider())
    ctx2.record_calibration("e@0", requests=8, retries=8, tuples=64,
                            latencies=[])
    ctx2.refresh_headroom()
    assert ctx2.batch_headroom("e@0") == 0.5
    llm_embedding(ctx2, model, texts)
    half = ctx2.reports[-1].batch_sizes
    assert max(half) < max(full)


def test_embedding_scheduler_counts_match_serial_with_batches():
    texts = [f"passage {i} body" for i in range(24)]
    model = {"model": "e", "embedding_dim": 8, "context_window": 48}
    ctx_s = SemanticContext(provider=MockProvider())
    ref = llm_embedding(ctx_s, model, texts)
    with RequestScheduler() as sched:
        ctx_c = SemanticContext(provider=MockProvider(), scheduler=sched)
        out = llm_embedding(ctx_c, model, texts)
    assert (out == ref).all()
    assert ctx_c.provider.stats.calls == ctx_s.provider.stats.calls
    assert ctx_s.provider.stats.calls > 1


# ---------------------------------------------------------------------------
# embed co-packing determinism under concurrency
# ---------------------------------------------------------------------------
def test_embedding_nodes_copack_fewer_requests_same_rows():
    # 24 rows x ~18 tokens at a 400-token window: each node plans one
    # full batch plus a 2-row tail; the tails are light enough to merge
    # into ONE co-packed request
    table = Table({
        "a": [f"first text {i} with a body of text" for i in range(24)],
        "b": [f"second text {i} with a body of text" for i in range(24)],
    })
    model = {"model": "e", "embedding_dim": 8, "context_window": 400,
             "max_concurrency": 8}

    def build(ctx):
        return (Pipeline(ctx, table, "docs")
                .llm_embedding("ea", model, ["a"])
                .llm_embedding("eb", model, ["b"]))

    runs = {}
    for copack in (False, True):
        with RequestScheduler(pack_linger_s=0.3) as sched:
            ctx = SemanticContext(provider=MockProvider(),
                                  scheduler=sched, copack=copack,
                                  enable_cache=False)
            t = build(ctx).collect(optimize=False)
            runs[copack] = (np.asarray(t.column("ea")),
                            np.asarray(t.column("eb")),
                            ctx.provider.stats.calls,
                            sched.stats.packed_requests)
    ea_off, eb_off, calls_off, _ = runs[False]
    ea_on, eb_on, calls_on, packed = runs[True]
    np.testing.assert_array_equal(ea_on, ea_off)
    np.testing.assert_array_equal(eb_on, eb_off)
    assert calls_on < calls_off
    assert packed >= 1


def test_retrieval_corpus_query_copack_deterministic_stress():
    corpus = Table({"content": [
        f"doc {i} about joins with a padded body of text"
        for i in range(55)]})
    queries = Table({"q": ["join algorithms", "index structures"]})
    model = {"model": "e", "embedding_dim": 8, "context_window": 300,
             "max_concurrency": 8}

    ctx_ref = SemanticContext(provider=MockProvider(),
                              enable_cache=False)
    ref = (Pipeline(ctx_ref, queries, "queries")
           .vector_topk("score", model, "q", corpus, k=5,
                        doc_col="content")
           .collect(optimize=False)).rows()
    for trial in range(4):
        with RequestScheduler(pack_linger_s=0.3) as sched:
            ctx = SemanticContext(provider=MockProvider(),
                                  scheduler=sched, enable_cache=False)
            rows = (Pipeline(ctx, queries, "queries")
                    .vector_topk("score", model, "q", corpus, k=5,
                                 doc_col="content")
                    .collect(optimize=False)).rows()
        assert rows == ref, f"trial {trial} diverged"


# ---------------------------------------------------------------------------
# grouped rerank + mesh-aware index
# ---------------------------------------------------------------------------
def test_llm_rerank_by_group_matches_per_group_rerank():
    from repro.core import llm_rerank as llm_rerank_fn
    table = Table({"g": [0, 0, 0, 1, 1, 1],
                   "content": [f"doc {i}" for i in range(6)]})
    ctx = SemanticContext(provider=MockProvider())
    t = (Pipeline(ctx, table, "docs")
         .llm_rerank(CHAT, {"prompt": "rank"}, ["content"], by="g")
         .collect())
    ctx2 = SemanticContext(provider=MockProvider())
    expected = []
    for g in (0, 1):
        docs = [{"content": f"doc {i}"} for i in range(3 * g, 3 * g + 3)]
        perm = llm_rerank_fn(ctx2, CHAT, {"prompt": "rank"}, docs)
        expected += [docs[p]["content"] for p in perm]
    assert t.column("content") == expected
    assert t.column("g") == [0, 0, 0, 1, 1, 1]


def test_vector_index_sharded_path_matches_oracle():
    import jax
    from jax.sharding import Mesh
    rng = np.random.default_rng(0)
    vectors = rng.standard_normal((64, 16)).astype(np.float32)
    q = rng.standard_normal((3, 16)).astype(np.float32)
    plain = VectorIndex(vectors)
    s_ref, i_ref = plain.topk(q, 5)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1,), ("data",))
    sharded = VectorIndex(vectors, mesh=mesh)
    s, i = sharded.topk(q, 5)
    np.testing.assert_array_equal(i, i_ref)
    np.testing.assert_allclose(s, s_ref, rtol=1e-5, atol=1e-6)
    # auto-detection ignores single-device meshes (sharding over one
    # device only adds dispatch overhead)
    with mesh:
        assert active_mesh() is None


def test_corpus_fingerprint_is_order_sensitive():
    assert corpus_fingerprint(["a", "b"]) != corpus_fingerprint(["b", "a"])
    assert corpus_fingerprint(["a", "b"]) == corpus_fingerprint(["a", "b"])


def test_corpus_fingerprint_is_unambiguous():
    # length framing: no text content can fake a document boundary, so
    # distinct corpora never alias one registry/IndexStore key
    assert corpus_fingerprint(["a\x1fb"]) != corpus_fingerprint(["a", "b"])
    assert corpus_fingerprint(["a\x1f", "b"]) != \
        corpus_fingerprint(["a", "\x1fb"])
    assert corpus_fingerprint(["12", "3"]) != corpus_fingerprint(["1",
                                                                  "23"])


def test_select_pushdown_keeps_grouped_rerank_key():
    corpus = make_corpus(20)

    def build(ctx, select_cols):
        return (Pipeline(ctx, queries_table(), "queries")
                .bm25_topk("score", "q", corpus, k=3, doc_col="content")
                .llm_rerank(CHAT, {"prompt": "rank"}, ["content"],
                            by="q")
                .select(*select_cols))

    # a select that drops the group key must NOT push below the rerank
    ctx = SemanticContext(provider=MockProvider())
    pipe = build(ctx, ("content", "score"))
    rows_opt = pipe.collect().rows()        # KeyError before the fix
    assert not any("pushdown(select before llm_rerank)" in rw
                   for rw in pipe._plan().rewrites)
    ctx2 = SemanticContext(provider=MockProvider())
    assert rows_opt == build(ctx2, ("content", "score")) \
        .collect(optimize=False).rows()
    # one that keeps the key still pushes
    ctx3 = SemanticContext(provider=MockProvider())
    pipe3 = build(ctx3, ("q", "content"))
    rows3 = pipe3.collect().rows()
    assert any("pushdown(select before llm_rerank)" in rw
               for rw in pipe3._plan().rewrites)
    ctx4 = SemanticContext(provider=MockProvider())
    assert rows3 == build(ctx4, ("q", "content")) \
        .collect(optimize=False).rows()
