#!/usr/bin/env python
"""flocklint — repo-specific AST lint rules for flock-jax.

Encodes the bug classes that earlier PRs fixed by hand as permanent,
mechanical rules (stdlib ``ast`` only — no third-party deps):

  FLKL101  wall-clock in duration paths: any ``time.time`` reference.
           Durations must use ``time.monotonic()``; genuine wall-clock
           timestamps (manifests, catalog ``created_at``) carry a
           pragma justifying the exemption.  Scope: all of ``src/``.
  FLKL102  provider dispatch / blocking call while holding a scheduler
           lock: ``.call(...)``, ``.run(...)``, ``.join(...)``,
           ``time.sleep`` / ``.sleep(...)``, ``.result(...)`` inside a
           ``with *lock:`` body.  (``Condition.wait`` is exempt — it
           releases the lock.)  Scope: ``core/scheduler.py``.
  FLKL103  lock-acquisition order: nested ``with *lock:`` blocks must
           follow the file's ``# flocklint: lock-order: a < b < c``
           declaration; nesting without a declaration is a violation.
           Scope: ``core/scheduler.py``.
  FLKL104  non-atomic sidecar staging: ``.with_suffix(".tmp")`` (strips
           the last suffix, so multi-dot sidecars collide — use the
           full-name ``_tmp_path`` helper) and ``os.rename`` (use
           ``os.replace`` / ``Path.replace`` for atomic overwrite).
           Scope: ``core/``, ``retrieval/``.
  FLKL105  bare / broad ``except`` (``except:``, ``except Exception``,
           ``except BaseException``) — narrow it, or pragma with the
           reason the broad catch is load-bearing (e.g. re-raised on
           the caller thread).  Scope: ``core/``, ``engine/``,
           ``retrieval/``.
  FLKL106  ad-hoc thread spawning: ``threading.Thread(...)`` constructed
           outside ``core/scheduler.py``.  Unbounded per-item threads
           oversubscribe past the scheduler's ``max_workers`` (the PR 3
           speculative-chain bug); route concurrency through
           ``RequestScheduler`` / ``SpeculativeJoin``, or pragma with
           the reason a dedicated thread is load-bearing.
           Scope: ``core/``, ``engine/``.

Suppression: ``# flocklint: ignore[CODE]`` (or ``ignore[C1,C2]``) on
the violating line or the line directly above it.

Usage::

    python tools/flocklint.py src/            # exit 1 on any violation
    python tools/flocklint.py file.py dir/ --list-rules
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, List, Optional, Sequence

_PRAGMA_RE = re.compile(r"#\s*flocklint:\s*ignore\[([A-Z0-9,\s]+)\]")
_LOCK_ORDER_RE = re.compile(r"#\s*flocklint:\s*lock-order:\s*(.+)$")

# FLKL102: attribute-call names that block (or dispatch to a provider)
# and therefore must never run under a scheduler lock.  ``wait`` is
# deliberately absent: Condition.wait releases the lock while blocked.
_BLOCKING_ATTRS = {"call", "run", "join", "sleep", "result"}

RULES = {
    "FLKL101": "time.time used (durations must use time.monotonic)",
    "FLKL102": "blocking/dispatch call while holding a scheduler lock",
    "FLKL103": "nested lock acquisition violates declared lock-order",
    "FLKL104": "non-atomic sidecar staging (.with_suffix('.tmp') / os.rename)",
    "FLKL105": "bare or broad except clause",
    "FLKL106": "threading.Thread constructed outside core/scheduler.py",
}


@dataclass(frozen=True)
class Violation:
    path: Path
    line: int
    code: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _pragma_codes(lines: Sequence[str], lineno: int) -> set:
    """Codes suppressed at ``lineno`` (1-based): pragmas on the line
    itself or on the line directly above count."""
    codes: set = set()
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            m = _PRAGMA_RE.search(lines[ln - 1])
            if m:
                codes.update(c.strip() for c in m.group(1).split(","))
    return codes


def _dotted(node: ast.expr) -> Optional[str]:
    """Render ``a.b.c`` attribute chains; None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _lock_name(expr: ast.expr) -> Optional[str]:
    """Normalized lock identity for a ``with`` context expression, or
    None when the expression is not a lock acquisition.  ``self.`` is
    stripped and at most the last two components kept, so
    ``s.job._lock`` and ``job._lock`` unify while ``self._lock`` and
    ``job._lock`` stay distinct."""
    name = _dotted(expr)
    if name is None or not name.split(".")[-1].endswith("lock"):
        return None
    parts = [p for p in name.split(".") if p != "self"]
    return ".".join(parts[-2:])


def _in_scope(rel: Path, *prefixes: str) -> bool:
    parts = rel.parts
    return any(p in parts for p in prefixes)


# ---------------------------------------------------------------------------
# per-rule visitors
# ---------------------------------------------------------------------------
class _Walker(ast.NodeVisitor):
    """Single-pass walker that runs every enabled rule, tracking the
    stack of locks held at each node (``with``-statement nesting)."""

    def __init__(self, path: Path, rel: Path, lines: Sequence[str],
                 lock_order: Optional[List[str]]):
        self.path = path
        self.rel = rel
        self.lines = lines
        self.lock_order = lock_order
        self.lock_stack: List[str] = []
        self.out: List[Violation] = []
        self.scheduler = rel.name == "scheduler.py" and _in_scope(rel, "core")
        self.atomic_scope = _in_scope(rel, "core", "retrieval")
        self.except_scope = _in_scope(rel, "core", "engine", "retrieval")
        self.thread_scope = (_in_scope(rel, "core", "engine")
                             and not self.scheduler)

    def _emit(self, code: str, lineno: int, message: str):
        if code not in _pragma_codes(self.lines, lineno):
            self.out.append(Violation(self.path, lineno, code, message))

    # ---- FLKL101 ----------------------------------------------------------
    def visit_Attribute(self, node: ast.Attribute):
        if (node.attr == "time" and isinstance(node.value, ast.Name)
                and node.value.id == "time"):
            self._emit("FLKL101", node.lineno,
                       "time.time: use time.monotonic() for durations "
                       "(pragma wall-clock timestamps)")
        self.generic_visit(node)

    # ---- FLKL102 / FLKL104 ------------------------------------------------
    def visit_Call(self, node: ast.Call):
        dotted = _dotted(node.func)
        if self.scheduler and self.lock_stack:
            attr = (node.func.attr
                    if isinstance(node.func, ast.Attribute) else None)
            if attr in _BLOCKING_ATTRS or dotted == "time.sleep":
                self._emit("FLKL102", node.lineno,
                           f"blocking call .{attr or 'sleep'}(...) while "
                           f"holding {self.lock_stack[-1]}")
        if self.atomic_scope:
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "with_suffix" and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and node.args[0].value == ".tmp"):
                self._emit("FLKL104", node.lineno,
                           '.with_suffix(".tmp") mangles multi-dot '
                           "sidecar names: use cache._tmp_path")
            if dotted == "os.rename":
                self._emit("FLKL104", node.lineno,
                           "os.rename: use os.replace for atomic "
                           "overwrite semantics")
        if self.thread_scope and dotted == "threading.Thread":
            self._emit("FLKL106", node.lineno,
                       "threading.Thread outside core/scheduler.py: "
                       "route concurrency through RequestScheduler / "
                       "SpeculativeJoin (or pragma with justification)")
        self.generic_visit(node)

    # ---- FLKL103 + lock-stack maintenance ---------------------------------
    def visit_With(self, node: ast.With):
        acquired = [ln for item in node.items
                    if (ln := _lock_name(item.context_expr)) is not None]
        for ln in acquired:
            if self.lock_stack:
                self._check_order(self.lock_stack[-1], ln, node.lineno)
        self.lock_stack.extend(acquired)
        self.generic_visit(node)
        del self.lock_stack[len(self.lock_stack) - len(acquired):]

    def _check_order(self, outer: str, inner: str, lineno: int):
        if self.lock_order is None:
            self._emit("FLKL103", lineno,
                       f"nested lock acquisition ({outer} -> {inner}) "
                       "but no '# flocklint: lock-order:' declaration")
            return
        try:
            if self.lock_order.index(outer) > self.lock_order.index(inner):
                self._emit("FLKL103", lineno,
                           f"lock order violation: {outer} held while "
                           f"acquiring {inner} (declared: "
                           f"{' < '.join(self.lock_order)})")
        except ValueError:
            missing = outer if outer not in self.lock_order else inner
            self._emit("FLKL103", lineno,
                       f"lock {missing!r} not in the declared lock-order")

    # nested function bodies do not run under the enclosing lock
    def visit_FunctionDef(self, node: ast.FunctionDef):
        saved, self.lock_stack = self.lock_stack, []
        self.generic_visit(node)
        self.lock_stack = saved

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]

    # ---- FLKL105 ----------------------------------------------------------
    def visit_ExceptHandler(self, node: ast.ExceptHandler):
        if self.except_scope:
            broad = None
            if node.type is None:
                broad = "bare except:"
            else:
                types = (node.type.elts if isinstance(node.type, ast.Tuple)
                         else [node.type])
                for t in types:
                    if (isinstance(t, ast.Name)
                            and t.id in ("Exception", "BaseException")):
                        broad = f"except {t.id}"
                        break
            if broad:
                self._emit("FLKL105", node.lineno,
                           f"{broad}: narrow to the expected exceptions "
                           "or pragma with a justification")
        self.generic_visit(node)


def _parse_lock_order(lines: Sequence[str]) -> Optional[List[str]]:
    for line in lines:
        m = _LOCK_ORDER_RE.search(line)
        if m:
            return [p.strip() for p in re.split(r"[<,]", m.group(1))
                    if p.strip()]
    return None


def lint_source(source: str, path: Path, rel: Path) -> List[Violation]:
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as exc:
        return [Violation(path, exc.lineno or 0, "FLKL000",
                          f"syntax error: {exc.msg}")]
    lines = source.splitlines()
    walker = _Walker(path, rel, lines, _parse_lock_order(lines))
    walker.visit(tree)
    return sorted(walker.out, key=lambda v: (v.line, v.code))


def _iter_files(targets: Sequence[str]) -> Iterator[Path]:
    for t in targets:
        p = Path(t)
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def _rel_to_package(path: Path) -> Path:
    """Path relative to the package root (the part after ``src/``), so
    scope checks see ``repro/core/...`` regardless of invocation cwd."""
    parts = path.parts
    if "src" in parts:
        return Path(*parts[parts.index("src") + 1:])
    return path


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("targets", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for code, desc in sorted(RULES.items()):
            print(f"{code}  {desc}")
        return 0
    violations: List[Violation] = []
    n_files = 0
    for path in _iter_files(args.targets or ["src"]):
        n_files += 1
        source = path.read_text(encoding="utf-8")
        violations.extend(lint_source(source, path, _rel_to_package(path)))
    for v in violations:
        print(v)
    print(f"flocklint: {n_files} file(s), {len(violations)} violation(s)",
          file=sys.stderr)
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
