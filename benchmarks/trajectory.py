"""Fold the per-bench ``BENCH_*.json`` snapshots into one committed
performance trajectory, and gate CI on regressions against it.

``TRAJECTORY.json`` holds a series of labelled snapshots — one per PR
(the label defaults to ``git rev-list --count HEAD``) — each mapping
bench name to its flattened scalar metrics.  Re-running under the same
label replaces that entry, so the file stays one line per PR no matter
how many local runs precede the commit.

Two modes:

``python benchmarks/trajectory.py``
    Aggregate: read every ``BENCH_*.json`` next to this file and
    append/replace the current label's snapshot in ``TRAJECTORY.json``.

``python benchmarks/trajectory.py --check``
    Gate: compare the freshly generated ``BENCH_*.json`` files against
    the LAST committed snapshot.  Each gated metric (see
    ``GATED_METRICS``) may drift in its bad direction by at most the
    bench's relative tolerance — ``BENCH_<NAME>_TOL`` env var,
    default ``DEFAULT_TOL`` — before the exit code turns nonzero.
    Metrics absent from the baseline (a brand-new bench or field) pass:
    the NEXT aggregated snapshot starts gating them.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

BENCH_DIR = Path(__file__).resolve().parent
TRAJECTORY_PATH = BENCH_DIR / "TRAJECTORY.json"

DEFAULT_TOL = 0.25

# bench -> [(dotted metric path, good direction)].  "higher" metrics
# regress by dropping, "lower" metrics regress by growing; everything
# else recorded in the trajectory is context, not a gate.
GATED_METRICS: Dict[str, List[Tuple[str, str]]] = {
    "scheduler": [("concurrency_4.speedup", "higher")],
    "speculative": [("speedup", "higher"),
                    ("filter_map.wall_ratio", "lower"),
                    ("rerank.wall_ratio", "lower")],
    "copack": [("copack_on.requests", "lower"),
               ("copack_on.mean_fill", "higher")],
    "rag": [("embed_requests_on", "lower")],
    "ann": [("recall_at_k", "higher"),
            ("ivf_speedup_vs_exact", "higher")],
}


def _flatten(obj, prefix: str = "") -> Dict[str, float]:
    """Scalar leaves of a nested bench dict as dotted paths."""
    out: Dict[str, float] = {}
    if isinstance(obj, dict):
        for key, val in obj.items():
            path = f"{prefix}.{key}" if prefix else str(key)
            out.update(_flatten(val, path))
    elif isinstance(obj, bool):
        out[prefix] = float(obj)
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)
    return out


def _load_benches() -> Dict[str, Dict[str, float]]:
    benches: Dict[str, Dict[str, float]] = {}
    for path in sorted(BENCH_DIR.glob("BENCH_*.json")):
        name = path.stem[len("BENCH_"):]
        try:
            benches[name] = _flatten(json.loads(path.read_text()))
        except (json.JSONDecodeError, OSError) as exc:
            print(f"trajectory: skipping unreadable {path.name}: {exc}",
                  file=sys.stderr)
    return benches


def _default_label() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-list", "--count", "HEAD"], cwd=BENCH_DIR,
            capture_output=True, text=True, timeout=10)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except (OSError, subprocess.TimeoutExpired):
        pass
    return "0"


def _load_trajectory() -> dict:
    if TRAJECTORY_PATH.exists():
        try:
            doc = json.loads(TRAJECTORY_PATH.read_text())
            if isinstance(doc, dict) and isinstance(
                    doc.get("series"), list):
                return doc
        except json.JSONDecodeError:
            print("trajectory: corrupt TRAJECTORY.json, starting fresh",
                  file=sys.stderr)
    return {"series": []}


def aggregate(label: Optional[str] = None) -> int:
    label = label or _default_label()
    benches = _load_benches()
    if not benches:
        print("trajectory: no BENCH_*.json files found", file=sys.stderr)
        return 1
    doc = _load_trajectory()
    entry = {"label": label, "benches": benches}
    series = [e for e in doc["series"] if e.get("label") != label]
    series.append(entry)
    doc["series"] = series
    TRAJECTORY_PATH.write_text(json.dumps(doc, indent=1) + "\n")
    n_metrics = sum(len(m) for m in benches.values())
    print(f"trajectory: recorded label={label} "
          f"({len(benches)} benches, {n_metrics} metrics) "
          f"-> {TRAJECTORY_PATH.name}")
    return 0


def _tolerance(bench: str) -> float:
    raw = os.environ.get(f"BENCH_{bench.upper()}_TOL")
    if raw is None:
        return DEFAULT_TOL
    try:
        return float(raw)
    except ValueError:
        print(f"trajectory: bad BENCH_{bench.upper()}_TOL={raw!r}, "
              f"using {DEFAULT_TOL}", file=sys.stderr)
        return DEFAULT_TOL


def check() -> int:
    doc = _load_trajectory()
    if not doc["series"]:
        print("trajectory: no committed baseline — nothing to check "
              "(run aggregate first)")
        return 0
    baseline = doc["series"][-1]
    base_benches = baseline.get("benches", {})
    current = _load_benches()
    failures: List[str] = []
    checked = 0
    for bench, metrics in GATED_METRICS.items():
        cur = current.get(bench)
        base = base_benches.get(bench)
        if cur is None:
            print(f"trajectory: {bench}: no fresh BENCH_{bench}.json — "
                  f"skipped", file=sys.stderr)
            continue
        if base is None:
            continue                    # new bench: gates start next PR
        tol = _tolerance(bench)
        for path, direction in metrics:
            if path not in base:
                continue                # new metric: gates start next PR
            if path not in cur:
                failures.append(
                    f"{bench}.{path}: present in baseline but missing "
                    f"from the fresh run")
                continue
            b, c = base[path], cur[path]
            checked += 1
            if direction == "higher":
                limit = b * (1.0 - tol)
                bad = c < limit
                drift = f">= {limit:.4g} (baseline {b:.4g} -{tol:.0%})"
            else:
                limit = b * (1.0 + tol)
                bad = c > limit
                drift = f"<= {limit:.4g} (baseline {b:.4g} +{tol:.0%})"
            if bad:
                failures.append(
                    f"{bench}.{path}: {c:.4g} regressed past {drift}")
    if failures:
        print("trajectory: GATED METRIC REGRESSION")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"trajectory: {checked} gated metrics within tolerance of "
          f"baseline label={baseline.get('label')}")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--check", action="store_true",
                        help="gate fresh BENCH_*.json files against the "
                             "committed baseline instead of aggregating")
    parser.add_argument("--label", default=None,
                        help="snapshot label (default: git rev-list "
                             "--count HEAD)")
    args = parser.parse_args(argv)
    if args.check:
        return check()
    return aggregate(args.label)


if __name__ == "__main__":
    sys.exit(main())
