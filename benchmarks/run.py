"""Benchmark harness — one function per paper table/claim.

Prints ``name,us_per_call,derived`` CSV rows.  The headline paper claims
reproduced here:

  * §2.3 batching: "up to 7x speedup for chat-completion map functions"
    -> bench_batching_chat_api (simulated per-request API latency, the
       paper's setting) and bench_batching_chat_local (real JAX provider —
       the TPU-native setting; speedup from dispatch amortisation)
  * §2.3 batching: "48x for embedding functions"
    -> bench_batching_embedding
  * §2.3 caching / dedup -> bench_caching, bench_dedup
  * async provider scheduler -> bench_scheduler (wall-clock vs
    max_concurrency on a latency-simulating MockProvider; emits
    machine-readable BENCH_scheduler.json next to this file)
  * speculative filter-chain dispatch -> bench_speculative (3-filter
    chain: k serial round-trips collapse to ~1, wasted requests within
    the selectivity-predicted budget, calibrated explain() wall-clock
    estimate within tolerance of measured; emits BENCH_speculative.json)
  * cross-node batch co-packing -> bench_copack (two map nodes sharing
    a metaprompt prefix: part-filled tail batches merge, mean batch
    fill strictly higher / requests strictly lower, bit-identical rows,
    packed wall-clock <= unpacked (deadline-aware last-tail-out flush);
    plus the calibration-aware headroom loop: observed overflow retries
    shrink the next session's planned batches; emits BENCH_copack.json)
  * first-class retrieval operators -> bench_rag (two-query hybrid
    plan: fewer embed requests from co-packing + IndexStore reuse,
    rows bit-identical to the imperative composition, retrieval cost
    in explain(), packed session wall-clock <= isolated sessions;
    emits BENCH_rag.json)
  * million-document retrieval -> bench_ann (100k-doc synthetic corpus:
    exact jnp scan vs Pallas-routed block-max scan vs IVF-ANN wall-clock
    + measured recall@10; incremental append embeds ONLY the delta vs a
    from-scratch rebuild — request/tuple counts asserted; emits
    BENCH_ann.json, recall gated by BENCH_ANN_RECALL_MIN)
  * Query 3 hybrid search -> bench_hybrid_search
  * serving engine -> bench_continuous_batching
  * kernels -> bench_kernel_* (interpret-mode correctness-path timing; the
    real perf story is the dry-run roofline in EXPERIMENTS.md)
"""

from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path

import numpy as np


def _row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _timeit(fn, n=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


# ---------------------------------------------------------------------------
def bench_batching_chat_api():
    """Paper setting: each request pays API overhead; batching packs tuples."""
    from repro.core import MockProvider, SemanticContext, llm_complete
    rows = [{"review": f"review text number {i} with some body"}
            for i in range(200)]
    model = {"model": "gpt-4o-mini", "context_window": 8192,
             "max_output_tokens": 8}
    times = {}
    for on in (False, True):
        # latency constants calibrated to the paper's API regime (~30 ms
        # request overhead, ~200 us/token service time): per-tuple work
        # ~4.6 ms vs 30 ms overhead -> ~7x from batching, the paper's
        # headline number
        ctx = SemanticContext(
            provider=MockProvider(latency_per_call_s=0.030,
                                  latency_per_token_s=0.0002),
            enable_batching=on, enable_cache=False, enable_dedup=False)
        dt = _timeit(lambda c=ctx: llm_complete(c, model,
                                                {"prompt": "classify"},
                                                rows), n=1, warmup=0)
        times[on] = dt
    speedup = times[False] / times[True]
    _row("batching_chat_api_off", times[False] * 1e6 / len(rows),
         f"requests={200}")
    _row("batching_chat_api_on", times[True] * 1e6 / len(rows),
         f"speedup={speedup:.1f}x(paper:7x)")
    return speedup


def bench_batching_chat_local():
    """TPU-native setting: real JAX provider; batching amortises dispatch."""
    from repro.core import SemanticContext, llm_complete
    from repro.core.provider import LocalJaxProvider
    rows = [{"t": f"row {i}"} for i in range(24)]
    model = {"model": "local", "context_window": 4096,
             "max_output_tokens": 2}
    prov = LocalJaxProvider("olmo-1b")
    times = {}
    for on in (False, True):
        ctx = SemanticContext(provider=prov, enable_batching=on,
                              enable_cache=False, enable_dedup=False)
        dt = _timeit(lambda c=ctx: llm_complete(
            c, model, {"prompt": "classify"}, rows), n=1, warmup=1)
        times[on] = dt
    _row("batching_chat_local", times[True] * 1e6 / len(rows),
         f"speedup={times[False]/times[True]:.1f}x")
    return times[False] / times[True]


def bench_batching_embedding():
    """Paper: 48x for embedding functions.  Real JAX embed path."""
    from repro.core import SemanticContext, llm_embedding
    from repro.core.provider import LocalJaxProvider
    texts = [f"passage number {i} about joins" for i in range(64)]
    model = {"model": "local-embed", "context_window": 4096}
    prov = LocalJaxProvider("olmo-1b")
    times = {}
    for on in (False, True):
        ctx = SemanticContext(provider=prov, enable_batching=on,
                              enable_cache=False, enable_dedup=False)
        dt = _timeit(lambda c=ctx: llm_embedding(c, model, texts),
                     n=1, warmup=1)
        times[on] = dt
    _row("batching_embedding", times[True] * 1e6 / len(texts),
         f"speedup={times[False]/times[True]:.1f}x(paper:48x)")
    return times[False] / times[True]


def bench_optimizer():
    """Cost-based plan rewriting (pushdown + fusion): naive vs optimized
    request/token counts on a 1k-row filter+complete+limit workload."""
    from repro.core import MockProvider, SemanticContext
    from repro.engine import Pipeline, Table

    n = 1000
    table = Table({
        "id": list(range(n)),
        "text": [f"review {i} about {'joins' if i % 4 else 'indexes'} "
                 f"with a reasonably long body of text" for i in range(n)],
        "year": [2000 + i % 25 for i in range(n)],
    })
    model = {"model": "m", "context_window": 4096, "max_output_tokens": 8}

    def make(ctx):
        return (Pipeline(ctx, table, "reviews")
                .llm_filter(model, {"prompt": "is about joins"}, ["text"])
                .llm_complete("summary", model, {"prompt": "summarize"},
                              ["text"])
                .llm_complete_json("meta", model, {"prompt": "extract"},
                                   ["text"])
                .order_by("year", desc=True)
                .limit(10))

    stats = {}
    for optimize in (False, True):
        ctx = SemanticContext(provider=MockProvider(), enable_cache=False,
                              enable_dedup=False)
        pipe = make(ctx)
        t0 = time.perf_counter()
        pipe.collect(optimize=optimize)
        dt = time.perf_counter() - t0
        est = (pipe._plan().optimized_cost if optimize
               else pipe._plan().naive_cost)
        stats[optimize] = (ctx.provider.stats.calls,
                           ctx.provider.stats.prompt_tokens, est, dt)
    req_n, tok_n, est_n, dt_n = stats[False]
    req_o, tok_o, est_o, dt_o = stats[True]
    _row("optimizer_naive", dt_n * 1e6 / n,
         f"requests={req_n} prompt_tokens={tok_n} est[{est_n}]")
    _row("optimizer_optimized", dt_o * 1e6 / n,
         f"requests={req_o} prompt_tokens={tok_o} est[{est_o}]")
    assert req_o < req_n and tok_o < tok_n, \
        "optimized plan must issue strictly fewer requests and tokens"
    assert est_o.requests < est_n.requests
    assert est_o.tokens < est_n.tokens
    _row("optimizer_reduction", 0.0,
         f"requests={req_n/max(req_o,1):.1f}x tokens={tok_n/max(tok_o,1):.1f}x")
    return req_n / max(req_o, 1)


def bench_scheduler():
    """Async provider scheduler: wall-clock vs max_concurrency on a
    multi-node plan over a latency-simulating MockProvider.  Results,
    request counts and token counts must be identical to the serial
    path — only the wall-clock may change (near-linearly with the
    concurrency limit, until the batch count per node caps the overlap).
    """
    from repro.core import MockProvider, RequestScheduler, SemanticContext
    from repro.engine import Pipeline, Table

    # 50 ms per request keeps dispatch overhead a small fraction of the
    # measured time, so the >=3x gate at concurrency 4 has real headroom
    # (ideal is 4x; thread wakeup costs eat ~1-3 ms per request)
    latency = 0.05
    n = 72
    table = Table({
        "text": [f"review number {i} with a moderately sized body of "
                 f"text to fill the context window" for i in range(n)],
    })
    # small window -> ~8 batches per node; 3 independent map nodes
    base = {"model": "m", "context_window": 700, "max_output_tokens": 8}

    def run(concurrency):
        sched = (RequestScheduler() if concurrency else None)
        model = dict(base, max_concurrency=concurrency or 1)
        ctx = SemanticContext(provider=MockProvider(
            latency_per_call_s=latency), scheduler=sched,
            enable_cache=False, enable_dedup=False)
        pipe = (Pipeline(ctx, table, "reviews")
                .llm_complete("summary", model, {"prompt": "summarize"},
                              ["text"])
                .llm_complete("topic", model, {"prompt": "name the topic"},
                              ["text"])
                .llm_complete_json("meta", model, {"prompt": "extract"},
                                   ["text"]))
        t0 = time.perf_counter()
        out = pipe.collect(optimize=False)
        dt = time.perf_counter() - t0
        if sched is not None:
            sched.shutdown()
        return (dt, out.rows(), ctx.provider.stats.calls,
                ctx.provider.stats.prompt_tokens)

    t_sync, rows_sync, req_sync, tok_sync = run(None)
    results = {"latency_per_call_s": latency, "rows": n, "nodes": 3,
               "sync": {"wall_s": round(t_sync, 4), "requests": req_sync,
                        "prompt_tokens": tok_sync}}
    for c in (1, 4, 16):
        dt, rows, req, tok = run(c)
        assert rows == rows_sync, "scheduled results differ from serial"
        assert (req, tok) == (req_sync, tok_sync), \
            f"request/token counts changed at concurrency {c}: " \
            f"{(req, tok)} != {(req_sync, tok_sync)}"
        results[f"concurrency_{c}"] = {
            "wall_s": round(dt, 4), "requests": req,
            "prompt_tokens": tok, "speedup": round(t_sync / dt, 2)}
        _row(f"scheduler_c{c}", dt * 1e6 / n,
             f"speedup={t_sync/dt:.1f}x requests={req}")
    speedup4 = results["concurrency_4"]["speedup"]
    out_path = Path(__file__).resolve().parent / "BENCH_scheduler.json"
    out_path.write_text(json.dumps(results, indent=1))
    # BENCH_SCHEDULER_MIN_SPEEDUP relaxes the gate on oversubscribed CI
    # runners where thread wakeups stretch past the simulated latency
    floor = float(os.environ.get("BENCH_SCHEDULER_MIN_SPEEDUP", "3.0"))
    assert speedup4 >= floor, \
        f"expected >={floor}x wall-clock reduction at max_concurrency=4, " \
        f"got {speedup4:.1f}x"
    _row("scheduler_sync", t_sync * 1e6 / n,
         f"requests={req_sync} json={out_path.name}")
    return speedup4


def bench_speculative():
    """Speculative filter-chain dispatch: a 3-filter llm_filter chain
    over a latency-simulating MockProvider, serial vs speculative.

    Serial chain execution pays one provider round-trip per member
    (each filter waits for its predecessor's survivors); speculation
    fans all members out over the chain input concurrently and ANDs
    the masks, collapsing the chain's critical path to ~1 round-trip.
    Asserts:

      * surviving rows are identical serial vs speculative,
      * the planner CHOOSES speculation from the calibrated cost model
        (a warmup run records selectivity + latency statistics),
      * measured wasted requests stay within the selectivity-predicted
        budget reported by explain(),
      * explain()'s calibrated wall-clock estimate for the speculative
        plan is within tolerance of the measured wall-clock,
      * speculative wall-clock beats serial by the configured floor.

    Two further scenarios exercise the cross-operator speculation
    shapes under ``speculate="auto"``:

      * **filter->map**: an ``llm_complete`` downstream of a 0.5
        selectivity ``llm_filter`` dispatches over the filter's full
        input concurrently with the mask; gated on
        ``wall_spec <= BENCH_SPEC_WALL_TOL x wall_serial``
        (default 0.6),
      * **retrieval->rerank**: an ``llm_rerank`` downstream of
        ``hybrid_topk`` warms its window cache over the BM25-predicted
        candidates while the dense embeds run; the corpus is crafted so
        the BM25 and fused orders agree (asserted as a precondition),
        gated on ``wall_spec <= BENCH_SPEC_RERANK_WALL_TOL x
        wall_serial`` (default 0.9).
    """
    import re as _re

    from repro.core import MockProvider, RequestScheduler, SemanticContext
    from repro.engine import Pipeline, Table

    # big enough that dispatch/GIL overhead (tens of ms across the
    # 12-request fan-out) stays a small fraction of each round-trip
    latency = 0.25
    n = 96

    def behaviour(kind, prefix, rows):
        # deterministic, content-based verdicts with known selectivity:
        # a filter prompt "contains <marker>" passes rows whose text
        # carries the marker
        if kind != "filter":
            return None
        marker = _re.search(r"contains (\w+)", prefix).group(1)
        return [f"{i}: {'true' if marker in r else 'false'}"
                for i, r in enumerate(rows)]

    table = Table({"text": [
        f"doc {i} {'alpha' if i % 3 else 'x'} "
        f"{'beta' if i % 2 == 0 else 'y'} "
        f"{'gamma' if i % 4 < 2 else 'z'} with a body of text"
        for i in range(n)]})

    # three DISTINCT models: semantic fusion would otherwise merge the
    # chain into one multi-task pass (same model + cols), and distinct
    # models fan out on independent concurrency gates
    def model(k):
        return {"model": f"spec-m{k}", "context_window": 100_000,
                "max_output_tokens": 8, "max_concurrency": 16}

    def build(ctx):
        return (Pipeline(ctx, table, "docs")
                .llm_filter(model(1), {"prompt": "contains alpha"},
                            ["text"])
                .llm_filter(model(2), {"prompt": "contains beta"},
                            ["text"])
                .llm_filter(model(3), {"prompt": "contains gamma"},
                            ["text"]))

    with RequestScheduler() as sched:
        ctx = SemanticContext(
            provider=MockProvider(behaviour, latency_per_call_s=latency),
            scheduler=sched, enable_cache=False, enable_dedup=False,
            max_batch=24)
        # warmup: records per-prompt selectivity and per-model latency
        # calibration — the statistics the speculation decision needs
        build(ctx).collect(speculate=False)

        c0 = ctx.provider.stats.calls
        t0 = time.perf_counter()
        rows_serial = build(ctx).collect(speculate=False).rows()
        dt_serial = time.perf_counter() - t0
        req_serial = ctx.provider.stats.calls - c0

        pipe = build(ctx)
        t0 = time.perf_counter()
        rows_spec = pipe.collect(speculate=True).rows()
        dt_spec = time.perf_counter() - t0
        req_spec = ctx.provider.stats.calls - c0 - req_serial

    assert rows_spec == rows_serial, \
        "speculative chain changed the surviving tuple stream"
    plan = pipe._plan(True)
    decisions = [d for d in plan.spec_decisions if d.chosen]
    assert decisions, "planner did not choose speculation: " + "; ".join(
        str(d) for d in plan.spec_decisions)
    d = decisions[0]
    wasted = req_spec - req_serial
    assert wasted <= d.wasted_requests, \
        f"measured waste {wasted} exceeds the selectivity-predicted " \
        f"budget {d.wasted_requests}"

    est_wall = plan.optimized_cost.wall_s
    assert est_wall > 0, "cost model stayed uncalibrated after warmup"
    est_err = abs(est_wall - dt_spec) / dt_spec
    # gates relaxable on oversubscribed CI runners (thread wakeups
    # stretch past the simulated provider latency)
    tol = float(os.environ.get("BENCH_SPECULATIVE_EST_TOL", "0.25"))
    floor = float(os.environ.get("BENCH_SPECULATIVE_MIN_SPEEDUP", "1.8"))
    speedup = dt_serial / dt_spec

    # -- scenario 2: map past filter at selectivity 0.5 -----------------
    table2 = Table({"text": [
        f"doc {i} {'alpha' if i % 2 == 0 else 'omega'} "
        f"with a body of text" for i in range(n)]})
    map_model = {"model": "spec-map", "context_window": 100_000,
                 "max_output_tokens": 16, "max_concurrency": 16}

    def build_map(ctx):
        return (Pipeline(ctx, table2, "docs")
                .llm_filter(model(1), {"prompt": "contains alpha"},
                            ["text"])
                .llm_complete("summary", map_model,
                              {"prompt": "summarize"}, ["text"]))

    with RequestScheduler() as sched:
        ctx = SemanticContext(
            provider=MockProvider(behaviour, latency_per_call_s=latency),
            scheduler=sched, enable_cache=False, enable_dedup=False,
            max_batch=24)
        # warmup: records the 0.5 mask density and per-model latency
        build_map(ctx).collect(speculate=False)

        c0 = ctx.provider.stats.calls
        t0 = time.perf_counter()
        rows_m_serial = build_map(ctx).collect(speculate=False).rows()
        dt_m_serial = time.perf_counter() - t0
        req_m_serial = ctx.provider.stats.calls - c0

        pipe_m = build_map(ctx)
        t0 = time.perf_counter()
        rows_m_spec = pipe_m.collect(speculate="auto").rows()
        dt_m_spec = time.perf_counter() - t0
        req_m_spec = ctx.provider.stats.calls - c0 - req_m_serial
        cancelled = sched.stats.spec_cancelled

    assert rows_m_spec == rows_m_serial, \
        "speculative map changed the output tuple stream"
    plan_m = pipe_m._plan("auto")
    dm = [x for x in plan_m.spec_decisions
          if x.kind == "map" and x.chosen]
    assert dm, "planner did not choose map speculation: " + "; ".join(
        str(x) for x in plan_m.spec_decisions)
    wasted_m = req_m_spec - req_m_serial
    assert wasted_m <= dm[0].wasted_requests, \
        f"measured map waste {wasted_m} exceeds the predicted budget " \
        f"{dm[0].wasted_requests}"
    wall_tol = float(os.environ.get("BENCH_SPEC_WALL_TOL", "0.6"))
    _row("speculative_map_serial", dt_m_serial * 1e6 / n,
         f"requests={req_m_serial}")
    _row("speculative_map_spec", dt_m_spec * 1e6 / n,
         f"requests={req_m_spec} wasted={wasted_m} "
         f"cancelled={cancelled} "
         f"speedup={dt_m_serial / dt_m_spec:.1f}x")

    # -- scenario 3: retrieval-aware rerank -----------------------------
    # the corpus is crafted (per-doc salts searched offline) so the
    # mock embedding similarities RANK the matching docs in the same
    # order as their BM25 term-frequency scores: the fused top-k then
    # equals the BM25-predicted top-k and warmup window-cache entries
    # byte-match the authoritative rerank's windows
    k_rr, cand_rr = 6, 12
    docs_rr = [
        "join algorithms " * (k_rr - i) + f"candidate document {i} s{s}"
        for i, s in enumerate((0, 91, 9, 41, 51, 1))
    ] + [
        f"unrelated storage passage number {i} s{s}"
        for i, s in zip(range(6, 24),
                        (1, 3, 0, 0, 0, 0, 0, 0, 0, 1, 1, 0,
                         0, 0, 0, 0, 2, 1))
    ]
    docs_rr = [t.strip() for t in docs_rr]
    corpus_rr = Table({"content": docs_rr})
    queries_rr = Table({"q": ["join algorithms"], "qid": [0]})
    emb_model = {"model": "spec-emb", "embedding_dim": 16,
                 "context_window": 4096}
    rr_model = {"model": "spec-rr", "context_window": 100_000,
                "max_output_tokens": 16, "max_concurrency": 8}

    def build_rr(ctx):
        return (Pipeline(ctx, queries_rr, "queries")
                .hybrid_topk("score", emb_model, "q", corpus_rr,
                             k=k_rr, doc_col="content",
                             candidate_k=cand_rr)
                .llm_rerank(rr_model, {"prompt": "most relevant"},
                            ["content"], by="q"))

    # precondition: BM25 order must match the fused order, else the
    # warmup cannot hit and the scenario silently degrades to serial
    pre = (Pipeline(SemanticContext(provider=MockProvider()),
                    queries_rr, "queries")
           .hybrid_topk("score", emb_model, "q", corpus_rr, k=k_rr,
                        doc_col="content", candidate_k=cand_rr)
           .collect(speculate=False))
    assert [r["content"] for r in pre.rows()] == docs_rr[:k_rr], \
        "crafted corpus drifted: fused top-k no longer equals the " \
        "BM25 prediction (re-search the per-doc salts)"

    def run_rr(speculate):
        with RequestScheduler() as sched:
            ctx = SemanticContext(
                provider=MockProvider(latency_per_call_s=latency),
                scheduler=sched, speculate=speculate)
            pipe = build_rr(ctx)
            t0 = time.perf_counter()
            out = pipe.collect()
            dt = time.perf_counter() - t0
            return out.rows(), dt, pipe

    rows_r_serial, dt_r_serial, _ = run_rr(False)
    rows_r_spec, dt_r_spec, pipe_r = run_rr("auto")
    assert rows_r_spec == rows_r_serial, \
        "speculative rerank changed the reranked tuple stream"
    assert any(nd.op == "spec_rerank"
               for nd in pipe_r._executed_nodes), \
        "planner did not choose rerank speculation"
    rr_tol = float(os.environ.get("BENCH_SPEC_RERANK_WALL_TOL", "0.9"))
    _row("speculative_rerank_serial", dt_r_serial * 1e6,
         f"k={k_rr} candidate_k={cand_rr}")
    _row("speculative_rerank_spec", dt_r_spec * 1e6,
         f"overlap={1 - dt_r_spec / dt_r_serial:.0%}")

    results = {
        "latency_per_call_s": latency, "rows": n, "chain": 3,
        "serial": {"wall_s": round(dt_serial, 4), "requests": req_serial,
                   "waves_est": d.serial_waves,
                   "wall_est_s": round(d.serial_wall_s, 4)},
        "speculative": {"wall_s": round(dt_spec, 4),
                        "requests": req_spec,
                        "waves_est": d.spec_waves,
                        "wall_est_s": round(est_wall, 4)},
        "wasted_requests": wasted,
        "wasted_budget": d.wasted_requests,
        "speedup": round(speedup, 2),
        "est_wall_error": round(est_err, 3),
        # cross-operator scenarios (picked up by TRAJECTORY.json)
        "wall_serial_s": round(dt_m_serial, 4),
        "wall_spec_s": round(dt_m_spec, 4),
        "spec_cancelled": cancelled,
        "filter_map": {
            "selectivity": 0.5,
            "wall_serial_s": round(dt_m_serial, 4),
            "wall_spec_s": round(dt_m_spec, 4),
            "requests_serial": req_m_serial,
            "requests_spec": req_m_spec,
            "wasted_requests": wasted_m,
            "wasted_budget": dm[0].wasted_requests,
            "spec_cancelled": cancelled,
            "wall_ratio": round(dt_m_spec / dt_m_serial, 3),
        },
        "rerank": {
            "wall_serial_s": round(dt_r_serial, 4),
            "wall_spec_s": round(dt_r_spec, 4),
            "wall_ratio": round(dt_r_spec / dt_r_serial, 3),
            "overlap": round(1 - dt_r_spec / dt_r_serial, 3),
        },
    }
    out_path = Path(__file__).resolve().parent / "BENCH_speculative.json"
    out_path.write_text(json.dumps(results, indent=1))

    _row("speculative_serial", dt_serial * 1e6 / n,
         f"requests={req_serial} waves={d.serial_waves}")
    _row("speculative_spec", dt_spec * 1e6 / n,
         f"requests={req_spec} waves={d.spec_waves} "
         f"speedup={speedup:.1f}x wasted={wasted}/{d.wasted_requests} "
         f"json={out_path.name}")
    _row("speculative_estimate", est_wall * 1e6,
         f"est_wall_error={est_err:.1%}")
    assert est_err <= tol, \
        f"calibrated wall estimate {est_wall:.3f}s is {est_err:.0%} " \
        f"off measured {dt_spec:.3f}s (tolerance {tol:.0%})"
    assert speedup >= floor, \
        f"expected >={floor}x wall-clock reduction from speculation, " \
        f"got {speedup:.1f}x"
    assert dt_m_spec <= wall_tol * dt_m_serial, \
        f"filter->map speculative wall {dt_m_spec:.3f}s exceeds " \
        f"{wall_tol:.2f}x serial wall {dt_m_serial:.3f}s"
    assert dt_r_spec <= rr_tol * dt_r_serial, \
        f"retrieval->rerank speculative wall {dt_r_spec:.3f}s shows " \
        f"no overlap vs serial {dt_r_serial:.3f}s " \
        f"(tolerance {rr_tol:.2f}x)"
    return speedup


def bench_copack():
    """Cross-node batch co-packing: two map nodes sharing one metaprompt
    prefix (same model + prompt + kind over different columns) dispatch
    concurrently; with co-packing their part-filled tail batches merge
    into one provider request.  Asserts:

      * collected rows are bit-identical with co-packing on vs off,
      * total provider requests are strictly LOWER with co-packing on,
      * mean dispatched batch fill (tuples per request) is strictly
        HIGHER with co-packing on,
      * explain() reports the packed request estimate (packed_req <
        requests).

    Also measures the calibration-aware headroom loop on a tight-window
    workload: session 1 overflows (token estimates undercount the
    serialization framing) and records retries; session 2 loads the
    calibration sidecar, plans with headroom, and pays fewer
    split-and-requeue retries.
    """
    import tempfile

    from repro.core import (MockProvider, PredictionCache,
                            RequestScheduler, SemanticContext,
                            llm_complete)
    from repro.engine import Pipeline, Table

    n = 60
    max_batch = 24          # 60 rows -> [24, 24, 12]: part-filled tail
    table = Table({
        "a": [f"first column text number {i} with a body of text"
              for i in range(n)],
        "b": [f"second column text number {i} with a body of text"
              for i in range(n)],
    })
    model = {"model": "cp", "context_window": 100_000,
             "max_output_tokens": 8, "max_concurrency": 8}

    def build(ctx):
        return (Pipeline(ctx, table, "docs")
                .llm_complete("s1", model, {"prompt": "summarize"}, ["a"])
                .llm_complete("s2", model, {"prompt": "summarize"},
                              ["b"]))

    runs = {}
    explain_text = None
    packed_est = None
    for copack in (False, True):
        with RequestScheduler(pack_linger_s=0.5) as sched:
            ctx = SemanticContext(
                provider=MockProvider(latency_per_call_s=0.01),
                scheduler=sched, max_batch=max_batch, copack=copack)
            pipe = build(ctx)
            t0 = time.perf_counter()
            rows = pipe.collect(optimize=False).rows()
            dt = time.perf_counter() - t0
            tuples = sum(sum(r.batch_sizes) for r in ctx.reports)
            runs[copack] = {
                "rows": rows, "wall_s": dt,
                "requests": ctx.provider.stats.calls,
                "tuples_dispatched": tuples,
                "mean_fill": tuples / max(ctx.provider.stats.calls, 1),
                "packed_requests": sched.stats.packed_requests,
                "packed_batches": sched.stats.packed_batches,
            }
            if copack:
                explain_text = pipe.explain()
                plan = pipe._plan()
                packed_est = plan.optimized_cost.packed_requests
                est_requests = plan.optimized_cost.requests

    off, on = runs[False], runs[True]
    assert on["rows"] == off["rows"], \
        "co-packing changed the collected rows"
    assert on["requests"] < off["requests"], \
        f"expected strictly fewer requests with co-packing, got " \
        f"{on['requests']} vs {off['requests']}"
    assert on["mean_fill"] > off["mean_fill"], \
        f"expected strictly denser batches with co-packing, got " \
        f"{on['mean_fill']:.2f} vs {off['mean_fill']:.2f}"
    assert packed_est and packed_est < est_requests, \
        "explain() must report a packed request estimate below the " \
        "unpacked one"
    assert "packed_req=" in explain_text
    assert "Objectives:" in explain_text and "latency:" in explain_text \
        and "cost:" in explain_text, \
        "explain() must report both objective frontiers"

    # the packed path must also be the fast path: last-tail-out flushes
    # make co-packing free on wall-clock (tolerance for runner noise)
    wall_tol = float(os.environ.get("BENCH_COPACK_WALL_TOL", "1.10"))
    assert on["wall_s"] <= off["wall_s"] * wall_tol, \
        f"co-packing regressed wall-clock: {on['wall_s']:.3f}s packed " \
        f"vs {off['wall_s']:.3f}s unpacked (tolerance {wall_tol}x)"

    # calibration-aware headroom: overflow retries feed back into the
    # planner as a smaller budget the NEXT session
    with tempfile.TemporaryDirectory() as td:
        cache_path = f"{td}/cache.jsonl"
        tight = {"model": "tight", "context_window": 260,
                 "max_output_tokens": 2}
        retries = []
        for tag in ("alpha", "beta"):
            ctx = SemanticContext(
                cache=PredictionCache(persist_path=cache_path),
                provider=MockProvider(), enable_dedup=False)
            with ctx:
                llm_complete(ctx, tight, {"prompt": "p"},
                             [{"t": f"{tag} row {i} and padding {i}"}
                              for i in range(48)])
            retries.append(ctx.last_report().retries)
    assert retries[0] > 0 and retries[1] < retries[0], \
        f"headroom did not reduce overflow retries: {retries}"

    results = {
        "rows": n, "nodes": 2, "max_batch": max_batch,
        "copack_off": {k: v for k, v in off.items() if k != "rows"},
        "copack_on": {k: v for k, v in on.items() if k != "rows"},
        "packed_request_estimate": packed_est,
        "wall_packed_s": round(on["wall_s"], 4),
        "wall_unpacked_s": round(off["wall_s"], 4),
        "headroom": {"session1_retries": retries[0],
                     "session2_retries": retries[1]},
    }
    for r in (results["copack_off"], results["copack_on"]):
        r["wall_s"] = round(r["wall_s"], 4)
        r["mean_fill"] = round(r["mean_fill"], 2)
    out_path = Path(__file__).resolve().parent / "BENCH_copack.json"
    out_path.write_text(json.dumps(results, indent=1))

    _row("copack_off", off["wall_s"] * 1e6 / n,
         f"requests={off['requests']} fill={off['mean_fill']:.1f}")
    _row("copack_on", on["wall_s"] * 1e6 / n,
         f"requests={on['requests']} fill={on['mean_fill']:.1f} "
         f"packed_req_est={packed_est} json={out_path.name}")
    _row("copack_headroom", 0.0,
         f"retries_session1={retries[0]} retries_session2={retries[1]}")
    return off["requests"] / on["requests"]


def bench_rag():
    """First-class retrieval operators (paper Query 3 as a PLAN): a
    two-query hybrid workload — ``hybrid_topk`` -> ``llm_rerank`` per
    query over one corpus — run two ways:

      * OFF: per-query isolated session, no co-packing, no index store
        (the imperative pre-PR posture: every query re-embeds the
        corpus, corpus and query embeds ship separately);
      * ON: one session with the concurrent scheduler, embed co-packing
        and the ``IndexStore`` sidecar (query 1 builds the index and
        merges its corpus tail batch with the query embed; query 2
        reuses the index and embeds only the query).

    Asserts:

      * retrieved+reranked rows are bit-identical ON vs OFF and vs the
        imperative BM25Index/VectorIndex/fusion/llm_rerank composition,
      * provider embed requests are strictly FEWER with co-packing +
        index reuse ON,
      * ``explain()`` reports the retrieval cost: per-node embed request
        estimate (``req=``), the co-packed estimate (``packed_req=``)
        and the index-scan cost (``scan_flops=``).
    """
    import tempfile

    from repro.core import (MockProvider, RequestScheduler,
                            SemanticContext, llm_embedding, llm_rerank,
                            rrf)
    from repro.engine import Pipeline, Table
    from repro.retrieval import BM25Index, VectorIndex

    n_docs = 80
    topics = ("joins", "indexes", "vectors", "storage")
    corpus = Table({
        "content": [f"passage {i} about {topics[i % 4]} with a body of "
                    f"searchable text" for i in range(n_docs)],
        "kind": [topics[i % 4] for i in range(n_docs)],
    })
    queries = ["cyclic join algorithms", "vector index scans"]
    k, c = 5, 12
    # ~16-token docs at a 600-token window: the corpus plans two full
    # embed batches plus a part-filled tail that can merge with the
    # (tiny) query embed batch
    emb = {"model": "emb", "embedding_dim": 32, "context_window": 600,
           "max_concurrency": 8}
    chat = {"model": "chat", "context_window": 8192,
            "max_output_tokens": 16}

    def build(ctx, query):
        return (Pipeline(ctx, Table({"q": [query]}), "question")
                .hybrid_topk("score", emb, "q", corpus, k=k,
                             doc_col="content", candidate_k=c)
                .llm_rerank(chat, {"prompt": "most relevant to the "
                                             "question"},
                            ["content"], by="q"))

    def embed_requests(ctx):
        return sum(r.requests for r in ctx.reports
                   if r.function == "embedding")

    # OFF: isolated per-query sessions, serial, no index store
    rows_off, req_off = [], 0
    t0 = time.perf_counter()
    for q in queries:
        ctx = SemanticContext(provider=MockProvider(),
                              enable_cache=False, copack=False)
        rows_off.append(build(ctx, q).collect().rows())
        req_off += embed_requests(ctx)
    dt_off = time.perf_counter() - t0

    # ON: one session — scheduler + co-packing + IndexStore sidecar
    rows_on, per_query_req = [], []
    explain_text = None
    packed_est = est_requests = None
    with tempfile.TemporaryDirectory() as td:
        with RequestScheduler(pack_linger_s=0.5) as sched:
            ctx = SemanticContext(provider=MockProvider(),
                                  scheduler=sched, enable_cache=False,
                                  index_path=f"{td}/index.json")
            t0 = time.perf_counter()
            for qi, q in enumerate(queries):
                before = embed_requests(ctx)
                pipe = build(ctx, q)
                rows_on.append(pipe.collect().rows())
                per_query_req.append(embed_requests(ctx) - before)
                if qi == 0:
                    explain_text = pipe.explain()
                    plan = pipe._plan()
                    packed_est = plan.optimized_cost.packed_requests
                    est_requests = plan.optimized_cost.requests
                    scan_est = plan.optimized_cost.scan_flops
            dt_on = time.perf_counter() - t0
            req_on = sum(per_query_req)
            packed_batches = sched.stats.packed_batches

    assert rows_on == rows_off, \
        "co-packing + index reuse changed the retrieved rows"
    assert req_on < req_off, \
        f"expected strictly fewer embed requests, got {req_on} vs " \
        f"{req_off}"
    assert per_query_req[1] < per_query_req[0], \
        "index reuse did not reduce the second query's embed requests"
    assert packed_est and packed_est < est_requests, \
        "explain() must report a packed embed-request estimate below " \
        "the unpacked one"
    assert "packed_req=" in explain_text
    assert "scan_flops=" in explain_text
    assert scan_est > 0
    assert "Objectives:" in explain_text, \
        "explain() must report both objective frontiers"

    # latency contract: the packed session (co-packing + index reuse)
    # must not be slower than the isolated per-query sessions
    wall_tol = float(os.environ.get("BENCH_RAG_WALL_TOL", "1.10"))
    assert dt_on <= dt_off * wall_tol, \
        f"packed RAG session regressed wall-clock: {dt_on:.3f}s packed " \
        f"vs {dt_off:.3f}s unpacked (tolerance {wall_tol}x)"

    # imperative composition (the pre-PR idiom): same rows, bit for bit
    ictx = SemanticContext(provider=MockProvider(), enable_cache=False)
    texts = [str(x) for x in corpus.column("content")]
    for q, plan_rows in zip(queries, rows_on):
        vi = VectorIndex(llm_embedding(ictx, emb, texts))
        qv = llm_embedding(ictx, emb, [q])
        v_s, v_idx = vi.topk(qv, c)
        bm = BM25Index.build(texts)
        b_scores = bm.score(q)
        b_top = np.argsort(-b_scores, kind="stable")[:c]
        col_b = np.full(n_docs, np.nan)
        col_b[b_top] = b_scores[b_top]
        col_v = np.full(n_docs, np.nan)
        col_v[v_idx[0]] = v_s[0]
        fused = rrf(col_b, col_v)
        top = np.argsort(-fused, kind="stable")[:k]
        perm = llm_rerank(ictx, chat,
                          {"prompt": "most relevant to the question"},
                          [{"content": texts[i]} for i in top])
        imp = [(texts[top[p]], float(fused[top[p]])) for p in perm]
        got = [(r["content"], r["score"]) for r in plan_rows]
        assert got == imp, "plan rows diverge from the imperative " \
                           "composition"

    results = {
        "docs": n_docs, "queries": len(queries), "k": k,
        "candidate_k": c,
        "embed_requests_off": req_off,
        "embed_requests_on": req_on,
        "per_query_embed_requests_on": per_query_req,
        "packed_tail_batches": packed_batches,
        "packed_request_estimate": packed_est,
        "unpacked_request_estimate": est_requests,
        "scan_flops_estimate": scan_est,
        "wall_s_off": round(dt_off, 4), "wall_s_on": round(dt_on, 4),
        "wall_packed_s": round(dt_on, 4),
        "wall_unpacked_s": round(dt_off, 4),
    }
    out_path = Path(__file__).resolve().parent / "BENCH_rag.json"
    out_path.write_text(json.dumps(results, indent=1))

    _row("rag_off", dt_off * 1e6 / n_docs,
         f"embed_requests={req_off}")
    _row("rag_on", dt_on * 1e6 / n_docs,
         f"embed_requests={req_on} second_query="
         f"{per_query_req[1]} packed_est={packed_est} "
         f"json={out_path.name}")
    return req_off / max(req_on, 1)


def bench_ann():
    """Million-document retrieval (ISSUE 7): IVF-ANN vs the exact scan.

    A 100k-doc clustered synthetic corpus (the geometry real embedding
    corpora exhibit), 64 queries, k=10:

      * exact numpy scan (the ``IVFIndex.exact_scan`` scorer — the same
        arithmetic the IVF path shortcuts to at full probing);
      * Pallas-routed ``topk_sim`` block-max scan (``VectorIndex``
        ``use_kernel=True`` path; interpret-mode on CPU hosts);
      * IVF-ANN at the calibrated nprobe for recall target 0.95.

    Asserts measured recall@10 >= ``BENCH_ANN_RECALL_MIN`` (0.95) and
    IVF speedup over exact >= ``BENCH_ANN_MIN_SPEEDUP`` (5.0 — relaxable
    on oversubscribed CI).  Then the incremental-append contract on a
    provider-backed corpus: growing a built index embeds ONLY the delta
    texts (tuple counts asserted), rows bit-identical to a rebuild.
    """
    from repro.core import MockProvider, SemanticContext
    from repro.retrieval import VectorIndex, ensure_index

    n_docs, dim, n_q, k = 100_000, 64, 64, 10
    rng = np.random.default_rng(0)
    centers = rng.standard_normal((64, dim)).astype(np.float32) * 4.0
    labels = rng.integers(0, 64, n_docs)
    vs = (centers[labels]
          + rng.standard_normal((n_docs, dim)).astype(np.float32))
    qs = vs[rng.integers(0, n_docs, n_q)] + 0.05 * rng.standard_normal(
        (n_q, dim)).astype(np.float32)

    index = VectorIndex(vs)
    qn = qs / np.maximum(np.linalg.norm(qs, axis=1, keepdims=True), 1e-9)
    t0 = time.perf_counter()
    ivf = index.ivf()                          # build + calibrate once
    dt_build = time.perf_counter() - t0
    nprobe = ivf.nprobe_for(0.95)

    dt_exact = _timeit(lambda: ivf.exact_scan(qn, k), n=3, warmup=1)
    dt_kernel = _timeit(
        lambda: VectorIndex(vs, use_kernel=True).topk(qs, k), n=1,
        warmup=1)
    dt_ivf = _timeit(lambda: ivf.search(qn, k, nprobe), n=3, warmup=1)

    _, i_exact = ivf.exact_scan(qn, k)
    _, i_ivf = ivf.search(qn, k, nprobe)
    recall = float(np.mean([len(set(a) & set(b)) / k
                            for a, b in zip(i_ivf, i_exact)]))
    speedup = dt_exact / max(dt_ivf, 1e-9)

    recall_min = float(os.environ.get("BENCH_ANN_RECALL_MIN", "0.95"))
    speedup_min = float(os.environ.get("BENCH_ANN_MIN_SPEEDUP", "5.0"))
    assert recall >= recall_min, \
        f"IVF recall@{k} {recall:.3f} below the {recall_min} gate " \
        f"(nprobe={nprobe}/{ivf.nlist})"
    assert speedup >= speedup_min, \
        f"IVF speedup {speedup:.1f}x below the {speedup_min}x gate " \
        f"({dt_exact*1e3:.1f}ms exact vs {dt_ivf*1e3:.1f}ms IVF)"

    # incremental append: only the delta embeds, rows match a rebuild
    texts = [f"passage {i} body {i % 97}" for i in range(600)]
    emb = {"model": "emb", "embedding_dim": 32, "context_window": 4096}

    def embeds(ctx):
        return sum(r.n_tuples for r in ctx.reports
                   if r.function == "embedding")

    ctx = SemanticContext(provider=MockProvider(), enable_cache=False)
    ensure_index(ctx, emb, texts[:500])
    base_embeds = embeds(ctx)
    t0 = time.perf_counter()
    grown, src = ensure_index(ctx, emb, texts)
    dt_append = time.perf_counter() - t0
    append_embeds = embeds(ctx) - base_embeds
    assert src == "appended" and append_embeds == 100, \
        f"append embedded {append_embeds} tuples (want the 100-delta), " \
        f"source={src}"

    ctx2 = SemanticContext(provider=MockProvider(), enable_cache=False)
    t0 = time.perf_counter()
    rebuilt, _ = ensure_index(ctx2, emb, texts)
    dt_rebuild = time.perf_counter() - t0
    rebuild_embeds = embeds(ctx2)
    assert rebuild_embeds == 600
    assert np.array_equal(grown.raw, rebuilt.raw), \
        "appended index diverges from the from-scratch rebuild"

    results = {
        "docs": n_docs, "dim": dim, "queries": n_q, "k": k,
        "nlist": ivf.nlist, "nprobe": nprobe,
        "recall_at_k": round(recall, 4),
        "exact_scan_ms": round(dt_exact * 1e3, 2),
        "pallas_scan_ms": round(dt_kernel * 1e3, 2),
        "ivf_scan_ms": round(dt_ivf * 1e3, 2),
        "ivf_build_s": round(dt_build, 3),
        "ivf_speedup_vs_exact": round(speedup, 2),
        "append_embedded_tuples": append_embeds,
        "rebuild_embedded_tuples": rebuild_embeds,
        "append_wall_s": round(dt_append, 4),
        "rebuild_wall_s": round(dt_rebuild, 4),
    }
    out_path = Path(__file__).resolve().parent / "BENCH_ann.json"
    out_path.write_text(json.dumps(results, indent=1))

    _row("ann_exact_scan", dt_exact * 1e6 / n_q, f"docs={n_docs}")
    _row("ann_pallas_scan", dt_kernel * 1e6 / n_q, "use_kernel=True")
    _row("ann_ivf_scan", dt_ivf * 1e6 / n_q,
         f"recall@{k}={recall:.3f} nprobe={nprobe}/{ivf.nlist} "
         f"speedup={speedup:.1f}x json={out_path.name}")
    _row("ann_incremental_append", dt_append * 1e6,
         f"delta_tuples={append_embeds} rebuild_tuples={rebuild_embeds}")
    return speedup


def bench_caching():
    from repro.core import MockProvider, SemanticContext, llm_complete
    rows = [{"r": f"text {i}"} for i in range(100)]
    model = {"model": "m", "context_window": 8192, "max_output_tokens": 8}
    ctx = SemanticContext(provider=MockProvider(latency_per_call_s=0.02))
    t_cold = _timeit(lambda: llm_complete(ctx, model, {"prompt": "p"},
                                          rows), n=1, warmup=0)
    t_warm = _timeit(lambda: llm_complete(ctx, model, {"prompt": "p"},
                                          rows), n=1, warmup=0)
    _row("caching_cold", t_cold * 1e6 / len(rows), "cache=miss")
    _row("caching_warm", t_warm * 1e6 / len(rows),
         f"speedup={t_cold/max(t_warm,1e-9):.1f}x "
         f"hits={ctx.cache.stats['hits']}")
    return t_cold / max(t_warm, 1e-9)


def bench_dedup():
    from repro.core import MockProvider, SemanticContext, llm_complete
    rows = [{"city": f"city-{i % 7}"} for i in range(210)]
    model = {"model": "m", "context_window": 600, "max_output_tokens": 8}
    calls = {}
    for on in (False, True):
        prov = MockProvider(latency_per_call_s=0.01)
        ctx = SemanticContext(provider=prov, enable_dedup=on,
                              enable_cache=False)
        llm_complete(ctx, model, {"prompt": "p"}, rows)
        calls[on] = ctx.reports[-1].requests
    _row("dedup", 0.0,
         f"requests_no_dedup={calls[False]} requests_dedup={calls[True]} "
         f"reduction={calls[False]/max(calls[True],1):.0f}x")
    return calls[False] / max(calls[True], 1)


def bench_hybrid_search():
    """Paper Query 3 end-to-end over a synthetic passage corpus."""
    from repro.core import SemanticContext, llm_embedding, llm_rerank, rrf
    from repro.retrieval import BM25Index, VectorIndex
    rng = np.random.default_rng(0)
    vocab = ("join algorithm database query index scan hash sort merge "
             "cyclic vector embedding text search rank").split()
    docs = [" ".join(rng.choice(vocab, 12)) for _ in range(2000)]
    ctx = SemanticContext()
    model = {"model": "e", "embedding_dim": 64}

    def pipeline():
        bm = BM25Index.build(docs)
        b_idx, b_s = bm.topk("cyclic join query", 100)
        vi = VectorIndex(llm_embedding(ctx, model, docs))
        q = llm_embedding(ctx, model, ["cyclic join query"])
        v_s, v_idx = vi.topk(q, 100)
        fb = np.full(len(docs), np.nan)
        fb[b_idx] = b_s
        fv = np.full(len(docs), np.nan)
        fv[v_idx[0]] = v_s[0]
        fused = rrf(fb, fv)
        top10 = np.argsort(-fused)[:10]
        perm = llm_rerank(ctx, {"model": "m"},
                          {"prompt": "mentions cyclic joins"},
                          [{"doc": docs[i]} for i in top10])
        return [int(top10[p]) for p in perm]

    dt = _timeit(pipeline, n=1, warmup=1)
    _row("hybrid_search_q3", dt * 1e6, f"docs={len(docs)} "
         f"rate={len(docs)/dt:.0f}docs/s")


def bench_fusion_methods():
    from repro.core import fusion
    rng = np.random.default_rng(0)
    a, b, c = (rng.random(10_000) for _ in range(3))
    for m in ("rrf", "combsum", "combmnz", "combmed", "combanz"):
        dt = _timeit(lambda m=m: fusion(m, a, b, c), n=5)
        _row(f"fusion_{m}", dt * 1e6, "n=10000x3")


def bench_continuous_batching():
    from repro.configs import get_smoke_config
    from repro.serving.engine import ServingEngine
    cfg = get_smoke_config("olmo-1b").replace(remat=False)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, 24)) for _ in range(8)]

    eng = ServingEngine(cfg, n_slots=4, max_context=128, chunk=16)
    t0 = time.perf_counter()
    reqs = [eng.submit(p, 16) for p in prompts]
    eng.run_until_idle()
    t_cb = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in reqs)

    eng2 = ServingEngine(cfg, n_slots=1, max_context=128, chunk=16)
    t0 = time.perf_counter()
    for p in prompts:
        eng2.generate(p, 16)
    t_seq = time.perf_counter() - t0
    _row("continuous_batching", t_cb * 1e6 / max(toks, 1),
         f"tok/s={toks/t_cb:.1f} vs_sequential={t_seq/t_cb:.2f}x")


def bench_train_step():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.training import HParams, adamw_init, make_train_step
    from repro.training.data import DataConfig, SyntheticTokenPipeline
    cfg = get_smoke_config("olmo-1b").replace(remat=False)
    hp = HParams(total_steps=10)
    step = jax.jit(make_train_step(cfg, hp), donate_argnums=(0, 1))
    data = SyntheticTokenPipeline(DataConfig(cfg.vocab_size, 64, 8))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    params, opt, _ = step(params, opt, batch)      # compile
    t0 = time.perf_counter()
    for _ in range(5):
        params, opt, m = step(params, opt, batch)
    jax.block_until_ready(m["loss"])
    dt = (time.perf_counter() - t0) / 5
    _row("train_step_smoke", dt * 1e6, f"tok/s={8*64/dt:.0f}")


def bench_kernels():
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.topk_sim.ops import topk_sim
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 2, 32)), jnp.float32)
    dt = _timeit(lambda: flash_attention(q, k, v, block_q=32, block_k=32
                                         ).block_until_ready(), n=3)
    _row("kernel_flash_attention_interp", dt * 1e6, "B2_S128_H4_hd32")
    dt = _timeit(lambda: attention_ref(q, k, v).block_until_ready(), n=3)
    _row("kernel_flash_attention_ref", dt * 1e6, "oracle")
    c = jnp.asarray(rng.standard_normal((4096, 64)), jnp.float32)
    qs = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    dt = _timeit(lambda: topk_sim(c, qs, 16)[0].block_until_ready(), n=3)
    _row("kernel_topk_sim_interp", dt * 1e6, "N4096_D64_k16")


_ALL_BENCHES = {
    "batching_chat_api": bench_batching_chat_api,
    "optimizer": bench_optimizer,
    "scheduler": bench_scheduler,
    "speculative": bench_speculative,
    "copack": bench_copack,
    "rag": bench_rag,
    "ann": bench_ann,
    "caching": bench_caching,
    "dedup": bench_dedup,
    "fusion_methods": bench_fusion_methods,
    "hybrid_search": bench_hybrid_search,
    "batching_chat_local": bench_batching_chat_local,
    "batching_embedding": bench_batching_embedding,
    "continuous_batching": bench_continuous_batching,
    "train_step": bench_train_step,
    "kernels": bench_kernels,
}


def main(argv: list[str] | None = None) -> None:
    """Run all benches, or only those named on the command line
    (``python benchmarks/run.py scheduler optimizer``)."""
    names = list(argv if argv is not None else sys.argv[1:])
    unknown = [n for n in names if n not in _ALL_BENCHES]
    if unknown:
        raise SystemExit(f"unknown bench(es) {unknown}; "
                         f"choose from {sorted(_ALL_BENCHES)}")
    print("name,us_per_call,derived")
    for name, fn in _ALL_BENCHES.items():
        if not names or name in names:
            fn()


if __name__ == "__main__":
    main()
