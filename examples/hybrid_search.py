"""Paper Query 3: full hybrid search, imperative AND as a plan.

    PYTHONPATH=src python examples/hybrid_search.py [--local-jax]

Imperative composition: (1) embed the intent, (2) vector-scan the
corpus (the topk_sim kernel's oracle path), (3) BM25 retrieval,
(4) score fusion (rrf + max-norm), (5) LLM listwise rerank for "cyclic
joins".  Then the same retrieval as ONE plan — ``hybrid_topk`` ->
``llm_rerank(by=...)`` — where the optimizer prices embed requests and
index-scan cost in ``explain()`` and the corpus index is memoised for
repeated questions.  With --local-jax the embeddings come from a real
JAX model served by the continuous-batching engine instead of the
deterministic mock.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (SemanticContext, llm_embedding, llm_rerank,
                        max_normalize, rrf)
from repro.engine import Table
from repro.retrieval import BM25Index, VectorIndex


PASSAGES = [
    "hash joins build a table then probe it",
    "sort merge joins exploit interesting orders",
    "worst case optimal joins handle cyclic join queries",
    "cyclic joins such as triangles need wcoj algorithms",
    "b trees remain the default index structure",
    "vector search scans embeddings for nearest neighbours",
    "query optimizers reorder joins by cost",
    "triangle counting is a cyclic join in disguise",
    "columnar storage accelerates analytical scans",
    "bm25 ranks documents by term frequency saturation",
    "embedding models map text to dense vectors",
    "the relational model separates logic from execution",
]


def main():
    use_local = "--local-jax" in sys.argv
    if use_local:
        from repro.core.provider import LocalJaxProvider
        ctx = SemanticContext(provider=LocalJaxProvider("olmo-1b"))
    else:
        ctx = SemanticContext()
    emb_model = {"model": "text-embedding-3-small", "embedding_dim": 64}
    research_passages = Table({"idx": list(range(len(PASSAGES))),
                               "content": PASSAGES})

    # (1) embedding for the user intent
    intent = "join algorithms in databases"
    q_vec = llm_embedding(ctx, emb_model, [intent])

    # (2) vector similarity scan, top 100
    vi = VectorIndex.build(ctx, emb_model,
                           research_passages.column("content"))
    v_scores, v_idx = vi.topk(q_vec, k=10)

    # (3) BM25 retriever
    bm = BM25Index.build(research_passages.column("content"))
    b_idx, b_scores = bm.topk(intent, k=10)

    # (4) FULL OUTER JOIN + max-normalised fusion
    n = len(PASSAGES)
    col_v = np.full(n, np.nan)
    col_v[v_idx[0]] = max_normalize(v_scores[0])
    col_b = np.full(n, np.nan)
    col_b[b_idx] = max_normalize(b_scores)
    fused = rrf(col_b, col_v)
    top10 = np.argsort(-fused)[:10]

    print("fusion top-10 (rrf over bm25 + cosine):")
    for i in top10:
        print(f"  [{fused[i]:.4f}] {PASSAGES[i]}")

    # (5) rerank for the narrower intent
    docs = [{"content": PASSAGES[i]} for i in top10]
    perm = llm_rerank(ctx, {"model": "gpt-4o"},
                      {"prompt": "mentions cyclic joins"}, docs)
    print("\nafter llm_rerank('mentions cyclic joins'):")
    for rank, p in enumerate(perm):
        print(f"  {rank + 1}. {PASSAGES[top10[p]]}")
    print("\nprovider stats:", ctx.provider.stats.snapshot())

    # ---- the same retrieval as ONE plan (first-class operators) -----
    from repro.engine import Pipeline
    question = Table({"q": ["cyclic join algorithms"]})
    pipe = (Pipeline(ctx, question, "question")
            .hybrid_topk("score", emb_model, "q", research_passages,
                         k=5, doc_col="content", candidate_k=10)
            .llm_rerank({"model": "gpt-4o"},
                        {"prompt": "mentions cyclic joins"},
                        ["content"], by="q"))
    # pre-flight static analysis BEFORE paying for provider calls:
    # check() resolves MODEL/PROMPT refs against the catalog, binds
    # prompt {placeholders} to visible columns, validates ann/k knobs,
    # and infers every node's output schema — a typo here raises
    # PlanValidationError with a stable FLK code and ZERO requests
    # (see docs/diagnostics.md)
    pipe.check()
    result = pipe.collect(verify="strict")   # also re-proves each
    #                                          optimizer rewrite sound
    print("\nplan-based hybrid_topk -> llm_rerank top-5:")
    for r in result.rows():
        print(f"  [{r['score']:.4f}] {r['content']}")
    print("\n" + pipe.explain())

    # ---- million-document posture: ANN + incremental append ---------
    # ann="auto" lets the optimizer price the IVF probe FLOPs against
    # the exact scan per node: the 12-passage corpus above stays exact,
    # this larger one flips to IVF — explain() shows both frontiers
    # (ann[... ivf_flops=... exact_flops=...]) and the ann_select
    # rewrite that resolved the choice.
    big_corpus = Table({"content": [
        f"passage {i}: {PASSAGES[i % len(PASSAGES)]}" for i in range(2000)
    ]})
    ann_pipe = (Pipeline(ctx, question, "question")
                .vector_topk("score", emb_model, "q", big_corpus,
                             k=5, doc_col="content",
                             ann="auto", recall_target=0.95))
    ann_pipe.collect()
    print("\nann=\"auto\" over a 2000-doc corpus (optimizer picks IVF):")
    for line in ann_pipe.explain().splitlines():
        if "ann" in line:
            print("  " + line.strip())

    # growing a built index embeds ONLY the delta: the session (or the
    # IndexStore sidecar) memoises the prefix, and the new texts are
    # appended as a segment — no re-embedding of the base corpus.
    from repro.retrieval import ensure_index

    def embedded_tuples():
        return sum(r.n_tuples for r in ctx.reports
                   if r.function == "embedding")

    before = embedded_tuples()
    grown = big_corpus.column("content") + [
        f"fresh passage {i}" for i in range(50)]
    _, source = ensure_index(ctx, emb_model, grown)
    print(f"\nincremental append: source={source!r}, "
          f"texts embedded for +50 docs: {embedded_tuples() - before} "
          f"(the 2000-doc base was not re-embedded)")


if __name__ == "__main__":
    main()
