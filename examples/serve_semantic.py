"""End-to-end driver (the paper is a serving-kind system): batched semantic
requests against a small model on the continuous-batching engine.

    PYTHONPATH=src python examples/serve_semantic.py [--requests 16]

Routes a review-classification workload through the full FlockJAX stack:
semantic operators -> dedup -> cache -> adaptive batching -> LocalJaxProvider
-> ServingEngine (chunked prefill + slot-based decode) — i.e. every layer
the TPU deployment would run, on the CPU smoke model.
"""

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import SemanticContext, llm_complete, llm_filter
from repro.core.provider import LocalJaxProvider
from repro.engine import Pipeline, Table


def main():
    n = 16
    if "--requests" in sys.argv:
        n = int(sys.argv[sys.argv.index("--requests") + 1])

    reviews = Table({
        "id": list(range(n)),
        "review": [f"the app crashed {i % 3} times during transfer"
                   if i % 2 else f"smooth experience number {i % 5}"
                   for i in range(n)],
    })

    ctx = SemanticContext(provider=LocalJaxProvider("olmo-1b"))
    model = {"model": "flock-serve", "context_window": 2048,
             "max_output_tokens": 4}

    t0 = time.time()
    pipe = (Pipeline(ctx, reviews, "bank_reviews")
            .llm_filter(model, {"prompt": "mentions technical issues"},
                        ["review"])
            .llm_complete("severity", model,
                          {"prompt": "assign a severity 1-5"}, ["review"]))
    out = pipe.collect()
    dt = time.time() - t0

    print(out)
    print()
    print(pipe.explain())
    s = ctx.provider.stats
    print(f"\n{n} tuples -> {s.calls} engine calls, "
          f"{s.prompt_tokens} prompt tokens, {s.output_tokens} generated, "
          f"{dt:.2f}s wall ({n / dt:.1f} tuples/s)")


if __name__ == "__main__":
    main()
