"""Quickstart: the paper's Query 1 + Query 2 as FlockJAX library calls.

    PYTHONPATH=src python examples/quickstart.py

Defines MODEL/PROMPT resources (paper §2.1), runs the filter -> summarize
-> extract-JSON pipeline (paper Query 2) and prints the inspected plan
(paper Fig. 2b) showing the optimizer's choices: batch sizes, dedup
factor, cache hits.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import SemanticContext, reset_global_catalog
from repro.engine import Pipeline, Table


def main():
    reset_global_catalog()
    ctx = SemanticContext()

    # -- (1) Define a model to use (paper Query 1) -------------------------
    ctx.catalog.create_model("model-relevance-check", arch="mock",
                             scope="global", context_window=8192)
    # -- (2) Define a prompt ------------------------------------------------
    ctx.catalog.create_prompt("joins-prompt",
                              "is related to join algos given abstract")

    research_papers = Table({
        "id": list(range(8)),
        "title": ["Hash Joins Revisited", "Sort-merge in Practice",
                  "B-tree Internals", "Worst-case Optimal Joins",
                  "Vector Databases", "Hash Joins Revisited",
                  "Adaptive Radix Trees", "Cyclic Query Plans"],
        "abstract": ["hash join performance", "merge joins on modern cpus",
                     "index structures", "cyclic join queries and wcoj",
                     "embedding search at scale", "hash join performance",
                     "trie indexes", "plans for cyclic joins"],
        "content": ["..."] * 8,
    })

    # -- Query 2: filter -> summarize -> extract ----------------------------
    pipe = (Pipeline(ctx, research_papers, "research_papers")
            .llm_filter({"model_name": "model-relevance-check"},
                        {"prompt_name": "joins-prompt"},
                        ["title", "abstract"])
            .llm_complete("summarized_abstract", {"model": "gpt-4o"},
                          {"prompt": "Summarize the abstract in 1 sentence"},
                          ["abstract"])
            .llm_complete_json(
                "extracted", {"model": "gpt-4o"},
                {"prompt": 'extract {"keywords": <3>, "type": '
                           '<empirical|theoretical>} as JSON'},
                ["title", "abstract"]))

    out = pipe.collect()
    print(out)
    print()
    print(pipe.explain())
    print()
    print("prediction cache:", ctx.cache.stats)

    # -- The plan optimizer at work -----------------------------------------
    # Chained as written, the summarize pass would run over every row and
    # only then keep the newest 3; the optimizer pushes order_by+limit
    # below the LLM op and fuses same-model adjacent semantic ops, so the
    # provider sees 3 tuples instead of 8.  collect(optimize=False) is the
    # escape hatch that runs the plan exactly as chained.
    demo_ctx = SemanticContext(enable_cache=False)   # isolate call counts
    wasteful = (Pipeline(demo_ctx, research_papers, "research_papers")
                .llm_complete("tldr", {"model": "gpt-4o"},
                              {"prompt": "one-line tl;dr"}, ["abstract"])
                .order_by("id", desc=True)
                .limit(3))
    print("\n--- optimizer demo: llm_complete -> order_by -> limit ---")
    print(wasteful.explain())
    wasteful.collect()
    opt_tuples = demo_ctx.reports[-1].n_tuples
    wasteful.collect(optimize=False)
    naive_tuples = demo_ctx.reports[-1].n_tuples
    print(f"tuples sent to the model: optimized run -> {opt_tuples}, "
          f"naive run -> {naive_tuples}")

    # resource independence: swap the model, query stays identical
    ctx.catalog.update_model("model-relevance-check", context_window=2048)
    print("\nmodel updated to v2 — same pipeline, no query change:")
    print(pipe.collect().head(3))


if __name__ == "__main__":
    main()
