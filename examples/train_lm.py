"""End-to-end training driver: train a small LM for a few hundred steps
with checkpointing + fault-tolerant resume.

    PYTHONPATH=src python examples/train_lm.py            # ~20M params
    PYTHONPATH=src python examples/train_lm.py --steps 300

Exercises the full training substrate (bf16 params, fp32 AdamW master,
remat, synthetic packed data, atomic keep-N checkpoints, straggler
watchdog).  On a TPU mesh the identical entry point runs sharded — this
CPU run uses the same code path minus the MeshPolicy.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import run


def main():
    argv = sys.argv[1:]
    steps = "200"
    if "--steps" in argv:
        steps = argv[argv.index("--steps") + 1]
        argv = [a for i, a in enumerate(argv)
                if a != "--steps" and argv[max(i - 1, 0)] != "--steps"]
    run(["--arch", "olmo-1b", "--smoke",
         "--steps", steps,
         "--global-batch", "8", "--seq-len", "128",
         "--ckpt-dir", "/tmp/flockjax_train_lm",
         "--ckpt-every", "50", "--resume", "auto",
         "--log-every", "10"] + argv)


if __name__ == "__main__":
    main()
