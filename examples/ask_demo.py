"""ASK demo (paper Fig. 2a/2b): NL question -> generated query -> plan.

    PYTHONPATH=src python examples/ask_demo.py

The planner is deterministic/template-based (DESIGN.md §8: faithful NL->SQL
needs an instruction-tuned checkpoint).  The interesting part is the plan
inspection: batch size chosen by the system, serialization format, the full
meta-prompt, and what changes when the user forces a manual batch size —
the paper's interactive challenge.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import SemanticContext, build_prefix
from repro.engine import Table, ask


def main():
    ctx = SemanticContext()
    reviews = Table({
        "id": list(range(10)),
        "review": [
            "transfer failed with a timeout error",
            "great ui, love the dark mode",
            "app crashes on login every time",
            "support was friendly",
            "charged twice for one transaction",
            "transfer failed with a timeout error",
            "cannot reset my password, keeps erroring",
            "fast and reliable",
            "the otp sms never arrives",
            "statement export is broken",
        ],
    })

    question = ("list reviews mentioning technical issues and assign a "
                "severity score to each issue")
    print(f"ASK: {question!r}\n")
    sql, pipe = ask(ctx, reviews, question, text_cols=["review"])
    print("generated query:\n" + sql + "\n")
    out = pipe.collect()
    print(out)
    print("\n--- Inspect Plan ---")
    print(pipe.explain())
    print("\nfull meta-prompt prefix used by llm_filter:\n")
    print(build_prefix("filter", "is about technical issues", "xml"))

    # the interactive challenge: force batch size 2 and re-run
    print("--- manual batch size = 2 (vs Auto) ---")
    ctx2 = SemanticContext(max_batch=2)
    _, pipe2 = ask(ctx2, reviews, question, text_cols=["review"])
    pipe2.collect()
    print(pipe2.explain())
    print("\nnote the extra requests vs Auto — the latency/accuracy "
          "trade-off the paper demonstrates.")


if __name__ == "__main__":
    main()
