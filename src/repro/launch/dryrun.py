"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware, and extracts
the roofline terms from the compiled artifacts.

Methodology notes (verified experimentally in this container):
  * XLA's HLO cost model counts while-loop (lax.scan) bodies ONCE, so the
    scanned-over-layers production program under-reports FLOPs.  We
    therefore lower small *probe* configs with python-unrolled layers
    (1 repeat per stage, and 2 repeats for the probed stage) and solve for
    the per-stage marginal cost:
        body_i  = cost(probe_i) - cost(base)
        total   = cost(base) + sum_i (repeats_i - 1) * body_i
    The true scanned program is still lowered and compiled for the memory
    analysis and as the multi-pod shardability proof.
  * ``compiled.cost_analysis()`` reports PER-DEVICE flops/bytes of the
    SPMD-partitioned module (verified), so roofline terms divide by
    single-chip peaks.
  * collective bytes are parsed from ``compiled.as_text()`` (per-device
    shapes); ring all-reduce counts 2x its payload, other collectives 1x.
"""

# The first two lines MUST run before any other import (jax locks the
# device count on first init).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.configs.specs import input_specs
from repro.models import model as M
from repro.models import sharding as S
from repro.models.config import SHAPES, ModelConfig, ShapeCell, cell_is_supported
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.serving.steps import make_decode_step, make_prefill_step
from repro.training import HParams, adamw_init, make_train_step, opt_specs

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Per-device collective payload bytes by op kind (ring-transfer conv.)."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] = out.get(op, 0.0) + nbytes * _COLL_FACTOR[op]
    return out


# --------------------------------------------------------------------------
# step builders (shared by the real lowering and the cost probes)
# --------------------------------------------------------------------------
def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh):
    """Returns (fn, example_args (SDS), in_shardings, donate_argnums)."""
    policy = S.MeshPolicy(mesh, cfg, cell.global_batch)
    pspecs = S.param_specs(cfg, mesh)
    params_sds = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    batch_sds = input_specs(cfg, cell)
    bspecs = S.batch_specs(cfg, mesh, cell.global_batch, cell.kind)

    if cell.kind == "train":
        hp = HParams(accum_steps=cfg.train_accum_steps)
        step = make_train_step(cfg, hp, policy)
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        ospecs = opt_specs(pspecs, params_sds, mesh)
        args = (params_sds, opt_sds, batch_sds)
        shardings = (S.to_shardings(mesh, pspecs),
                     S.to_shardings(mesh, ospecs),
                     S.to_shardings(mesh, bspecs))
        return step, args, shardings, (0, 1)

    if cell.kind == "prefill":
        step = make_prefill_step(cfg, cache_len=cell.seq_len, policy=policy)
        args = (params_sds, batch_sds)
        shardings = (S.to_shardings(mesh, pspecs),
                     S.to_shardings(mesh, bspecs))
        return step, args, shardings, ()

    # decode: one new token against a cache of seq_len
    cache_sds = jax.eval_shape(
        lambda: M.init_cache(cfg, cell.global_batch, cell.seq_len))
    cspecs = S.cache_specs(cfg, mesh, cell.global_batch)
    step = make_decode_step(cfg, policy)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    args = (params_sds, batch_sds["tokens"], cache_sds, pos_sds)
    shardings = (S.to_shardings(mesh, pspecs),
                 NamedSharding(mesh, P(S._dp(mesh, cell.global_batch), None)),
                 S.to_shardings(mesh, cspecs),
                 NamedSharding(mesh, P()))
    return step, args, shardings, (2,)


def lower_and_analyze(cfg, cell, mesh, *, want_memory=True):
    fn, args, shardings, donate = build_cell(cfg, cell, mesh)
    jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
    t0 = time.monotonic()
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    dt = time.monotonic() - t0
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):      # jax<=0.4.x: one dict per device
        ca = ca[0] if ca else {}
    res = {
        "compile_s": round(dt, 2),
        "flops_per_dev": float(ca.get("flops", 0.0)),
        "bytes_per_dev": float(ca.get("bytes accessed", 0.0)),
        "collectives": parse_collective_bytes(compiled.as_text()),
    }
    if want_memory:
        ma = compiled.memory_analysis()
        res["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
    return res


# --------------------------------------------------------------------------
# cost probes (per-stage marginal cost; see module docstring)
# --------------------------------------------------------------------------
def _probe_variants(cfg: ModelConfig):
    dec = [(list(pat), 1) for pat, _ in cfg.stages()]
    enc = [(list(pat), 1) for pat, _ in cfg.encoder_stages()]
    base = cfg.replace(stages_override=tuple((tuple(p), r) for p, r in dec),
                       enc_stages_override=tuple((tuple(p), r)
                                                 for p, r in enc),
                       unroll_layers=True, unroll_inner=True)
    probes = []
    for i in range(len(dec)):
        d2 = [(p, 2 if j == i else 1) for j, (p, _) in enumerate(dec)]
        probes.append(("dec", i, base.replace(
            stages_override=tuple((tuple(p), r) for p, r in d2))))
    for i in range(len(enc)):
        e2 = [(p, 2 if j == i else 1) for j, (p, _) in enumerate(enc)]
        probes.append(("enc", i, base.replace(
            enc_stages_override=tuple((tuple(p), r) for p, r in e2))))
    return base, probes


def probed_costs(cfg: ModelConfig, cell: ShapeCell, mesh):
    """Scan-corrected per-device flops/bytes/collectives for the full model."""
    base_cfg, probes = _probe_variants(cfg)
    base = lower_and_analyze(base_cfg, cell, mesh, want_memory=False)

    def combine(total, body, mult):
        total["flops_per_dev"] += mult * max(body["flops_per_dev"], 0.0)
        total["bytes_per_dev"] += mult * max(body["bytes_per_dev"], 0.0)
        for k, v in body["collectives"].items():
            total["collectives"][k] = total["collectives"].get(k, 0.0) \
                + mult * max(v, 0.0)

    total = {"flops_per_dev": base["flops_per_dev"],
             "bytes_per_dev": base["bytes_per_dev"],
             "collectives": dict(base["collectives"]),
             "probe_compile_s": base["compile_s"]}
    dec_reps = [r for _, r in cfg.stages()]
    enc_reps = [r for _, r in cfg.encoder_stages()]
    for kind, i, pcfg in probes:
        pr = lower_and_analyze(pcfg, cell, mesh, want_memory=False)
        body = {
            "flops_per_dev": pr["flops_per_dev"] - base["flops_per_dev"],
            "bytes_per_dev": pr["bytes_per_dev"] - base["bytes_per_dev"],
            "collectives": {
                k: pr["collectives"].get(k, 0.0)
                - base["collectives"].get(k, 0.0)
                for k in set(pr["collectives"]) | set(base["collectives"])},
        }
        reps = (dec_reps if kind == "dec" else enc_reps)[i]
        combine(total, body, reps - 1)
        total["probe_compile_s"] += pr["compile_s"]
    return total


# --------------------------------------------------------------------------
# roofline
# --------------------------------------------------------------------------
def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """6*N_active*D for train, 2*N_active*D forward-only."""
    n = cfg.active_params()
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch        # decode: one token per row


def roofline(cost: dict, n_chips: int, cfg, cell) -> dict:
    t_compute = cost["flops_per_dev"] / PEAK_FLOPS_BF16
    t_memory = cost["bytes_per_dev"] / HBM_BW
    coll_bytes = sum(cost["collectives"].values())
    t_coll = coll_bytes / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    hlo_flops_global = cost["flops_per_dev"] * n_chips
    mf = model_flops(cfg, cell)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "collective_bytes_per_dev": coll_bytes,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": mf / hlo_flops_global if hlo_flops_global else 0,
        # roofline fraction: useful model flops vs chip-seconds implied by
        # the *dominant* term (what fraction of peak the step achieves)
        "roofline_fraction": (mf / n_chips / PEAK_FLOPS_BF16)
        / max(terms[dominant], 1e-30),
    }


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: Path,
             *, skip_existing: bool = True, overrides: dict | None = None,
             variant: str = "") -> dict:
    name = f"{arch}__{shape}__{mesh_kind}"
    if variant:
        name += f"__{variant}"
    out_path = out_dir / f"{name}.json"
    if skip_existing and out_path.exists():
        return json.loads(out_path.read_text())
    cell = SHAPES[shape]
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "variant": variant, "overrides": overrides or {},
           "time": time.strftime("%Y-%m-%d %H:%M:%S")}
    if not cell_is_supported(arch, shape):
        rec["status"] = "SKIP"
        rec["reason"] = ("full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md §4)")
        out_path.write_text(json.dumps(rec, indent=2))
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    cfg = get_config(arch, shard_multiple=mesh.shape["model"])
    if overrides:
        cfg = cfg.replace(**overrides)
    try:
        full = lower_and_analyze(cfg, cell, mesh, want_memory=True)
        rec["memory"] = full["memory"]
        rec["compile_s"] = full["compile_s"]
        rec["scanned_program"] = {k: full[k] for k in
                                  ("flops_per_dev", "bytes_per_dev",
                                   "collectives")}
        cost = probed_costs(cfg, cell, mesh)
        rec["cost"] = cost
        rec["roofline"] = roofline(cost, n_chips, cfg, cell)
        rec["status"] = "OK"
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--overrides", default="",
                    help="JSON ModelConfig overrides (hillclimb variants)")
    ap.add_argument("--variant", default="",
                    help="variant label appended to the output file name")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    overrides = json.loads(args.overrides) if args.overrides else None

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                t0 = time.monotonic()
                rec = run_cell(arch, shape, mk, out_dir,
                               skip_existing=not args.force,
                               overrides=overrides, variant=args.variant)
                status = rec["status"]
                n_ok += status == "OK"
                n_skip += status == "SKIP"
                n_fail += status == "FAIL"
                extra = ""
                if status == "OK":
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']:10s} "
                             f"frac={r['roofline_fraction']:.3f} "
                             f"useful={r['useful_flops_ratio']:.3f}")
                elif status == "FAIL":
                    extra = rec["error"][:120]
                print(f"[{status:4s}] {arch:24s} {shape:12s} {mk:6s} "
                      f"{time.monotonic()-t0:6.1f}s {extra}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
