"""Render the §Dry-run / §Roofline tables from results/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import list_archs
from repro.models.config import SHAPES


def fmt_bytes(n):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def load(dir_: Path):
    recs = {}
    for p in sorted(dir_.glob("*.json")):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def roofline_table(recs, mesh="single"):
    head = ("| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | dominant |"
            " useful | roofline | HBM/dev | note |")
    sep = "|" + "---|" * 10
    rows = [head, sep]
    for arch in list_archs():
        for shape in SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                rows.append(f"| {arch} | {shape} | - | - | - | MISSING | "
                            "- | - | - | not yet run |")
                continue
            if r["status"] == "SKIP":
                rows.append(f"| {arch} | {shape} | - | - | - | SKIP | - |"
                            f" - | - | {r['reason'][:60]} |")
                continue
            if r["status"] == "FAIL":
                rows.append(f"| {arch} | {shape} | - | - | - | FAIL | - |"
                            f" - | - | {r['error'][:60]} |")
                continue
            ro = r["roofline"]
            mem = r["memory"]
            hbm = mem["argument_bytes"] + mem["temp_bytes"] \
                + mem["output_bytes"] - mem["alias_bytes"]
            fits = "" if hbm < 16 * 2 ** 30 else " **>16GB HBM**"
            rows.append(
                f"| {arch} | {shape} | {ro['t_compute_s']:.4f} |"
                f" {ro['t_memory_s']:.4f} | {ro['t_collective_s']:.4f} |"
                f" {ro['dominant']} | {ro['useful_flops_ratio']:.3f} |"
                f" {ro['roofline_fraction']:.4f} | {fmt_bytes(hbm)} |"
                f"{fits} |")
    return "\n".join(rows)


def dryrun_table(recs):
    head = ("| arch | shape | mesh | status | compile(s) | args/dev |"
            " temps/dev | collectives/dev |")
    sep = "|" + "---|" * 8
    rows = [head, sep]
    for (arch, shape, mesh), r in sorted(recs.items()):
        if r["status"] != "OK":
            rows.append(f"| {arch} | {shape} | {mesh} | {r['status']} |"
                        " - | - | - | - |")
            continue
        mem = r["memory"]
        coll = sum(r["cost"]["collectives"].values())
        rows.append(
            f"| {arch} | {shape} | {mesh} | OK | {r['compile_s']:.1f} |"
            f" {fmt_bytes(mem['argument_bytes'])} |"
            f" {fmt_bytes(mem['temp_bytes'])} | {fmt_bytes(coll)} |")
    return "\n".join(rows)


def summarize(recs):
    ok = sum(r["status"] == "OK" for r in recs.values())
    skip = sum(r["status"] == "SKIP" for r in recs.values())
    fail = sum(r["status"] == "FAIL" for r in recs.values())
    return f"{ok} OK / {skip} SKIP / {fail} FAIL of {len(recs)} records"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun", "summary"])
    args = ap.parse_args()
    recs = load(Path(args.dir))
    print(summarize(recs))
    if args.table == "roofline":
        print(roofline_table(recs, args.mesh))
    elif args.table == "dryrun":
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
