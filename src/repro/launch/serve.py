"""Serving launcher: continuous-batching engine + semantic-operator REPL.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --requests 8

Feeds a stream of synthetic requests through the engine and reports
throughput/latency; with --semantic it routes the requests through the
FlockJAX semantic-operator layer (LocalJaxProvider) instead of raw
generate calls.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.serving.engine import ServingEngine


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-context", type=int, default=256)
    ap.add_argument("--semantic", action="store_true",
                    help="drive via the semantic-operator layer")
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))

    if args.semantic:
        from repro.core import SemanticContext, llm_complete
        from repro.core.provider import LocalJaxProvider
        ctx = SemanticContext(provider=LocalJaxProvider(args.arch))
        rows = [{"text": f"request {i} body " * 3}
                for i in range(args.requests)]
        t0 = time.monotonic()
        out = llm_complete(ctx, {"model": "local",
                                 "context_window": args.max_context,
                                 "max_output_tokens": 8},
                           {"prompt": "echo"}, rows)
        dt = time.monotonic() - t0
        print(f"semantic path: {len(out)} rows in {dt:.2f}s "
              f"({len(out)/dt:.1f} rows/s); "
              f"reports={[r.batch_sizes for r in ctx.reports]}")
        return

    eng = ServingEngine(cfg, n_slots=args.slots,
                        max_context=args.max_context)
    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size,
                                         args.prompt_len)),
                       max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    eng.run_until_idle()
    dt = time.monotonic() - t0
    done = sum(r.finished for r in reqs)
    toks = sum(len(r.generated) for r in reqs)
    print(f"{done}/{len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.steps} engine steps)")


if __name__ == "__main__":
    run()
