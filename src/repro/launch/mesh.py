"""Production mesh construction (single-pod 16x16 and 2-pod 2x16x16).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    # jax.sharding.AxisType only exists on newer jax; on older releases
    # (<=0.4.x) every axis is implicitly Auto, so omit the argument.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh helper (elastic re-shard paths, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


# TPU v5e-class hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
