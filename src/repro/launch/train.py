"""Training launcher: mesh setup, data, checkpoint/resume, fault tolerance.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
      --steps 50 --ckpt-dir /tmp/ck --resume auto
  (on a TPU fleet the same entry point runs with --mesh single|multi; on
  CPU it runs the reduced config end-to-end.)

Fault tolerance drill (see tests/test_fault_tolerance.py):
  run N steps -> kill -> rerun with --resume auto -> loss continues
  bitwise-identically, because data batches are pure functions of the step
  and the checkpoint stores (params, opt, step).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import model as M
from repro.models import sharding as S
from repro.models.layers import NULL_POLICY
from repro.training import HParams, adamw_init, make_train_step, opt_specs
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, StragglerWatchdog, \
    SyntheticTokenPipeline


def build_trainer(cfg, hp, mesh=None, global_batch=8, seq_len=64):
    """Returns (train_step_fn, init_fn) placed for the mesh (or CPU)."""
    if mesh is None:
        policy = NULL_POLICY
        step = jax.jit(make_train_step(cfg, hp, policy), donate_argnums=(0, 1))
        return step, None
    policy = S.MeshPolicy(mesh, cfg, global_batch)
    pspecs = S.param_specs(cfg, mesh)
    params_sds = jax.eval_shape(lambda: M.init_params(cfg,
                                                      jax.random.PRNGKey(0)))
    ospecs = opt_specs(pspecs, params_sds, mesh)
    bspecs = S.batch_specs(cfg, mesh, global_batch, "train")
    psh = S.to_shardings(mesh, pspecs)
    osh = S.to_shardings(mesh, ospecs)
    step = jax.jit(
        make_train_step(cfg, hp, policy),
        in_shardings=(psh, osh, S.to_shardings(mesh, bspecs)),
        # outputs must round-trip as next step's inputs
        out_shardings=(psh, osh, None),
        donate_argnums=(0, 1))
    return step, (pspecs, ospecs)


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--die-at-step", type=int, default=-1,
                    help="simulate a node failure (fault-tolerance drill)")
    args = ap.parse_args(argv)

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    hp = HParams(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                 total_steps=args.steps, accum_steps=args.accum_steps)
    step_fn, _ = build_trainer(cfg, hp)

    data = SyntheticTokenPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed))

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.resume == "auto" and mgr.latest_step() >= 0:
        state = mgr.restore_latest()
        params = jax.tree.map(jnp.asarray, state["params"])
        opt = jax.tree.map(jnp.asarray, state["opt"])
        start_step = int(mgr.latest_step())
        print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    watchdog = StragglerWatchdog()
    losses = []
    for step in range(start_step, args.steps):
        if step == args.die_at_step:
            print(f"[failure-drill] dying at step {step} (simulated)")
            raise SystemExit(42)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        watchdog.start()
        params, opt, metrics = step_fn(params, opt, batch)
        straggled = watchdog.stop()
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"med_step {watchdog.median_s * 1e3:.0f}ms"
                  + (" [STRAGGLER]" if straggled else ""), flush=True)
        if mgr and ((step + 1) % args.ckpt_every == 0
                    or step == args.steps - 1):
            mgr.save(step + 1, {"params": params, "opt": opt},
                     {"arch": cfg.name, "loss": loss})
    return losses


if __name__ == "__main__":
    run()
