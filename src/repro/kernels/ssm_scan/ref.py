"""Pure-jnp oracle: sequential selective scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def ssm_scan_ref(x, dt, Bm, Cm, A_log, D):
    """x, dt: (B, S, di); Bm, Cm: (B, S, N); A_log: (di, N); D: (di,)."""
    A = -jnp.exp(A_log.astype(F32))
    xf, dtf = x.astype(F32), dt.astype(F32)
    a = jnp.exp(dtf[..., None] * A)                       # (B,S,di,N)
    bu = (dtf * xf)[..., None] * Bm.astype(F32)[:, :, None, :]

    def step(h, inp):
        a_t, bu_t, c_t = inp
        h = a_t * h + bu_t
        return h, jnp.sum(h * c_t[:, None, :], axis=-1)

    B, S, di = x.shape
    h0 = jnp.zeros((B, di, A.shape[-1]), F32)
    _, y = jax.lax.scan(step, h0,
                        (a.transpose(1, 0, 2, 3), bu.transpose(1, 0, 2, 3),
                         Cm.astype(F32).transpose(1, 0, 2)))
    y = y.transpose(1, 0, 2)
    return (y + D.astype(F32) * xf).astype(x.dtype)
