"""Mamba-1 selective-scan Pallas TPU kernel.

The CUDA selective-scan kernel fights for occupancy with a parallel
Blelloch scan across thread blocks.  TPU adaptation: the grid's sequential
last dimension gives a free cross-chunk carry, so the layout is

   grid (B, n_channel_blocks, n_time_chunks)

with the recurrent state h (block_d, N) living in VMEM scratch across time
chunks.  Within a chunk the recurrence runs as a fori_loop of VPU
elementwise ops over (block_d, N) registersful — the discretised Ā, B̄u
tensors are built in VMEM, never in HBM, which is the entire point: HBM
traffic is just x/dt/B/C/y streaming (the memory-roofline floor), instead
of the (S, d, N) materialisation a naive jnp implementation writes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, d_ref, o_ref, h_scr,
                *, chunk: int):
    ck = pl.program_id(2)

    @pl.when(ck == 0)
    def _init():
        h_scr[...] = jnp.zeros(h_scr.shape, F32)

    x = x_ref[0].astype(F32)                   # (chunk, bd)
    dt = dt_ref[0].astype(F32)                 # (chunk, bd)
    Bm = b_ref[0].astype(F32)                  # (chunk, N)
    Cm = c_ref[0].astype(F32)                  # (chunk, N)
    A = -jnp.exp(alog_ref[...].astype(F32))    # (bd, N)
    D = d_ref[...].astype(F32)                 # (bd,)

    a = jnp.exp(dt[:, :, None] * A[None])      # (chunk, bd, N) in VMEM only
    bu = (dt * x)[:, :, None] * Bm[:, None, :]

    def step(t, carry):
        h, y = carry
        h = a[t] * h + bu[t]                   # (bd, N)
        y = y.at[t].set(jnp.sum(h * Cm[t][None, :], axis=1))
        return h, y

    y0 = jnp.zeros((chunk, x.shape[1]), F32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h_scr[...], y0))
    h_scr[...] = h
    o_ref[0] = (y + D[None, :] * x).astype(o_ref.dtype)


def ssm_scan_flat(x, dt, Bm, Cm, A_log, D, *, chunk: int = 128,
                  block_d: int = 256, interpret: bool = True):
    """x, dt: (B, S, di); Bm, Cm: (B, S, N); A_log: (di, N); D: (di,).

    Returns y: (B, S, di).  S % chunk == 0 and di % block_d == 0 (ops.py
    pads).
    """
    B, S, di = x.shape
    N = Bm.shape[-1]
    n_d = di // block_d
    n_ck = S // chunk
    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, n_d, n_ck),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((block_d,), lambda b, d, c: (d,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), F32)],
        interpret=interpret,
    )(x, dt, Bm, Cm, A_log, D)
