"""jit'd wrapper with padding for the selective-scan kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssm_scan_flat


@partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def ssm_scan(x, dt, Bm, Cm, A_log, D, *, chunk: int = 128,
             block_d: int = 256, interpret: bool = True):
    B, S, di = x.shape
    chunk = min(chunk, max(S, 8))
    block_d = min(block_d, di)
    pad_s = (-S) % chunk
    pad_d = (-di) % block_d
    if pad_s or pad_d:
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, pad_d)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, pad_d)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad_s), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad_s), (0, 0)))
        A_log = jnp.pad(A_log, ((0, pad_d), (0, 0)))
        D = jnp.pad(D, (0, pad_d))
    y = ssm_scan_flat(x, dt, Bm, Cm, A_log, D, chunk=chunk,
                      block_d=block_d, interpret=interpret)
    return y[:, :S, :di]
