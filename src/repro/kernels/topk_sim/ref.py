"""Pure-jnp oracle: full (N, Q) cosine scores + exact top-k."""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def topk_sim_ref(corpus, queries, k: int):
    """corpus: (N, D); queries: (Q, D) -> (scores (Q,k), idx (Q,k))."""
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-9)
    cn = corpus / jnp.maximum(
        jnp.linalg.norm(corpus, axis=-1, keepdims=True), 1e-9)
    s = jnp.einsum("qd,nd->qn", qn.astype(F32), cn.astype(F32))
    return jax.lax.top_k(s, k)
