"""Fused corpus-scan top-k similarity Pallas TPU kernel (hybrid search).

The paper's Query 3 step 2 scans every passage embedding against the query
and keeps the top 100 — FlockMTL leans on DuckDB's VSS extension; here the
scan is the TPU hot spot.  Materialising the (N, Q) score matrix in HBM is
the naive cost; the kernel instead:

  phase 1 (Pallas): blocked corpus x query matmul on the MXU, emitting only
     the per-block, per-query max — (Q, n_blocks) instead of (Q, N);
  phase 2 (XLA, ops.py): select the top-k *blocks* per query (their maxes
     upper-bound every member, so the true top-k elements provably live in
     the top-k blocks), gather those k*block rows, rescore exactly, top-k.

HBM traffic: one streaming pass over the corpus + k*block_n rescore reads,
vs 1 pass + (N, Q) writes + (N, Q) reads for the naive scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _blockmax_kernel(c_ref, q_ref, o_ref, *, n_valid: int, block_n: int):
    bi = pl.program_id(0)
    c = c_ref[...]                                   # (bn, D)
    q = q_ref[...]                                   # (Q, D)
    s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)   # (Q, bn)
    idx = bi * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < n_valid, s, -jnp.inf)
    o_ref[...] = s.max(axis=1, keepdims=True)


def block_max_scores(corpus, queries, *, block_n: int = 1024,
                     interpret: bool = True):
    """corpus: (N, D); queries: (Q, D) -> (Q, n_blocks) per-block maxima."""
    N, D = corpus.shape
    Q = queries.shape[0]
    pad = (-N) % block_n
    if pad:
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
    n_blocks = corpus.shape[0] // block_n
    kernel = functools.partial(_blockmax_kernel, n_valid=N, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_n, D), lambda i: (i, 0)),
            pl.BlockSpec((Q, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((Q, 1), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((Q, n_blocks), F32),
        interpret=interpret,
    )(corpus, queries)
