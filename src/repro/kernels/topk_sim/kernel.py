"""Fused corpus-scan top-k similarity Pallas TPU kernel (hybrid search).

The paper's Query 3 step 2 scans every passage embedding against the query
and keeps the top 100 — FlockMTL leans on DuckDB's VSS extension; here the
scan is the TPU hot spot.  Materialising the (N, Q) score matrix in HBM is
the naive cost; the kernel instead:

  phase 1 (Pallas): blocked corpus x query matmul on the MXU, emitting only
     the per-block, per-query max — (Q, n_blocks) instead of (Q, N);
  phase 2 (XLA, ops.py): select the top-k *blocks* per query (their maxes
     upper-bound every member, so the true top-k elements provably live in
     the top-k blocks), gather those k*block rows, rescore exactly, top-k.

HBM traffic: one streaming pass over the corpus + k*block_n rescore reads,
vs 1 pass + (N, Q) writes + (N, Q) reads for the naive scan.

Layout (what makes the COMPILED path lowerable, not just the
interpreter): each grid step consumes ``block_t`` consecutive sub-blocks
of ``block_n`` corpus rows and writes ONE (Q_pad, block_t) output tile.
With the defaults (block_n=64, block_t=128) the output tile's lane
dimension is the 128 the MXU/VPU tiling wants, queries pad to the f32
sublane multiple of 8, and the per-step corpus slab is
block_t*block_n*D*4 bytes (2 MiB at D=64) — VMEM-sized with room for
double buffering.  The old layout wrote (Q, 1) tiles, which TPU tiling
rejects; it only ever ran interpreted.

``interpret=None`` resolves per backend: compiled on TPU/GPU, the
interpreter fallback on CPU (where no Pallas lowering exists).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def resolve_interpret(interpret):
    """Backend-aware default: compiled wherever a Pallas lowering
    exists, interpreter on CPU."""
    if interpret is None:
        return jax.default_backend() == "cpu"
    return bool(interpret)


def _blockmax_kernel(c_ref, q_ref, o_ref, *, n_valid: int, block_n: int,
                     block_t: int):
    ti = pl.program_id(0)
    c = c_ref[...]                                   # (block_t*block_n, D)
    q = q_ref[...]                                   # (Q_pad, D)
    s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)  # (Q, bt*bn)
    idx = (ti * block_t * block_n
           + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1))
    s = jnp.where(idx < n_valid, s, -jnp.inf)
    qp = s.shape[0]
    o_ref[...] = s.reshape(qp, block_t, block_n).max(axis=2)


def block_max_scores(corpus, queries, *, block_n: int = 64,
                     block_t: int = 128, interpret=None):
    """corpus: (N, D); queries: (Q, D) -> (Q, n_blocks) per-block maxima
    over sub-blocks of ``block_n`` rows (padded blocks report -inf)."""
    interpret = resolve_interpret(interpret)
    N, D = corpus.shape
    Q = queries.shape[0]
    n_sub = -(-N // block_n)
    block_t = max(1, min(block_t, n_sub))
    chunk = block_n * block_t
    pad = (-N) % chunk
    if pad:
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
    qpad = (-Q) % 8                                  # f32 sublane multiple
    qp = jnp.pad(queries, ((0, qpad), (0, 0))) if qpad else queries
    grid = corpus.shape[0] // chunk
    n_blocks = grid * block_t
    kernel = functools.partial(_blockmax_kernel, n_valid=N,
                               block_n=block_n, block_t=block_t)
    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((chunk, D), lambda i: (i, 0)),
            pl.BlockSpec((Q + qpad, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((Q + qpad, block_t), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((Q + qpad, n_blocks), F32),
        interpret=interpret,
    )(corpus, qp)
    return out[:Q]
