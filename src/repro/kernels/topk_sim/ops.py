"""jit'd wrapper: block-max prune (Pallas) + exact rescore (XLA)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import block_max_scores

F32 = jnp.float32


@partial(jax.jit,
         static_argnames=("k", "block_n", "block_t", "interpret"))
def topk_sim(corpus, queries, k: int, *, block_n: int = 64,
             block_t: int = 128, interpret=None):
    """Exact cosine top-k via block-max pruning.

    corpus: (N, D) (normalised inside); queries: (Q, D).
    Returns (scores (Q, k), indices (Q, k)), exact (see kernel.py proof).
    ``k`` is capped at N; an empty corpus returns empty (Q, 0) results.
    ``interpret=None`` resolves per backend (compiled on TPU/GPU,
    interpreter on CPU)."""
    N, D = corpus.shape
    Q = queries.shape[0]
    k = min(k, N)
    if N == 0 or k == 0 or Q == 0:
        return (jnp.zeros((Q, min(k, N)), F32),
                jnp.zeros((Q, min(k, N)), jnp.int32))
    block_n = min(block_n, max(N, 8))
    cn = corpus / jnp.maximum(
        jnp.linalg.norm(corpus, axis=-1, keepdims=True), 1e-9)
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-9)
    qn = qn.astype(cn.dtype)

    bmax = block_max_scores(cn, qn, block_n=block_n, block_t=block_t,
                            interpret=interpret)     # (Q, n_blocks)
    n_blocks = bmax.shape[1]
    kb = min(k, n_blocks)
    _, top_blocks = jax.lax.top_k(bmax, kb)               # (Q, kb)

    # gather candidate rows: (Q, kb*block_n, D)
    row_idx = (top_blocks[:, :, None] * block_n
               + jnp.arange(block_n)[None, None, :]).reshape(Q, kb * block_n)
    row_idx = jnp.minimum(row_idx, N - 1)
    in_range = row_idx < N
    cand = jnp.take(cn, row_idx, axis=0)                  # (Q, kb*bn, D)
    s = jnp.einsum("qd,qnd->qn", qn.astype(F32), cand.astype(F32))
    s = jnp.where(in_range, s, -jnp.inf)
    # dedupe clipped duplicates (same row gathered twice scores twice —
    # mask all but the first occurrence)
    sorted_rows = jnp.sort(row_idx, axis=1)
    first = jnp.concatenate(
        [jnp.ones((Q, 1), bool),
         sorted_rows[:, 1:] != sorted_rows[:, :-1]], axis=1)
    order = jnp.argsort(row_idx, axis=1)
    inv = jnp.argsort(order, axis=1)
    keep = jnp.take_along_axis(first, inv, axis=1)
    s = jnp.where(keep, s, -jnp.inf)
    top_s, pos = jax.lax.top_k(s, k)
    top_i = jnp.take_along_axis(row_idx, pos, axis=1)
    return top_s, top_i
