"""jit'd wrapper: model layout (B, S, KH, hd) caches + position masking."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import decode_attention_flat


@partial(jax.jit, static_argnames=("window", "block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     block_s: int = 512, interpret: bool = True):
    """q: (B, 1, H, hd); caches: (B, S, KH, hd); pos scalar or (B,).
    Returns (B, 1, H, hd)."""
    B, _, H, hd = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    block_s = min(block_s, max(S, 8))
    pad = (-S) % block_s
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    k_pos = jnp.arange(S + pad)
    valid = k_pos[None, :] <= pos_b[:, None]
    valid = valid & (k_pos[None, :] < S)
    if window:
        valid = valid & (pos_b[:, None] - k_pos[None, :] < window)
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * KH, S + pad, hd)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * KH, S + pad, hd)
    qf = q.reshape(B, KH, G, hd).reshape(B * KH, G, hd)
    validf = jnp.repeat(valid, KH, axis=0)      # (B*KH, S+pad)
    o = decode_attention_flat(qf, kf, vf, validf, block_s=block_s,
                              interpret=interpret)
    return o.reshape(B, 1, H, hd)
