"""Pure-jnp oracle for flash-decode (single token over a masked cache)."""

from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def decode_attention_ref(q, k_cache, v_cache, pos, *, window: int = 0,
                         scale: float | None = None):
    """q: (B, 1, H, hd); caches: (B, S, KH, hd); pos: scalar or (B,)."""
    B, _, H, hd = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = scale if scale is not None else hd ** -0.5
    qg = (q.astype(F32) * scale).reshape(B, KH, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache.astype(F32))
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    k_pos = jnp.arange(S)
    mask = k_pos[None, :] <= pos_b[:, None]
    if window:
        mask = mask & (pos_b[:, None] - k_pos[None, :] < window)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-37)
    o = jnp.einsum("bkgs,bskh->bkgh", p / l, v_cache.astype(F32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)
