"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

GPU flash-decoding splits the KV sequence across SMs and combines partial
(m, l, acc) triples with a second reduction kernel.  On TPU the grid's
last dimension already iterates sequentially with VMEM-resident state, so
the same split-K idea becomes: stream S-blocks of the cache HBM->VMEM,
keep the running softmax state for all G grouped q-heads in VMEM scratch,
flush once.  HBM traffic = exactly one pass over the cache (the roofline
floor for decode), with no (B, H, S) score materialisation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, n_s: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG, F32)
        l_scr[...] = jnp.zeros(l_scr.shape, F32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, F32)

    q = q_ref[0].astype(F32) * scale                    # (G, hd)
    k = k_ref[0]                                        # (bs, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32)  # (G, bs)
    s = jnp.where(valid_ref[0][None, :], s, NEG)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    pv = jax.lax.dot_general(p, v_ref[0], (((1,), (0,)), ((), ())),
                             preferred_element_type=F32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = m_new

    @pl.when(si == n_s - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_flat(q, k_cache, v_cache, valid, *,
                          scale: float | None = None, block_s: int = 512,
                          interpret: bool = True):
    """q: (BKH, G, hd); caches: (BKH, S, hd); valid: (BKH, S) bool.

    Returns (BKH, G, hd).  S must be a multiple of block_s (ops.py pads and
    extends ``valid`` with False).
    """
    BKH, G, hd = q.shape
    S = k_cache.shape[1]
    n_s = S // block_s
    scale = scale if scale is not None else hd ** -0.5
    kernel = functools.partial(_decode_kernel, scale=scale, n_s=n_s)
    return pl.pallas_call(
        kernel,
        grid=(BKH, n_s),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, si: (b, 0, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, block_s, hd), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, block_s), lambda b, si: (b, si)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, si: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), F32),
            pltpu.VMEM((G,), F32),
            pltpu.VMEM((G, hd), F32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, valid)
