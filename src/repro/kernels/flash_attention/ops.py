"""jit'd public wrapper: (B, S, H, hd) layout, padding, GQA flattening."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KH, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    block_q = min(block_q, max(Sq, 8))
    block_k = min(block_k, max(Sk, 8))
    qp = _pad_to(q, 1, block_q)
    kp = _pad_to(k, 1, block_k)
    vp = _pad_to(v, 1, block_k)
    qf = qp.transpose(0, 2, 1, 3).reshape(B * H, qp.shape[1], hd)
    kf = kp.transpose(0, 2, 1, 3).reshape(B * KH, kp.shape[1], hd)
    vf = vp.transpose(0, 2, 1, 3).reshape(B * KH, vp.shape[1], hd)
    # flattened (B*H) rows must map to (B*KH) rows by integer division:
    # reorder q rows so heads of one group are adjacent: (B, KH, G) order.
    # q is (B, H) = (B, KH*G) flattened -> already groups G adjacent ✓
    o = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                             block_q=block_q, block_k=block_k, kv_len=Sk,
                             interpret=interpret)
    o = o.reshape(B, H, qp.shape[1], hd).transpose(0, 2, 1, 3)
    return o[:, :Sq]
