"""Pure-jnp oracle for the flash-attention kernel (full masked softmax)."""

from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None, kv_len: int | None = None):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KH, hd).  Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else hd ** -0.5
    kv_len = Sk if kv_len is None else kv_len
    qg = q.reshape(B, Sq, KH, G, hd).astype(F32) * scale
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg, k.astype(F32))
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = (k_pos[None, :] < kv_len)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    else:
        mask = jnp.broadcast_to(mask, (Sq, Sk))
    if window:
        mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-37)
    o = jnp.einsum("bkgqt,btkh->bkgqh", p / l, v.astype(F32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)
