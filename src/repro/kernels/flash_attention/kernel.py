"""Flash-attention Pallas TPU kernel (prefill path).

TPU adaptation of the FlashAttention-2 schedule:
  * grid (B*H, n_q_blocks, n_kv_blocks); the last grid dim is sequential on
    a TensorCore, so the online-softmax running state (m, l, acc) lives in
    VMEM scratch and carries across KV blocks for free — no atomics, no
    inter-block synchronisation (the CUDA pain point simply disappears);
  * (block_q x block_k) tiles sized for the MXU (multiples of 128) and a
    VMEM working set of ~(bq*hd + bk*hd + bq*bk) * 4B;
  * causal / sliding-window masks are evaluated per *block* first —
    fully-masked KV blocks are skipped with pl.when, so SWA prefill does
    O(S*W) work, not O(S^2);
  * GQA: the KV block index map divides the flattened (B*H) row down to its
    (B*KH) source row, so KV tiles are fetched once per group.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, n_k: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG, F32)
        l_scr[...] = jnp.zeros(l_scr.shape, F32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, F32)

    q_start = qi * block_q
    k_start = ki * block_k
    relevant = k_start < kv_len
    if causal:
        relevant &= k_start <= q_start + block_q - 1
    if window:
        relevant &= q_start - (k_start + block_k - 1) < window

    @pl.when(relevant)
    def _body():
        q = q_ref[0].astype(F32) * scale                       # (bq, hd)
        k = k_ref[0]                                           # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)    # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(p, v_ref[0], (((1,), (0,)), ((), ())),
                                 preferred_element_type=F32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         scale: float | None = None, block_q: int = 128,
                         block_k: int = 128, kv_len: int | None = None,
                         interpret: bool = True):
    """q: (BH, Sq, hd); k, v: (BKH, Sk, hd); BH % BKH == 0.

    Sq/Sk must be padded to block multiples by the caller (ops.py does it);
    ``kv_len`` masks the KV padding.
    """
    BH, Sq, hd = q.shape
    BKH, Sk, _ = k.shape
    G = BH // BKH
    scale = scale if scale is not None else hd ** -0.5
    n_q = Sq // block_q
    n_k = Sk // block_k
    kv_len = Sk if kv_len is None else kv_len

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k, kv_len=kv_len)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q,), F32),
            pltpu.VMEM((block_q, hd), F32),
        ],
        interpret=interpret,
    )(q, k, v)
