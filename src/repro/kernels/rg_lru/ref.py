"""Pure-jnp oracle: sequential diagonal linear recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def rg_lru_ref(a, b):
    """a, b: (B, S, di) -> h_all: (B, S, di) with h_t = a_t*h_{t-1} + b_t."""
    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    af = a.astype(F32).transpose(1, 0, 2)
    bf = b.astype(F32).transpose(1, 0, 2)
    h0 = jnp.zeros(af.shape[1:], F32)
    _, y = jax.lax.scan(step, h0, (af, bf))
    return y.transpose(1, 0, 2).astype(a.dtype)
