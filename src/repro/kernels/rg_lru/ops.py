"""jit'd wrapper with padding for the RG-LRU recurrence kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import rg_lru_flat


@partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def rg_lru(a, b, *, chunk: int = 128, block_d: int = 512,
           interpret: bool = True):
    """Diagonal recurrence h_t = a_t*h_{t-1} + b_t; a, b: (B, S, di).

    Padding uses a=1, b=0 (identity elements) so padded steps are no-ops.
    """
    B, S, di = a.shape
    chunk = min(chunk, max(S, 8))
    block_d = min(block_d, di)
    pad_s = (-S) % chunk
    pad_d = (-di) % block_d
    if pad_s or pad_d:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_d)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_d)))
    y = rg_lru_flat(a, b, chunk=chunk, block_d=block_d, interpret=interpret)
    return y[:, :S, :di]
