"""RG-LRU (Griffin gated linear recurrence) Pallas TPU kernel.

Generic diagonal recurrence h_t = a_t * h_t-1 + b_t over the channel dim,
with gates a, b precomputed by XLA (the block-diagonal gate matmuls are
MXU-friendly einsums; the *recurrence* is the memory-bound part worth a
kernel).  Same chunked-carry structure as ssm_scan: grid
(B, n_channel_blocks, n_time_chunks), carry (block_d,) in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _lru_kernel(a_ref, b_ref, o_ref, h_scr, *, chunk: int):
    ck = pl.program_id(2)

    @pl.when(ck == 0)
    def _init():
        h_scr[...] = jnp.zeros(h_scr.shape, F32)

    a = a_ref[0].astype(F32)                # (chunk, bd)
    b = b_ref[0].astype(F32)

    def step(t, carry):
        h, y = carry
        h = a[t] * h + b[t]
        return h, y.at[t].set(h)

    y0 = jnp.zeros(a.shape, F32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h_scr[...], y0))
    h_scr[...] = h
    o_ref[0] = y.astype(o_ref.dtype)


def rg_lru_flat(a, b, *, chunk: int = 128, block_d: int = 512,
                interpret: bool = True):
    """a, b: (B, S, di) -> h: (B, S, di); S % chunk == 0, di % block_d == 0."""
    B, S, di = a.shape
    kernel = functools.partial(_lru_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, di // block_d, S // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b_, d, c: (b_, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b_, d, c: (b_, c, d)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda b_, d, c: (b_, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_d,), F32)],
        interpret=interpret,
    )(a, b)
