"""qwen1.5-32b [dense]: 64L, d=5120, 40H (kv=40), d_ff=27392, V=152064.

QKV bias.  40 heads are padded to 48 for 16-way head sharding (DESIGN §6).
[hf:Qwen/Qwen1.5-0.5B scaled per assignment]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152_064, head_dim=128,
    qkv_bias=True, max_seq=131_072,
)

SMOKE = CONFIG.replace(
    name="qwen-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, max_seq=64,
)
