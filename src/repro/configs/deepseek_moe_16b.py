"""deepseek-moe-16b [moe]: 28L, d=2048, 16H (kv=16), fine-grained MoE.

64 routed experts top-6 + 2 shared experts, per-expert d_ff=1408.
[arXiv:2401.06066]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102_400, head_dim=128,
    num_experts=64, top_k=6, num_shared_experts=2, moe_d_ff=1408,
    max_seq=131_072,
)

SMOKE = CONFIG.replace(
    name="deepseek-moe-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=32, vocab_size=256,
    num_experts=8, top_k=2, num_shared_experts=1, moe_d_ff=32, max_seq=64,
)
