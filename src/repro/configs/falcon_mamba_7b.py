"""falcon-mamba-7b [ssm]: 64L pure Mamba-1, d=4096, ssm_state=16, V=65024.

Attention-free (d_ff=0): each layer is a single Mamba block.
d_inner = 2*d_model, dt_rank = d_model/16.  [arXiv:2410.05355]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=65_024, head_dim=64,
    pattern=("mamba",),
    d_inner=8192, ssm_state=16, conv_width=4, dt_rank=256,
    max_seq=1_048_576, scan_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="falcon-mamba-smoke", num_layers=2, d_model=64,
    vocab_size=256, d_inner=128, ssm_state=4, dt_rank=8, max_seq=64,
)
