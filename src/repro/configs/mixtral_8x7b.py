"""mixtral-8x7b [moe]: 32L, d=4096, 32H (kv=8), 8 experts top-2, SWA 4096.

Per-expert d_ff=14336; sliding-window attention.  [arXiv:2401.04088]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32_000, head_dim=128,
    pattern=("swa",), window_size=4096,
    num_experts=8, top_k=2, moe_d_ff=14336,
    rope_theta=1e6, max_seq=1_048_576,
)

SMOKE = CONFIG.replace(
    name="mixtral-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=96, vocab_size=256,
    moe_d_ff=96, window_size=8, max_seq=64,
)
