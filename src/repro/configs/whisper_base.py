"""whisper-base [audio]: 6L enc + 6L dec, d=512, 8H, d_ff=2048, V=51865.

Enc-dec with conv audio frontend STUBBED: input_specs feeds precomputed
log-mel frame embeddings (B, 1500, 512).  [arXiv:2212.04356]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, head_dim=64,
    norm="layernorm", glu=False, act="gelu", tie_embeddings=True,
    is_encoder_decoder=True, num_encoder_layers=6, encoder_seq=1500,
    frontend="audio", max_seq=32_768,
)

SMOKE = CONFIG.replace(
    name="whisper-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
    num_encoder_layers=2, encoder_seq=16, max_seq=64,
)
