"""phi-3-vision-4.2b [vlm]: 32L, d=3072, 32H (kv=32), d_ff=8192, V=32064.

phi3-mini backbone + CLIP vision frontend STUBBED: input_specs feeds
precomputed patch embeddings prepended to the text tokens.
[hf:microsoft/Phi-3-vision-128k-instruct]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    frontend="vision", num_prefix_tokens=144, max_seq=131_072,
)

SMOKE = CONFIG.replace(
    name="phi3v-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
    num_prefix_tokens=4, max_seq=64,
)
