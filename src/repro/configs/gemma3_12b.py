"""gemma3-12b [dense]: 48L, d=3840, 16H (kv=8), d_ff=15360, V=262144.

5 local (window 1024, theta 10k) : 1 global (theta 1M) interleave; qk-norm;
128k context.  [hf:google/gemma-3-1b-pt scaled per assignment]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
    d_ff=15360, vocab_size=262_144, head_dim=256,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window_size=1024, rope_theta=1e6, rope_theta_local=10_000.0,
    qk_norm=True, embed_scale=True, tie_embeddings=True,
    act="gelu", max_seq=1_048_576,
)

SMOKE = CONFIG.replace(
    name="gemma3-smoke", num_layers=6, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    window_size=8, max_seq=64,
)
