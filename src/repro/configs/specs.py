"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, cell)`` mirrors the batch-dict convention of
models/model.py for the shape cell kinds train / prefill / decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeCell

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    B, S = cell.global_batch, cell.seq_len
    cd = jnp.dtype(cfg.compute_dtype)
    batch = {}
    if cell.kind == "decode":
        batch["tokens"] = _sds((B, 1), I32)
        return batch
    if cfg.is_encoder_decoder:
        batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), cd)
        batch["tokens"] = _sds((B, S), I32)
    elif cfg.frontend == "vision":
        P = cfg.num_prefix_tokens
        batch["patches"] = _sds((B, P, cfg.d_model), cd)
        batch["tokens"] = _sds((B, S - P), I32)
    else:
        batch["tokens"] = _sds((B, S), I32)
    if cell.kind == "train":
        batch["labels"] = _sds(batch["tokens"].shape, I32)
    return batch


def cache_specs_sds(cfg: ModelConfig, cell: ShapeCell) -> list:
    """ShapeDtypeStructs matching models.model.init_cache output."""
    from repro.models import model as M
    return jax.eval_shape(lambda: M.init_cache(cfg, cell.global_batch,
                                               cell.seq_len))


def make_batch(cfg: ModelConfig, cell: ShapeCell, seed: int = 0) -> dict:
    """Concrete random batch matching input_specs (smoke tests, examples)."""
    specs = input_specs(cfg, cell)
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if s.dtype == I32:
            out[name] = jax.random.randint(sub, s.shape, 0,
                                           max(cfg.vocab_size - 1, 2), I32)
        else:
            out[name] = jax.random.normal(sub, s.shape, s.dtype)
    return out
