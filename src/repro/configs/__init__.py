"""Architecture config registry: ``get_config(arch)`` / ``list_archs()``.

One module per assigned architecture; each exposes ``CONFIG`` (the exact
assigned full-size config) and ``SMOKE`` (a reduced same-family config for
CPU smoke tests).
"""

from __future__ import annotations

import importlib

_ARCHS = {
    "whisper-base": "whisper_base",
    "phi-3-vision-4.2b": "phi3_vision",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-8b": "granite_8b",
    "qwen1.5-32b": "qwen15_32b",
    "gemma3-12b": "gemma3_12b",
    "olmo-1b": "olmo_1b",
}


def list_archs():
    return list(_ARCHS)


def _mod(arch: str):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCHS[arch]}")


def get_config(arch: str, *, shard_multiple: int = 1):
    cfg = _mod(arch).CONFIG
    return cfg.replace(shard_multiple=shard_multiple) if shard_multiple > 1 \
        else cfg


def get_smoke_config(arch: str):
    return _mod(arch).SMOKE
