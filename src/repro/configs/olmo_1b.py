"""olmo-1b [dense]: 16L, d=2048, 16H (kv=16), d_ff=8192, V=50304.

Non-parametric LayerNorm; tied embeddings; SwiGLU.  [arXiv:2402.00838]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=50_304, head_dim=128,
    norm="nonparam_ln", tie_embeddings=True, max_seq=131_072,
)

SMOKE = CONFIG.replace(
    name="olmo-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, max_seq=64,
)
