"""granite-8b [dense]: 36L, d=4096, 32H (kv=8), d_ff=14336, V=49152.

Llama-architecture code model.  [arXiv:2405.04324]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=49_152, head_dim=128,
    max_seq=131_072,
)

SMOKE = CONFIG.replace(
    name="granite-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, max_seq=64,
)
