"""recurrentgemma-9b [hybrid]: 38L, d=4096, 16H (kv=1), d_ff=12288, V=256000.

Griffin: RG-LRU recurrent blocks + local attention, 1 attn : 2 rec
(pattern rec,rec,local; window 2048).  [arXiv:2402.19427]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256_000, head_dim=256,
    pattern=("rec", "rec", "local"), window_size=2048,
    d_inner=4096, conv_width=4, rglru_blocks=16,
    act="gelu", glu=True, embed_scale=True, tie_embeddings=True,
    max_seq=1_048_576, scan_chunk=1024,
)

SMOKE = CONFIG.replace(
    name="recurrentgemma-smoke", num_layers=3, d_model=64, num_heads=4,
    num_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256,
    d_inner=64, rglru_blocks=4, window_size=8, max_seq=64,
)
