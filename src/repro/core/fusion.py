"""Score-fusion functions for hybrid search (paper Table 1: FUSION).

Implements rrf / combsum / combmnz / combmed / combanz over N retriever
score columns, vectorised with numpy.  Missing scores (a document absent
from one retriever's top-k) are NaN.
"""

from __future__ import annotations

import numpy as np

FUSION_METHODS = ("rrf", "combsum", "combmnz", "combmed", "combanz")


def _scores_matrix(score_lists) -> np.ndarray:
    """Stack score columns -> (n_docs, n_retrievers) float with NaN holes."""
    cols = [np.asarray(s, dtype=np.float64) for s in score_lists]
    n = {len(c) for c in cols}
    if len(n) != 1:
        raise ValueError("fusion inputs must share length")
    return np.stack(cols, axis=1)


def rrf(*score_lists, k: int = 60) -> np.ndarray:
    """Reciprocal rank fusion: sum_i 1/(k + rank_i).  NaN -> no contribution."""
    m = _scores_matrix(score_lists)
    out = np.zeros(m.shape[0])
    for j in range(m.shape[1]):
        col = m[:, j]
        valid = ~np.isnan(col)
        order = np.argsort(-np.where(valid, col, -np.inf), kind="stable")
        ranks = np.empty(m.shape[0], dtype=np.int64)
        ranks[order] = np.arange(1, m.shape[0] + 1)
        out += np.where(valid, 1.0 / (k + ranks), 0.0)
    return out


def combsum(*score_lists) -> np.ndarray:
    m = _scores_matrix(score_lists)
    return np.nansum(m, axis=1)


def combmnz(*score_lists) -> np.ndarray:
    m = _scores_matrix(score_lists)
    nz = np.sum(~np.isnan(m) & (m != 0), axis=1)
    return np.nansum(m, axis=1) * nz


def combmed(*score_lists) -> np.ndarray:
    m = _scores_matrix(score_lists)
    with np.errstate(all="ignore"):
        med = np.nanmedian(m, axis=1)
    return np.where(np.isnan(med), 0.0, med)


def combanz(*score_lists) -> np.ndarray:
    m = _scores_matrix(score_lists)
    nz = np.maximum(np.sum(~np.isnan(m), axis=1), 1)
    return np.nansum(m, axis=1) / nz


def fusion(method: str, *score_lists, **kw) -> np.ndarray:
    fns = {"rrf": rrf, "combsum": combsum, "combmnz": combmnz,
           "combmed": combmed, "combanz": combanz}
    if method not in fns:
        raise ValueError(f"unknown fusion method {method!r}; "
                         f"choices: {FUSION_METHODS}")
    return fns[method](*score_lists, **kw)


def max_normalize(scores) -> np.ndarray:
    """Per-retriever max normalisation (paper Query 3 step 4)."""
    s = np.asarray(scores, dtype=np.float64)
    with np.errstate(all="ignore"):
        mx = np.nanmax(np.abs(s))
    return s / mx if mx and not np.isnan(mx) else s
