"""Score-fusion functions for hybrid search (paper Table 1: FUSION).

Implements rrf / combsum / combmnz / combmed / combanz over N retriever
score columns, vectorised with numpy.  Missing scores (a document absent
from one retriever's top-k) are NaN.

These are the fusion methods the ``hybrid_topk`` plan operator
(``engine.retrieval_ops``) dispatches on — the paper's Query 3 step 4
composes them relationally over the per-retriever score columns of a
FULL OUTER JOIN.  Edge-case contract (hardened):

  * a retriever column that is ALL NaN contributes nothing (it behaves
    as an absent retriever, never poisons the fused scores with NaN);
  * a single retriever column is valid input (fusion degenerates to a
    monotone transform of that retriever's ranking);
  * ``rrf`` assigns competition ("1224") ranks, so tied scores share
    the rank of their tie group's first element — fused scores are
    independent of the retrievers' internal tie-break order;
  * ``combmnz`` of a row with zero non-NaN entries is exactly 0.0 (no
    0 * nansum-of-empty-slice degeneracy), and fused outputs never
    contain NaN.
"""

from __future__ import annotations

import numpy as np

FUSION_METHODS = ("rrf", "combsum", "combmnz", "combmed", "combanz")


def _scores_matrix(score_lists) -> np.ndarray:
    """Stack score columns -> (n_docs, n_retrievers) float with NaN holes."""
    if not score_lists:
        raise ValueError("fusion needs at least one score column")
    cols = [np.asarray(s, dtype=np.float64) for s in score_lists]
    n = {len(c) for c in cols}
    if len(n) != 1:
        raise ValueError("fusion inputs must share length")
    return np.stack(cols, axis=1)


def rrf(*score_lists, k: int = 60) -> np.ndarray:
    """Reciprocal rank fusion: sum_i 1/(k + rank_i).  NaN -> no contribution.

    Ranks are competition ranks ("1224"): documents with equal scores in
    one retriever share that tie group's first rank, so the fused score
    does not depend on the arbitrary order a retriever reports ties in."""
    m = _scores_matrix(score_lists)
    n = m.shape[0]
    out = np.zeros(n)
    if n == 0:
        return out
    for j in range(m.shape[1]):
        col = m[:, j]
        valid = ~np.isnan(col)
        if not valid.any():
            continue                    # all-NaN retriever: absent
        vals = np.where(valid, col, -np.inf)
        order = np.argsort(-vals, kind="stable")
        sv = vals[order]
        # index of each sorted element's tie-group head
        tied = np.zeros(n, dtype=bool)
        tied[1:] = sv[1:] == sv[:-1]
        head = np.maximum.accumulate(
            np.where(tied, 0, np.arange(n)))
        ranks = np.empty(n, dtype=np.int64)
        ranks[order] = head + 1
        out += np.where(valid, 1.0 / (k + ranks), 0.0)
    return out


def combsum(*score_lists) -> np.ndarray:
    m = _scores_matrix(score_lists)
    with np.errstate(all="ignore"):
        return np.nansum(m, axis=1)


def combmnz(*score_lists) -> np.ndarray:
    m = _scores_matrix(score_lists)
    with np.errstate(all="ignore"):
        nz = np.sum(~np.isnan(m) & (m != 0), axis=1)
        total = np.nansum(m, axis=1)
    # a row with zero non-NaN entries has no evidence at all: exactly 0,
    # never 0 * <empty-slice nansum> style degenerate arithmetic
    return np.where(nz > 0, total * nz, 0.0)


def combmed(*score_lists) -> np.ndarray:
    m = _scores_matrix(score_lists)
    med = np.zeros(m.shape[0])
    # nanmedian WARNS on all-NaN rows (errstate does not cover it);
    # compute it only where at least one retriever scored the doc
    some = ~np.all(np.isnan(m), axis=1)
    if some.any():
        med[some] = np.nanmedian(m[some], axis=1)
    return med


def combanz(*score_lists) -> np.ndarray:
    m = _scores_matrix(score_lists)
    with np.errstate(all="ignore"):
        nz = np.maximum(np.sum(~np.isnan(m), axis=1), 1)
        return np.nansum(m, axis=1) / nz


def fusion(method: str, *score_lists, **kw) -> np.ndarray:
    fns = {"rrf": rrf, "combsum": combsum, "combmnz": combmnz,
           "combmed": combmed, "combanz": combanz}
    if method not in fns:
        raise ValueError(f"unknown fusion method {method!r}; "
                         f"choices: {FUSION_METHODS}")
    return fns[method](*score_lists, **kw)


def max_normalize(scores) -> np.ndarray:
    """Per-retriever max normalisation (paper Query 3 step 4)."""
    s = np.asarray(scores, dtype=np.float64)
    with np.errstate(all="ignore"):
        mx = np.nanmax(np.abs(s)) if len(s) else np.nan
    return s / mx if mx and not np.isnan(mx) else s
