"""Prediction cache: reuse LLM outputs within and across queries (paper §2.3).

Exact-match cache keyed on (model@version, prompt@version or inline text,
function kind, serialization, decode params, serialized input tuple).
LRU in memory with optional JSON-lines persistence so reuse survives
process restarts ("across queries").
"""

from __future__ import annotations

import hashlib
import json
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Any, Optional


def cache_key(model_ref: str, prompt_key: str, function: str,
              serialization: str, payload: str, params: str = "") -> str:
    h = hashlib.sha256()
    for part in (model_ref, prompt_key, function, serialization, payload,
                 params):
        h.update(part.encode())
        h.update(b"\x1f")
    return h.hexdigest()


class PredictionCache:
    def __init__(self, capacity: int = 100_000,
                 persist_path: Optional[str] = None):
        self.capacity = capacity
        self._data: OrderedDict[str, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self._persist_path = Path(persist_path) if persist_path else None
        if self._persist_path and self._persist_path.exists():
            self._load()

    def get(self, key: str):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return True, self._data[key]
            self.misses += 1
            return False, None

    def put(self, key: str, value):
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
        if self._persist_path:
            with self._lock:
                with self._persist_path.open("a") as f:
                    f.write(json.dumps({"k": key, "v": value}) + "\n")

    def _load(self):
        for line in self._persist_path.read_text().splitlines():
            try:
                rec = json.loads(line)
                self._data[rec["k"]] = rec["v"]
            except (json.JSONDecodeError, KeyError):
                continue
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)

    @property
    def stats(self):
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._data)}

    def clear(self):
        with self._lock:
            self._data.clear()
            self.hits = self.misses = 0
