"""Prediction cache: reuse LLM outputs within and across queries (paper §2.3).

Exact-match cache keyed on (model@version, prompt@version or inline text,
function kind, serialization, decode params, serialized input tuple).
LRU in memory with optional JSON-lines persistence so reuse survives
process restarts ("across queries").
"""

from __future__ import annotations

import hashlib
import json
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Any, Optional


def cache_key(model_ref: str, prompt_key: str, function: str,
              serialization: str, payload: str, params: str = "") -> str:
    h = hashlib.sha256()
    for part in (model_ref, prompt_key, function, serialization, payload,
                 params):
        h.update(part.encode())
        h.update(b"\x1f")
    return h.hexdigest()


# once the JSONL holds this many superseded lines, put() compacts in place
_COMPACT_MIN_LINES = 4096


class PredictionCache:
    def __init__(self, capacity: int = 100_000,
                 persist_path: Optional[str] = None):
        self.capacity = capacity
        self._data: OrderedDict[str, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self._persisted_lines = 0
        self._persist_path = Path(persist_path) if persist_path else None
        if self._persist_path and self._persist_path.exists():
            self._load()

    def get(self, key: str):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return True, self._data[key]
            self.misses += 1
            return False, None

    def peek(self, key: str):
        """Lookup without touching LRU order or hit/miss counters (the
        scheduler's single-flight re-check uses this so its second look
        does not distort the session's cache statistics)."""
        with self._lock:
            if key in self._data:
                return True, self._data[key]
            return False, None

    @property
    def persist_path(self) -> Optional[Path]:
        return self._persist_path

    def put(self, key: str, value):
        with self._lock:
            noop = key in self._data and self._data[key] == value
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
            if noop:
                return       # re-put of an identical entry: no disk append
            self._persisted_lines += 1
            do_compact = (self._persist_path is not None
                          and self._persisted_lines
                          > max(_COMPACT_MIN_LINES, 2 * len(self._data)))
            if self._persist_path:
                with self._persist_path.open("a") as f:
                    f.write(json.dumps({"k": key, "v": value}) + "\n")
        if do_compact:
            self.compact()

    def compact(self):
        """Rewrite the persistence file from the live LRU contents,
        dropping superseded/evicted lines accumulated by appends."""
        if not self._persist_path:
            return
        with self._lock:
            tmp = self._persist_path.with_suffix(".tmp")
            with tmp.open("w") as f:
                for k, v in self._data.items():
                    f.write(json.dumps({"k": k, "v": v}) + "\n")
            tmp.replace(self._persist_path)
            self._persisted_lines = len(self._data)

    def _load(self):
        lines = self._persist_path.read_text().splitlines()
        for line in lines:
            try:
                rec = json.loads(line)
                self._data[rec["k"]] = rec["v"]
            except (json.JSONDecodeError, KeyError):
                continue
        self._persisted_lines = len(lines)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)

    @property
    def stats(self):
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._data)}

    def clear(self):
        with self._lock:
            self._data.clear()
            self.hits = self.misses = 0


class SelectivityStore:
    """JSON sidecar persisting per-prompt ``llm_filter`` pass rates.

    Lives alongside the prediction cache (default path: the cache's
    JSONL path + ``.selectivity.json``) so cost-ordered filter chains
    have real statistics on first sight of a recurring prompt across
    sessions.  Entries are keyed by the prompt's cache identity
    (``name@version`` for catalog prompts, ``inline:<text>`` otherwise),
    so a prompt or model re-version naturally orphans old entries;
    ``prune_stale`` additionally drops versioned keys that a catalog
    resolves to a *newer* ref, keeping the sidecar from growing with
    dead versions."""

    def __init__(self, path: str):
        self.path = Path(path)
        self._lock = threading.Lock()

    def load(self) -> dict[str, list]:
        if not self.path.exists():
            return {}
        try:
            data = json.loads(self.path.read_text())
        except (json.JSONDecodeError, OSError):
            return {}
        out: dict[str, list] = {}
        for pid, obs in data.get("stats", {}).items():
            if (isinstance(obs, list) and len(obs) == 2
                    and all(isinstance(x, int) and x >= 0 for x in obs)
                    and obs[0] <= obs[1]):
                out[pid] = [obs[0], obs[1]]
        return out

    def save(self, stats: dict[str, list]):
        with self._lock:
            tmp = self.path.with_suffix(".tmp")
            tmp.write_text(json.dumps({"stats": stats}, indent=1))
            tmp.replace(self.path)

    @staticmethod
    def prune_stale(stats: dict[str, list], catalog) -> dict[str, list]:
        """Drop entries whose ``name@version`` key is superseded by a
        newer prompt version in ``catalog`` (re-versioned prompts start
        from fresh statistics)."""
        out = {}
        for pid, obs in stats.items():
            name, sep, _ = pid.rpartition("@")
            if sep and not pid.startswith("inline:"):
                live = catalog.get_prompt(name)
                if live is not None and live.ref != pid:
                    continue
            out[pid] = obs
        return out


# per-model latency observations kept in the calibration sidecar: enough
# for stable percentiles without the file growing with every request
CALIBRATION_WINDOW = 256


class CalibrationStore:
    """JSON sidecar persisting per-model execution statistics aggregated
    from ``ExecutionReport``s: request/retry counts, tuples served (mean
    batch size), and a bounded window of recent per-request latencies.

    This is what turns the optimizer's flat serialization-sample cost
    model into a *calibrated* one: ``explain()``'s ``waves``
    critical-path estimate multiplies by the model's observed latency
    percentiles instead of guessing, and the speculative-dispatch
    decision compares serial vs speculative wall-clock from the same
    statistics.  Lives alongside the prediction cache (default path:
    the cache's JSONL path + ``.calibration.json``), keyed by the
    model's ``name@version`` ref so a model re-version orphans old
    entries; ``prune_stale`` drops refs a catalog resolves to a newer
    version.  A corrupt or unreadable sidecar loads as empty — the cost
    model degrades to uncalibrated, never crashes."""

    def __init__(self, path: str):
        self.path = Path(path)
        self._lock = threading.Lock()

    @staticmethod
    def _valid(rec) -> bool:
        if not isinstance(rec, dict):
            return False
        for k in ("requests", "retries", "tuples"):
            v = rec.get(k)
            if not isinstance(v, int) or v < 0:
                return False
        lat = rec.get("latency_s")
        return (isinstance(lat, list)
                and all(isinstance(x, (int, float)) and x >= 0
                        for x in lat))

    def load(self) -> dict[str, dict]:
        if not self.path.exists():
            return {}
        try:
            data = json.loads(self.path.read_text())
        except (json.JSONDecodeError, OSError):
            return {}
        if not isinstance(data, dict):
            return {}
        out: dict[str, dict] = {}
        for ref, rec in data.get("models", {}).items():
            if self._valid(rec):
                out[ref] = {"requests": rec["requests"],
                            "retries": rec["retries"],
                            "tuples": rec["tuples"],
                            "latency_s": [float(x) for x in
                                          rec["latency_s"]
                                          [-CALIBRATION_WINDOW:]]}
        return out

    def save(self, stats: dict[str, dict]):
        with self._lock:
            tmp = self.path.with_suffix(".tmp")
            tmp.write_text(json.dumps({"models": stats}, indent=1))
            tmp.replace(self.path)

    @staticmethod
    def prune_stale(stats: dict[str, dict], catalog) -> dict[str, dict]:
        """Drop entries whose ``name@version`` ref is superseded by a
        newer model version in ``catalog`` (a re-versioned model may
        have a new arch/window — its latency profile starts fresh)."""
        out = {}
        for ref, rec in stats.items():
            name, sep, _ = ref.rpartition("@")
            if sep:
                live = catalog.get_model(name)
                if live is not None and live.ref != ref:
                    continue
            out[ref] = rec
        return out
