"""Prediction cache: reuse LLM outputs within and across queries (paper §2.3).

Exact-match cache keyed on (model@version, prompt@version or inline text,
function kind, serialization, decode params, serialized input tuple).
LRU in memory with optional JSON-lines persistence so reuse survives
process restarts ("across queries").
"""

from __future__ import annotations

import hashlib
import json
import logging
import math
import threading
from collections import OrderedDict
from pathlib import Path
from typing import Any, Optional

logger = logging.getLogger(__name__)


def cache_key(model_ref: str, prompt_key: str, function: str,
              serialization: str, payload: str, params: str = "") -> str:
    h = hashlib.sha256()
    for part in (model_ref, prompt_key, function, serialization, payload,
                 params):
        h.update(part.encode())
        h.update(b"\x1f")
    return h.hexdigest()


# once the JSONL holds this many superseded lines, put() compacts in place
_COMPACT_MIN_LINES = 4096


def _tmp_path(path: Path) -> Path:
    """Atomic-replace staging name: the FULL filename + ``.tmp``.

    ``path.with_suffix(".tmp")`` strips only the last suffix, so
    multi-dot sidecar paths get mangled (``cache.jsonl.selectivity``
    -> ``cache.jsonl.tmp``) and sidecars sharing a prefix would stage
    through the SAME temp file and corrupt each other's atomic
    replace.  Appending to the full name keeps staging files unique
    per destination."""
    return path.with_name(path.name + ".tmp")


class PredictionCache:
    def __init__(self, capacity: int = 100_000,
                 persist_path: Optional[str] = None):
        self.capacity = capacity
        self._data: OrderedDict[str, Any] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self._persisted_lines = 0
        self._persist_path = Path(persist_path) if persist_path else None
        if self._persist_path and self._persist_path.exists():
            self._load()

    def get(self, key: str):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return True, self._data[key]
            self.misses += 1
            return False, None

    def peek(self, key: str):
        """Lookup without touching LRU order or hit/miss counters (the
        scheduler's single-flight re-check uses this so its second look
        does not distort the session's cache statistics)."""
        with self._lock:
            if key in self._data:
                return True, self._data[key]
            return False, None

    @property
    def persist_path(self) -> Optional[Path]:
        return self._persist_path

    def put(self, key: str, value):
        with self._lock:
            noop = key in self._data and self._data[key] == value
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)
            if noop:
                return       # re-put of an identical entry: no disk append
            self._persisted_lines += 1
            do_compact = (self._persist_path is not None
                          and self._persisted_lines
                          > max(_COMPACT_MIN_LINES, 2 * len(self._data)))
            if self._persist_path:
                with self._persist_path.open("a") as f:
                    f.write(json.dumps({"k": key, "v": value}) + "\n")
        if do_compact:
            self.compact()

    def compact(self):
        """Rewrite the persistence file from the live LRU contents,
        dropping superseded/evicted lines accumulated by appends."""
        if not self._persist_path:
            return
        with self._lock:
            tmp = _tmp_path(self._persist_path)
            with tmp.open("w") as f:
                for k, v in self._data.items():
                    f.write(json.dumps({"k": k, "v": v}) + "\n")
            tmp.replace(self._persist_path)
            self._persisted_lines = len(self._data)

    def _load(self):
        lines = self._persist_path.read_text().splitlines()
        for line in lines:
            try:
                rec = json.loads(line)
                self._data[rec["k"]] = rec["v"]
            except (json.JSONDecodeError, KeyError) as exc:
                logger.debug("cache line skipped (%s): %.80s", exc, line)
                continue
        self._persisted_lines = len(lines)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)

    @property
    def stats(self):
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._data)}

    def clear(self):
        with self._lock:
            self._data.clear()
            self.hits = self.misses = 0


# bounded observation window for selectivity statistics: once a prompt's
# recorded total exceeds this many tuples the counters are rescaled down,
# so recent observations carry at least 1/WINDOW of the weight and a
# shifted data distribution re-learns within ~one window instead of
# fighting an unbounded historical average (speculative waste budgets
# and filter ordering depend on the estimate tracking the CURRENT data)
SELECTIVITY_WINDOW = 1024


def bound_observations(passed: int, total: int,
                       window: int = SELECTIVITY_WINDOW
                       ) -> tuple[int, int]:
    """Rescale an aggregate (passed, total) pair so ``total`` never
    exceeds ``window`` — exponential forgetting with bounded weight."""
    if total <= window:
        return passed, total
    scale = window / total
    return min(window, int(round(passed * scale))), window


class SelectivityStore:
    """JSON sidecar persisting per-prompt ``llm_filter`` pass rates.

    Lives alongside the prediction cache (default path: the cache's
    JSONL path + ``.selectivity.json``) so cost-ordered filter chains
    have real statistics on first sight of a recurring prompt across
    sessions.  Entries are keyed by the prompt's cache identity
    (``name@version`` for catalog prompts, ``inline:<text>`` otherwise),
    so a prompt or model re-version naturally orphans old entries;
    ``prune_stale`` additionally drops versioned keys that a catalog
    resolves to a *newer* ref, keeping the sidecar from growing with
    dead versions."""

    def __init__(self, path: str):
        self.path = Path(path)
        self._lock = threading.Lock()

    def load(self) -> dict[str, list]:
        if not self.path.exists():
            return {}
        try:
            data = json.loads(self.path.read_text())
        except (json.JSONDecodeError, OSError) as exc:
            logger.debug("sidecar %s unreadable: %s", self.path, exc)
            return {}
        out: dict[str, list] = {}
        for pid, obs in data.get("stats", {}).items():
            if (isinstance(obs, list) and len(obs) == 2
                    and all(isinstance(x, int) and x >= 0 for x in obs)
                    and obs[0] <= obs[1]):
                # sidecars written before windowing may carry unbounded
                # totals; bound them on load so drift detection applies
                out[pid] = list(bound_observations(obs[0], obs[1]))
        return out

    def save(self, stats: dict[str, list]):
        with self._lock:
            tmp = _tmp_path(self.path)
            tmp.write_text(json.dumps({"stats": stats}, indent=1))
            tmp.replace(self.path)

    @staticmethod
    def prune_stale(stats: dict[str, list], catalog) -> dict[str, list]:
        """Drop entries whose ``name@version`` key is superseded by a
        newer prompt version in ``catalog`` (re-versioned prompts start
        from fresh statistics)."""
        out = {}
        for pid, obs in stats.items():
            name, sep, _ = pid.rpartition("@")
            if sep and not pid.startswith("inline:"):
                live = catalog.get_prompt(name)
                if live is not None and live.ref != pid:
                    continue
            out[pid] = obs
        return out


# per-model latency observations kept in the calibration sidecar: enough
# for stable percentiles without the file growing with every request
CALIBRATION_WINDOW = 256

# request/retry counters are bounded the same way as selectivity: beyond
# this many admissions the counters rescale, so a model whose overflow
# behaviour changed (bigger window, fixed serialization) re-learns its
# headroom instead of dragging historical retries forever
CALIBRATION_COUNT_WINDOW = 4096

# calibration-aware batch sizing: floor and activation threshold for the
# planning headroom derived from observed overflow-retry rates
HEADROOM_MIN = 0.5          # never plan below half the context budget
HEADROOM_MIN_OBS = 8        # admissions needed before trusting the rate


def headroom_factor(requests: int, retries: int) -> float:
    """Per-model batch-planning headroom from observed overflow retries.

    A retry means an admitted batch exceeded the provider's real budget
    — the planner's token estimates undercount by roughly the overflow
    fraction (serialization framing, id wrappers), so shaving the
    planned budget by the observed retry rate removes most splits up
    front.  Returns 1.0 (full budget) until enough admissions exist to
    trust the rate, floored at ``HEADROOM_MIN``."""
    total = requests + retries
    if total < HEADROOM_MIN_OBS or retries <= 0:
        return 1.0
    return max(HEADROOM_MIN, 1.0 - retries / total)


def corpus_fingerprint(texts) -> str:
    """Order-sensitive content fingerprint of a retrieval corpus.

    Keys the ``IndexStore`` (with the embedding model's ref) so a
    rebuilt index is reused exactly when the corpus texts AND their
    order are unchanged — candidate doc ids index into the corpus, so
    order is part of the identity.  Each text is length-prefixed so no
    choice of text content can make two different corpora collide
    (separator bytes inside a text cannot fake a document boundary)."""
    h = hashlib.sha256()
    for t in texts:
        payload = str(t).encode()
        h.update(str(len(payload)).encode())
        h.update(b":")
        h.update(payload)
    return h.hexdigest()


# persisted vector indexes are whole embedding matrices; keep only the
# most recent corpora so the sidecar stays bounded
INDEX_STORE_CAPACITY = 8


class IndexStore:
    """JSON sidecar memoising built vector indexes, keyed by
    ``(embedding model ref, corpus fingerprint)``.

    The expensive part of paper Query 3 is embedding the corpus; a
    repeated RAG query over an unchanged corpus should pay ZERO embed
    requests, not a prediction-cache scan over every document.  This
    sidecar persists the raw embedding matrix next to the prediction
    cache (default path: the cache's JSONL path + ``.index.json``) with
    the same discipline as the other sidecars: full-filename ``.tmp``
    atomic replace, corrupt-file recovery (a bad sidecar loads as empty
    and the index is rebuilt, never a crash), and ``prune_stale`` drops
    entries whose model ``name@version`` a catalog resolves to a newer
    ref.  Bounded to ``INDEX_STORE_CAPACITY`` corpora, oldest first.

    Indexes are stored as SEGMENTS so a corpus append persists only the
    delta: ``append_segment`` records the grown corpus as the base
    entry's segment chain plus one new segment holding just the new
    rows.  Entries written before segmentation (``{"vectors": ...}``)
    still load; the first append converts them in place.  Eviction and
    pruning garbage-collect segments no surviving entry references, so
    capacity accounting covers segment payloads too (no orphaned
    sidecar data)."""

    def __init__(self, path: str, capacity: int = INDEX_STORE_CAPACITY):
        self.path = Path(path)
        self.capacity = capacity
        self._lock = threading.Lock()
        # file writes serialize on their own lock so get()/has() (the
        # optimizer's index_cached probe, other retrieval nodes) never
        # block behind a multi-megabyte sidecar rewrite
        self._io_lock = threading.Lock()
        self._version = 0               # bumped per mutation, under _lock
        self._written = 0               # last version flushed to disk
        self._data: OrderedDict[str, dict] = OrderedDict()
        self._segments: dict[str, list] = {}
        self._load()

    @staticmethod
    def _key(model_ref: str, fingerprint: str) -> str:
        return f"{model_ref}|{fingerprint}"

    @staticmethod
    def _valid_matrix(vecs) -> bool:
        if not isinstance(vecs, list) or not vecs:
            return False
        width = {len(v) if isinstance(v, list) else -1 for v in vecs}
        if len(width) != 1 or -1 in width:
            return False
        return all(isinstance(x, (int, float)) and x == x
                   for v in vecs for x in v)

    def _valid(self, rec, segments=None) -> bool:
        if not isinstance(rec, dict):
            return False
        if "segments" in rec:
            segs = rec["segments"]
            pool = self._segments if segments is None else segments
            return (isinstance(segs, list) and segs
                    and all(isinstance(s, str) and s in pool for s in segs))
        return self._valid_matrix(rec.get("vectors"))

    @staticmethod
    def _rows(rec) -> int:
        if "segments" in rec:
            return int(rec.get("n", 0))
        return len(rec["vectors"])

    def _gc_segments(self):
        """Drop segments no live entry references (call under _lock).
        Evicting an entry frees its segment payloads unless a longer
        chain still shares them."""
        live = {s for rec in self._data.values()
                for s in rec.get("segments", ())}
        self._segments = {k: v for k, v in self._segments.items()
                          if k in live}

    def _evict(self):
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
        self._gc_segments()

    def _load(self):
        if not self.path.exists():
            return
        try:
            data = json.loads(self.path.read_text())
        except (json.JSONDecodeError, OSError) as exc:
            logger.debug("sidecar %s unreadable: %s", self.path, exc)
            return
        if not isinstance(data, dict):
            return
        segments = {k: v for k, v in data.get("segments", {}).items()
                    if self._valid_matrix(v)}
        for key, rec in data.get("indexes", {}).items():
            if self._valid(rec, segments):
                self._data[key] = rec
        self._segments = segments
        self._evict()

    def get(self, model_ref: str, fingerprint: str):
        """The stored embedding matrix as float32, or None."""
        import numpy as np
        with self._lock:
            rec = self._data.get(self._key(model_ref, fingerprint))
            if rec is None:
                return None
            if "segments" in rec:
                return np.concatenate(
                    [np.asarray(self._segments[s], np.float32)
                     for s in rec["segments"]], axis=0)
            return np.asarray(rec["vectors"], np.float32)

    def entries(self, model_ref: str) -> list:
        """(fingerprint, n_rows) for every stored corpus of this model,
        the prefix-append candidates ``ensure_index`` matches against."""
        prefix = f"{model_ref}|"
        with self._lock:
            return [(k[len(prefix):], self._rows(rec))
                    for k, rec in self._data.items()
                    if k.startswith(prefix)]

    def _snapshot(self) -> tuple[dict, int]:
        """Bump the version and capture a snapshot (call under _lock)."""
        self._version += 1
        return ({"indexes": dict(self._data),
                 "segments": dict(self._segments)}, self._version)

    def _write_snapshot(self, snapshot: dict, version: int):
        """Persist one mutation's snapshot.  The version guard makes a
        late writer with a stale snapshot a no-op, so concurrent puts
        cannot roll the file back to a state missing a newer entry."""
        payload = json.dumps(snapshot)
        with self._io_lock:
            if version <= self._written:
                return
            tmp = _tmp_path(self.path)
            tmp.write_text(payload)
            tmp.replace(self.path)
            self._written = version

    @staticmethod
    def _matrix(vectors):
        import numpy as np
        v = np.asarray(vectors, np.float32)
        if v.ndim != 2 or not v.size:
            return None
        # float32 -> python float -> float32 roundtrips exactly, so a
        # reloaded index reproduces the in-session one bit-for-bit
        return [[float(x) for x in row] for row in v]

    def put(self, model_ref: str, fingerprint: str, vectors):
        mat = self._matrix(vectors)
        if mat is None:
            return
        key = self._key(model_ref, fingerprint)
        with self._lock:
            self._data[key] = {"vectors": mat}
            self._data.move_to_end(key)
            self._evict()
            snapshot, version = self._snapshot()
        self._write_snapshot(snapshot, version)

    def _as_segments(self, key: str, rec: dict) -> dict:
        """Convert a legacy whole-matrix entry to a one-segment chain
        (call under _lock)."""
        if "segments" in rec:
            return rec
        seg = f"{key}#0"
        self._segments[seg] = rec["vectors"]
        new = {"segments": [seg], "n": len(rec["vectors"])}
        self._data[key] = new
        return new

    def append_segment(self, model_ref: str, base_fingerprint: str,
                       fingerprint: str, delta_vectors):
        """Persist a grown corpus as ``base``'s segment chain plus one
        new segment holding only ``delta_vectors``.  Falls back to
        nothing (caller should ``put`` the full matrix) when the base
        entry is absent.  Returns True when the append was recorded."""
        mat = self._matrix(delta_vectors)
        if mat is None:
            return False
        base_key = self._key(model_ref, base_fingerprint)
        key = self._key(model_ref, fingerprint)
        with self._lock:
            base = self._data.get(base_key)
            if base is None:
                return False
            base = self._as_segments(base_key, base)
            seg = f"{key}#{len(base['segments'])}"
            self._segments[seg] = mat
            self._data[key] = {"segments": base["segments"] + [seg],
                               "n": self._rows(base) + len(mat)}
            self._data.move_to_end(key)
            self._evict()
            snapshot, version = self._snapshot()
        self._write_snapshot(snapshot, version)
        return True

    def keys(self) -> list:
        with self._lock:
            return list(self._data)

    def segment_keys(self) -> list:
        with self._lock:
            return list(self._segments)

    def has(self, model_ref: str, fingerprint: str) -> bool:
        with self._lock:
            return self._key(model_ref, fingerprint) in self._data

    @staticmethod
    def prune_stale(keys, catalog) -> list:
        """Which of ``keys`` (``ref|fingerprint`` strings) survive: keys
        whose model ``name@version`` is superseded by a newer catalog
        version are stale (a re-versioned embedding model produces
        different vectors)."""
        out = []
        for key in keys:
            ref = key.split("|", 1)[0]
            name, sep, _ = ref.rpartition("@")
            if sep:
                live = catalog.get_model(name)
                if live is not None and live.ref != ref:
                    continue
            out.append(key)
        return out

    def prune(self, catalog):
        """Drop stale entries in place (called at session start)."""
        with self._lock:
            live = set(self.prune_stale(list(self._data), catalog))
            stale = [k for k in self._data if k not in live]
            for k in stale:
                del self._data[k]
            self._gc_segments()
            if not (stale and self.path.exists()):
                return
            snapshot, version = self._snapshot()
        self._write_snapshot(snapshot, version)


class CalibrationStore:
    """JSON sidecar persisting per-model execution statistics aggregated
    from ``ExecutionReport``s: request/retry counts, tuples served (mean
    batch size), and a bounded window of recent per-request latencies.

    This is what turns the optimizer's flat serialization-sample cost
    model into a *calibrated* one: ``explain()``'s ``waves``
    critical-path estimate multiplies by the model's observed latency
    percentiles instead of guessing, and the speculative-dispatch
    decision compares serial vs speculative wall-clock from the same
    statistics.  Lives alongside the prediction cache (default path:
    the cache's JSONL path + ``.calibration.json``), keyed by the
    model's ``name@version`` ref so a model re-version orphans old
    entries; ``prune_stale`` drops refs a catalog resolves to a newer
    version.  A corrupt or unreadable sidecar loads as empty — the cost
    model degrades to uncalibrated, never crashes."""

    def __init__(self, path: str):
        self.path = Path(path)
        self._lock = threading.Lock()

    @staticmethod
    def _valid(rec) -> bool:
        if not isinstance(rec, dict):
            return False
        for k in ("requests", "retries", "tuples"):
            v = rec.get(k)
            if not isinstance(v, int) or v < 0:
                return False
        return isinstance(rec.get("latency_s"), list)

    def load(self) -> dict[str, dict]:
        if not self.path.exists():
            return {}
        try:
            data = json.loads(self.path.read_text())
        except (json.JSONDecodeError, OSError) as exc:
            logger.debug("sidecar %s unreadable: %s", self.path, exc)
            return {}
        if not isinstance(data, dict):
            return {}
        out: dict[str, dict] = {}
        for ref, rec in data.get("models", {}).items():
            if not self._valid(rec):
                continue
            # self-heal: sidecars written before the monotonic-clock fix
            # may carry negative latencies (wall-clock stepped backwards
            # mid-request) — drop the bad samples, keep the record
            lat = [float(x) for x in rec["latency_s"]
                   if isinstance(x, (int, float)) and not isinstance(x, bool)
                   and math.isfinite(x) and x >= 0]
            out[ref] = {"requests": rec["requests"],
                        "retries": rec["retries"],
                        "tuples": rec["tuples"],
                        "latency_s": lat[-CALIBRATION_WINDOW:]}
        return out

    def save(self, stats: dict[str, dict]):
        with self._lock:
            tmp = _tmp_path(self.path)
            tmp.write_text(json.dumps({"models": stats}, indent=1))
            tmp.replace(self.path)

    @staticmethod
    def prune_stale(stats: dict[str, dict], catalog) -> dict[str, dict]:
        """Drop entries whose ``name@version`` ref is superseded by a
        newer model version in ``catalog`` (a re-versioned model may
        have a new arch/window — its latency profile starts fresh)."""
        out = {}
        for ref, rec in stats.items():
            name, sep, _ = ref.rpartition("@")
            if sep:
                live = catalog.get_model(name)
                if live is not None and live.ref != ref:
                    continue
            out[ref] = rec
        return out
