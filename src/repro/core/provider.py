"""Model providers: the execution backends behind MODEL resources.

FlockMTL calls OpenAI/Azure/Ollama over HTTP; FlockJAX's providers are:

  * MockProvider     — deterministic, dependency-free; unit tests and the
                       interactive demo.  Supports pluggable "behaviours" so
                       semantic functions return sensible values.
  * LocalJaxProvider — a real JAX model (any zoo arch, byte-level tokenizer)
                       served through repro.serving; random weights unless a
                       checkpoint is supplied, so outputs are structurally
                       real (true prefill/decode) but not semantically
                       meaningful.  This is the provider the TPU dry-run
                       configuration targets.

Providers enforce the context window: requests above it raise
ContextOverflowError, which drives the adaptive batcher's 10% backoff.
"""

from __future__ import annotations

import hashlib
import json
import re
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from .batching import ContextOverflowError
from .metaprompt import MetaPrompt
from .resources import ModelResource

TOKENS_PER_CHAR = 0.33


def estimate_tokens(text: str) -> int:
    return int(len(text) * TOKENS_PER_CHAR) + 1


@dataclass
class ProviderStats:
    """Aggregate provider counters.  The scheduler executes requests from
    a thread pool, so every mutation goes through ``add`` (one lock per
    provider); bare ``+=`` on the fields from worker threads would drop
    updates under concurrency."""
    calls: int = 0
    prompt_tokens: int = 0
    output_tokens: int = 0
    latency_s: float = 0.0

    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def add(self, calls: int = 0, prompt_tokens: int = 0,
            output_tokens: int = 0, latency_s: float = 0.0):
        with self._lock:
            self.calls += calls
            self.prompt_tokens += prompt_tokens
            self.output_tokens += output_tokens
            self.latency_s += latency_s

    def snapshot(self) -> dict:
        with self._lock:
            return {"calls": self.calls,
                    "prompt_tokens": self.prompt_tokens,
                    "output_tokens": self.output_tokens,
                    "latency_s": self.latency_s}


class BaseProvider:
    def __init__(self):
        self.stats = ProviderStats()

    # ---- protocol --------------------------------------------------------
    def complete(self, model: ModelResource, mp: MetaPrompt,
                 n_rows: int) -> List[str]:
        """Run one batched chat-completion; returns per-row raw lines
        (map functions) or a single-element list (reduce functions)."""
        raise NotImplementedError

    def embed(self, model: ModelResource,
              texts: Sequence[str]) -> np.ndarray:
        raise NotImplementedError

    # ---- shared checks -----------------------------------------------------
    def _check_context(self, model: ModelResource, mp: MetaPrompt,
                       n_rows: int):
        need = estimate_tokens(mp.text) + model.max_output_tokens * max(
            n_rows, 1)
        if need > model.context_window:
            raise ContextOverflowError(
                f"{need} tokens > context window {model.context_window}")


class MockProvider(BaseProvider):
    """Deterministic provider: hash-seeded answers, optional behaviours.

    behaviour: fn(function_kind, prompt_text, rows) -> list[str] | None.
    When it returns None the default hash-based answer is used.
    """

    def __init__(self, behaviour: Optional[Callable] = None,
                 latency_per_call_s: float = 0.0,
                 latency_per_token_s: float = 0.0):
        super().__init__()
        self.behaviour = behaviour
        self.latency_per_call_s = latency_per_call_s
        self.latency_per_token_s = latency_per_token_s

    _ID_RE = re.compile(r'\s*(?:id="\d+"|"id":\s*\d+,?|^\|\s*\d+\s)')

    @classmethod
    def _h(cls, text: str) -> int:
        # hash CONTENT only (strip the per-batch row id) so the same tuple
        # gets the same answer regardless of its position in a batch —
        # keeps dedup/cache semantics testable
        return int.from_bytes(
            hashlib.sha256(cls._ID_RE.sub("", text).encode()).digest()[:8],
            "big")

    _MULTI_TASK_RE = re.compile(
        r"\bt(\d+) \[(filter|complete|complete_json)\]")

    def _default_rows(self, mp: MetaPrompt, rows: List[str]) -> List[str]:
        fn = mp.function
        out = []
        if fn == "multi":
            # fused pass: answer every sub-task declared in the prefix with
            # the same content-hash scheme the single-task kinds use
            tasks = self._MULTI_TASK_RE.findall(mp.prefix)
            for i, r in enumerate(rows):
                obj = {}
                for tag, kind in tasks:
                    h = self._h(r + mp.prefix + tag)
                    if kind == "filter":
                        obj[f"t{tag}"] = h % 2 == 0
                    elif kind == "complete_json":
                        obj[f"t{tag}"] = {"value": f"v{h % 10_000}"}
                    else:
                        obj[f"t{tag}"] = f"text-{h % 10_000}"
                out.append(f"{i}: {json.dumps(obj)}")
            return out
        if fn in ("reduce", "reduce_json"):
            h = self._h(mp.text)
            return [json.dumps({"summary": f"agg-{h % 10_000}"})
                    if fn == "reduce_json" else f"summary-{h % 10_000}"]
        if fn == "rerank":
            idx = list(range(len(rows)))
            idx.sort(key=lambda i: self._h(rows[i] + mp.prefix))
            return [",".join(map(str, idx))]
        for i, r in enumerate(rows):
            h = self._h(r + mp.prefix)
            if fn == "filter":
                out.append(f"{i}: {'true' if h % 2 == 0 else 'false'}")
            elif fn == "complete_json":
                out.append(f'{i}: {{"value": "v{h % 10_000}"}}')
            else:
                out.append(f"{i}: text-{h % 10_000}")
        return out

    def complete(self, model, mp, n_rows):
        self._check_context(model, mp, n_rows)
        rows = [ln for ln in mp.suffix.splitlines()
                if ln and not ln.startswith("#")][:n_rows]
        rows += [""] * (n_rows - len(rows))
        t0 = time.monotonic()
        out = None
        if self.behaviour is not None:
            out = self.behaviour(mp.function, mp.prefix, rows)
        if out is None:
            out = self._default_rows(mp, rows)
        # simulated service latency: per-call overhead + per-token decode
        sim = self.latency_per_call_s + self.latency_per_token_s * (
            estimate_tokens(mp.text) + model.max_output_tokens * n_rows)
        if sim:
            time.sleep(min(sim, 1.0))
        self.stats.add(calls=1, prompt_tokens=estimate_tokens(mp.text),
                       output_tokens=sum(estimate_tokens(o) for o in out),
                       latency_s=time.monotonic() - t0)
        return out

    def embed(self, model, texts):
        t0 = time.monotonic()
        dim = model.embedding_dim or 64
        out = np.zeros((len(texts), dim), np.float32)
        for i, t in enumerate(texts):
            rng = np.random.default_rng(self._h(t) % (2 ** 32))
            v = rng.standard_normal(dim)
            out[i] = v / np.linalg.norm(v)
        # same simulated service latency regime as complete(): embeds
        # are provider round-trips too (retrieval overlap benchmarks
        # depend on the embed wave costing real wall-clock)
        sim = self.latency_per_call_s + self.latency_per_token_s * sum(
            estimate_tokens(t) for t in texts)
        if sim:
            time.sleep(min(sim, 1.0))
        self.stats.add(calls=1, latency_s=time.monotonic() - t0)
        return out


class LocalJaxProvider(BaseProvider):
    """Serve a zoo architecture with the repro.serving engine.

    Byte-level tokenizer (token id == byte value; ids < 256) keeps the
    provider independent of any external vocabulary.  Generation is greedy
    and structurally identical to production serving (prefill + decode with
    the cache machinery); weights are random unless a checkpoint is given.
    """

    def __init__(self, arch: str = "olmo-1b", *, use_smoke_config=True,
                 checkpoint: Optional[str] = None, max_context: int = 2048):
        super().__init__()
        from repro.configs import get_config, get_smoke_config
        from repro.serving.engine import ServingEngine
        cfg = (get_smoke_config(arch) if use_smoke_config
               else get_config(arch))
        self.engine = ServingEngine(cfg, checkpoint=checkpoint,
                                    max_context=max_context)
        # the serving engine mutates shared decode state (slots, pos, KV
        # cache); scheduler worker threads must take turns.  Concurrency
        # for this provider comes from the engine's own continuous
        # batching, not from overlapped calls.
        self._engine_lock = threading.Lock()

    @staticmethod
    def _tokenize(text: str, vocab: int) -> list[int]:
        return [b % vocab for b in text.encode()]

    @staticmethod
    def _detokenize(toks) -> str:
        return bytes(int(t) % 256 for t in toks).decode("latin1")

    def complete(self, model, mp, n_rows):
        self._check_context(model, mp, n_rows)
        t0 = time.monotonic()
        vocab = self.engine.cfg.vocab_size
        prompt = self._tokenize(mp.text, vocab)
        max_new = min(model.max_output_tokens * max(n_rows, 1), 64)
        with self._engine_lock:
            toks = self.engine.generate(prompt, max_new_tokens=max_new)
        text = self._detokenize(toks)
        self.stats.add(calls=1, prompt_tokens=len(prompt),
                       output_tokens=len(toks),
                       latency_s=time.monotonic() - t0)
        # random weights produce uninterpretable bytes; wrap them in the
        # contract shape so downstream parsing stays exercised end-to-end
        return [f"{i}: {text[:32]!r}" for i in range(n_rows)] \
            if mp.function in ("complete", "complete_json", "filter",
                               "multi") \
            else [text[:64]]

    def embed(self, model, texts):
        vocab = self.engine.cfg.vocab_size
        with self._engine_lock:
            out = self.engine.embed_batch(
                [self._tokenize(t, vocab) for t in texts])
        self.stats.add(calls=1)
        return out
