"""FlockJAX core: the paper's contribution as a composable library.

Semantic operators (paper Table 1), MODEL/PROMPT resources (§2.1) and the
seamless optimizations (§2.3): meta-prompting, adaptive batching, caching,
dedup — plus fusion for hybrid search.
"""

from .batching import (BatchPlan, BatchStats, ContextOverflowError,
                       plan_batches)
from .cache import (CalibrationStore, IndexStore, PredictionCache,
                    SelectivityStore, bound_observations, cache_key,
                    corpus_fingerprint, headroom_factor)
from .fusion import (FUSION_METHODS, combanz, combmed, combmnz, combsum,
                     fusion, max_normalize, rrf)
from .functions import (ExecutionReport, SemanticContext, llm_complete,
                        llm_complete_json, llm_embedding, llm_filter,
                        llm_first, llm_last, llm_multi, llm_reduce,
                        llm_reduce_json, llm_rerank)
from .metaprompt import (MetaPrompt, build_metaprompt, build_multi_task,
                         build_prefix, serialize_batch, serialize_tuple)
from .provider import (BaseProvider, LocalJaxProvider, MockProvider,
                       estimate_tokens)
from .resources import (Catalog, ModelResource, PromptResource,
                        reset_global_catalog)
from .scheduler import (DispatchJob, RequestScheduler, SchedulerStats,
                        SpecTask, SpeculativeJoin, SpeculativeMaskJoin,
                        execute_serial, split_batch)
