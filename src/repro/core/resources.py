"""MODEL and PROMPT as first-class, versioned schema objects (paper §2.1).

Mirrors FlockMTL's DDL:

    CREATE GLOBAL MODEL('model-relevance-check', 'gpt-4o-mini', 'openai')
    CREATE PROMPT('joins-prompt', 'is related to join algos given abstract')

becomes

    catalog.create_model("model-relevance-check", arch="olmo-1b",
                         scope="global", context_window=4096)
    catalog.create_prompt("joins-prompt",
                          "is related to join algos given abstract")

Resources are versioned: updating creates a new version, previous versions
stay addressable (``name@2``); the latest is used by default.  GLOBAL
resources live in a machine-level catalog shared across databases, LOCAL
ones in the current database's catalog — resolution order LOCAL, then
GLOBAL (as in FlockMTL).
"""

from __future__ import annotations

import json
import re
import threading
import time
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Optional


@dataclass(frozen=True)
class ModelResource:
    name: str
    version: int
    arch: str                       # one of the 10 zoo archs (or "mock")
    provider: str = "local-jax"     # local-jax | mock
    context_window: int = 4096
    max_output_tokens: int = 256
    temperature: float = 0.0
    embedding_dim: int = 0          # 0 -> arch d_model
    max_concurrency: int = 4        # scheduler: in-flight request cap
    scope: str = "local"
    created_at: float = 0.0
    deleted: bool = False

    @property
    def ref(self) -> str:
        return f"{self.name}@{self.version}"


@dataclass(frozen=True)
class PromptResource:
    name: str
    version: int
    text: str
    scope: str = "local"
    created_at: float = 0.0
    deleted: bool = False

    @property
    def ref(self) -> str:
        return f"{self.name}@{self.version}"


_REF_RE = re.compile(r"^(.*?)(?:@(\d+))?$")


class _Registry:
    def __init__(self):
        self._versions: dict[str, list] = {}

    def create(self, res):
        self._versions.setdefault(res.name, []).append(res)

    def next_version(self, name: str) -> int:
        return len(self._versions.get(name, [])) + 1

    def get(self, ref: str):
        m = _REF_RE.match(ref)
        name, ver = m.group(1), m.group(2)
        if name not in self._versions:
            return None
        versions = self._versions[name]
        if ver is None:
            live = [r for r in versions if not r.deleted]
            return live[-1] if live else None
        i = int(ver) - 1
        return versions[i] if 0 <= i < len(versions) else None

    def delete(self, name: str):
        if name in self._versions:
            self._versions[name] = [
                type(r)(**{**asdict(r), "deleted": True})
                for r in self._versions[name]]

    def all(self):
        return {n: list(v) for n, v in self._versions.items()}


class Catalog:
    """LOCAL (per-database) + GLOBAL (per-machine) resource catalogs."""

    _global_models = _Registry()
    _global_prompts = _Registry()
    _global_lock = threading.Lock()

    def __init__(self, path: Optional[str] = None):
        self._models = _Registry()
        self._prompts = _Registry()
        self._lock = threading.Lock()
        self._path = Path(path) if path else None
        if self._path and self._path.exists():
            self._load()

    # ----- DDL ------------------------------------------------------------
    def create_model(self, name: str, arch: str, *, scope: str = "local",
                     **kw) -> ModelResource:
        reg = self._global_models if scope == "global" else self._models
        lock = self._global_lock if scope == "global" else self._lock
        with lock:
            res = ModelResource(name=name, version=reg.next_version(name),
                                arch=arch, scope=scope,
                                # wall-clock catalog timestamp
                                # flocklint: ignore[FLKL101]
                                created_at=time.time(), **kw)
            reg.create(res)
        self._persist()
        return res

    def create_prompt(self, name: str, text: str, *,
                      scope: str = "local") -> PromptResource:
        reg = self._global_prompts if scope == "global" else self._prompts
        lock = self._global_lock if scope == "global" else self._lock
        with lock:
            res = PromptResource(name=name, version=reg.next_version(name),
                                 text=text, scope=scope,
                                 # wall-clock catalog timestamp
                                 # flocklint: ignore[FLKL101]
                                 created_at=time.time())
            reg.create(res)
        self._persist()
        return res

    def update_model(self, name: str, **changes) -> ModelResource:
        cur = self.get_model(name)
        if cur is None:
            raise KeyError(f"no MODEL named {name!r}")
        kw = {**asdict(cur), **changes}
        for drop in ("version", "created_at", "deleted"):
            kw.pop(drop, None)
        scope = kw.pop("scope", cur.scope)
        return self.create_model(kw.pop("name"), kw.pop("arch"),
                                 scope=scope, **kw)

    def update_prompt(self, name: str, text: str) -> PromptResource:
        cur = self.get_prompt(name)
        if cur is None:
            raise KeyError(f"no PROMPT named {name!r}")
        return self.create_prompt(name, text, scope=cur.scope)

    def delete_model(self, name: str):
        self._models.delete(name)
        with self._global_lock:
            self._global_models.delete(name)
        self._persist()

    def delete_prompt(self, name: str):
        self._prompts.delete(name)
        with self._global_lock:
            self._global_prompts.delete(name)
        self._persist()

    # ----- resolution (LOCAL shadows GLOBAL, like FlockMTL) ----------------
    def get_model(self, ref: str) -> Optional[ModelResource]:
        return self._models.get(ref) or self._global_models.get(ref)

    def get_prompt(self, ref: str) -> Optional[PromptResource]:
        return self._prompts.get(ref) or self._global_prompts.get(ref)

    # ----- persistence ------------------------------------------------------
    def _persist(self):
        if not self._path:
            return
        data = {
            "models": {n: [asdict(r) for r in v]
                       for n, v in self._models.all().items()},
            "prompts": {n: [asdict(r) for r in v]
                        for n, v in self._prompts.all().items()},
        }
        # full-name staging (path.name + ".tmp"): .with_suffix would
        # collide for multi-dot paths — see cache._tmp_path
        from .cache import _tmp_path
        tmp = _tmp_path(self._path)
        tmp.write_text(json.dumps(data, indent=1))
        tmp.replace(self._path)

    def _load(self):
        data = json.loads(self._path.read_text())
        for versions in data.get("models", {}).values():
            for r in versions:
                self._models.create(ModelResource(**r))
        for versions in data.get("prompts", {}).values():
            for r in versions:
                self._prompts.create(PromptResource(**r))


# convenience: reset GLOBAL state (tests)
def reset_global_catalog():
    Catalog._global_models = _Registry()
    Catalog._global_prompts = _Registry()
