"""Adaptive context-window batching (paper §2.3 "Batching").

FlockMTL packs as many tuples as fit the model's context window into a
single request; if the provider reports an output/context overflow the
batch shrinks by 10% and retries; a single tuple that still overflows
yields NULL.  The same protocol drives our in-cluster JAX provider, whose
"context window" is the padded device batch shape — so good packing is
what keeps the TPU step dense.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence


class ContextOverflowError(Exception):
    """Raised by providers when a request exceeds the context budget."""


@dataclass
class BatchPlan:
    batches: List[List[int]]            # tuple indices per request
    est_tokens: List[int]               # estimated prompt tokens per request


@dataclass
class BatchStats:
    requests: int = 0
    retries: int = 0
    nulls: int = 0
    packed: int = 0   # batches merged into another job's co-packed request
    batch_sizes: List[int] = field(default_factory=list)
    # wall seconds per successful provider request, in completion order;
    # feeds the calibrated cost model (SemanticContext.record_calibration)
    latencies: List[float] = field(default_factory=list)


def plan_batches(token_costs: Sequence[int], prefix_tokens: int,
                 context_window: int, max_output_tokens: int,
                 max_batch: int = 0, headroom: float = 1.0) -> BatchPlan:
    """Greedy fill until the context budget is reached (order-preserving).

    budget per request = (context_window - prefix_tokens) * headroom -
    expected output (output scales with batch size: ~max_output_tokens
    per tuple).  ``headroom`` < 1.0 deliberately under-fills: it is the
    calibration feedback path — a model whose requests routinely
    overflow (token estimates undercount serialization framing) plans
    smaller batches up front instead of paying split-and-requeue
    (``SemanticContext.batch_headroom``).

    ``est_tokens`` is the estimated PROMPT tokens per request (tuple
    payloads only; callers add prefix_tokens themselves) — expected
    output tokens participate in the budget accounting but are not part
    of the estimate.
    """
    batches, est = [], []
    cur, cur_tokens, cur_prompt = [], 0, 0
    budget = int((context_window - prefix_tokens) * headroom)
    for i, cost in enumerate(token_costs):
        out_cost = max_output_tokens
        add = cost + out_cost
        if cur and (cur_tokens + add > budget
                    or (max_batch and len(cur) >= max_batch)):
            batches.append(cur)
            est.append(cur_prompt)
            cur, cur_tokens, cur_prompt = [], 0, 0
        cur.append(i)
        cur_tokens += add
        cur_prompt += cost
    if cur:
        batches.append(cur)
        est.append(cur_prompt)
    return BatchPlan(batches=batches, est_tokens=est)


# NOTE: the deprecated ``run_adaptive`` compat alias was removed; the
# adaptive executor lives in ``scheduler.py`` as ``execute_serial`` (the
# ``scheduler=None`` path; the concurrent dispatch engine shares its
# split-and-requeue logic).  This module keeps only the pure planner.
