"""Async provider scheduler: staged, concurrent dispatch of LLM requests.

PR 1's optimizer cut *how many* requests a plan issues (batching, caching,
dedup, fusion); this module cuts *how long* they take.  The monolithic
``dedup -> cache -> batch -> provider`` loop becomes explicit stages, and
the provider stage runs on a bounded worker pool so wall-clock tracks the
provider's concurrency limit instead of the batch count — the DBMS, not
the user, hides provider latency behind concurrent in-flight requests
(arXiv:2508.20912 §3, arXiv:2402.02643 §4).

Pieces:

  * ``RequestScheduler`` — one per ``SemanticContext`` (opt-in via the
    ``scheduler=`` knob; ``None`` keeps the serial path bit-identical).
    Owns a thread pool sized ``max_workers`` and a per-model semaphore
    honouring ``ModelResource.max_concurrency``.
  * dispatch queue — any number of plan nodes submit batch-request jobs
    concurrently; batches from different jobs interleave freely on the
    pool, so independent plan nodes overlap end-to-end.
  * single-flight dedup — identical cache keys submitted by concurrent
    jobs issue ONE provider request; late submitters attach to the
    in-flight entry and read its value when it resolves.
  * co-packing stage — jobs submitted via ``submit_map`` with a pack
    identity (model + metaprompt prefix) park their part-filled TAIL
    batch in a short-lived per-(model, prefix) packing queue instead of
    dispatching it immediately; tails from different jobs that share
    the prefix merge into one provider request (results demultiplexed
    back to each owning job), so the context window stays dense when
    many plan nodes dispatch concurrently.  The queue is LATENCY-FIRST:
    callers register how many same-identity submitters are in flight
    (``pack_expect``/``pack_retire``, driven by the context's
    ``copack_begin``/``copack_end`` refcounts), every arriving
    submitter decrements the expectation, and the pack flushes the
    moment the LAST expected tail lands (or the identity retires) —
    merging costs no wall-clock when all riders show up.  A parked
    segment is additionally bounded by a per-pack deadline: the
    calibrated expected-arrival window (``pack["linger_s"]``, derived
    from the model's observed request latency) when known, the
    configured ``pack_linger_s`` cap otherwise — so no tail is ever
    older than the window before dispatching exactly as it would have
    unpacked.  Overflow-split remainders re-enter the same queue when
    a mergeable partner is still plausible.  Per-tuple results are
    independent of batch composition, so merged execution is
    bit-identical to unpacked.
  * ``SpeculativeJoin`` — the bounded fan-out/join group behind every
    speculative plan rewrite (filter chains, map-past-filter,
    retrieval-aware rerank): heterogeneous speculative tasks (mask
    thunks, row completions, rerank warmups) run concurrently on a
    small set of dedicated runner threads, capped in count and in
    total in-flight rows so deep chains cannot oversubscribe past the
    scheduler's worker pool; a task that has not started yet can be
    **cancelled** the moment an upstream mask proves its rows dead,
    and never reaches the provider.  ``SpeculativeMaskJoin`` survives
    as the mask-specific facade.  The extra requests are bounded by
    recorded selectivity (the optimizer's wasted-request budget) and
    identical keys still coalesce through the single-flight registry.
  * adaptive overflow — ``ContextOverflowError`` splits the batch 10%
    (the paper §2.3 protocol) and requeues both halves on the pool; a
    single tuple that still overflows resolves to NULL.  The same split
    loop drives the serial fallback (``execute_serial``), so the two
    paths produce identical results, request counts and token counts —
    with one stats-only exception: a borrower of an overflow-NULLed key
    adopts the NULL (counted in its ``nulls``) instead of re-issuing a
    request that would fail identically, so its request/retry counts
    can undercut a strictly serial run of that pathological workload.
"""

from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from .batching import BatchStats, ContextOverflowError, plan_batches
from .resources import ModelResource

# Lock discipline (checked by tools/flocklint.py): every lock here is a
# leaf — no code path holds two at once, and provider dispatch / pool
# joins happen strictly outside lock bodies.  If nesting ever becomes
# necessary it must follow this acquisition order:
# flocklint: lock-order: _pack_lock < _lock < job._lock < scheduler._lock


def split_batch(batch: List[int]) -> tuple[List[int], List[int]]:
    """Adaptive 10% shrink: (head to retry, tail to requeue)."""
    keep = max(1, len(batch) - max(1, len(batch) // 10))
    return batch[:keep], batch[keep:]


def execute_serial(indices: Sequence, token_costs: Sequence[int],
                   prefix_tokens: int, context_window: int,
                   max_output_tokens: int,
                   call: Callable[[List[int]], list],
                   max_batch: int = 0,
                   headroom: float = 1.0) -> tuple[list, BatchStats]:
    """The scheduler-free fallback: plan batches, run them one at a time
    under the adaptive overflow protocol.  ``call(positions)`` receives
    positions into ``indices`` and returns per-position results."""
    results: list = [None] * len(indices)
    stats = BatchStats()
    plan = plan_batches(token_costs, prefix_tokens, context_window,
                        max_output_tokens, max_batch, headroom=headroom)
    work = list(plan.batches)
    while work:
        batch = work.pop(0)
        try:
            t0 = time.monotonic()
            out = call(batch)
            stats.latencies.append(time.monotonic() - t0)
            stats.requests += 1
            stats.batch_sizes.append(len(batch))
            for idx, val in zip(batch, out):
                results[idx] = val
        except ContextOverflowError:
            stats.retries += 1
            if len(batch) == 1:
                results[batch[0]] = None       # single tuple too large
                stats.nulls += 1
                continue
            head, tail = split_batch(batch)
            work.insert(0, tail)
            work.insert(0, head)
    return results, stats


# ---------------------------------------------------------------------------
# single-flight registry
# ---------------------------------------------------------------------------
class _InflightEntry:
    """One in-flight cache key.  The owning job resolves it; borrowing
    jobs block on the event instead of issuing a duplicate request.  If
    the owning request errored, borrowers re-raise instead of treating
    the missing value as a legitimate NULL."""
    __slots__ = ("event", "value", "error")

    def __init__(self):
        self.event = threading.Event()
        self.value = None
        self.error: Optional[BaseException] = None

    def resolve(self, value):
        self.value = value
        self.event.set()

    def resolve_error(self, exc: BaseException):
        self.error = exc
        self.event.set()


class _ModelGate:
    """Admission gate bounding one model's in-flight requests.

    Non-blocking by design: a batch that cannot enter is parked on the
    gate's pending queue and handed back when a slot frees, so pool
    threads never sleep waiting for a busy model — one low-concurrency
    model with a deep queue cannot starve other models' jobs out of the
    worker pool.

    Unlike a plain semaphore the limit can shrink after creation: when
    the same model resource is resolved with different
    ``max_concurrency`` values, the most restrictive one wins (exceeding
    the smallest advertised cap is never safe against a rate-limited
    provider)."""

    def __init__(self, limit: int):
        self._lock = threading.Lock()
        self.limit = max(1, limit)
        self.active = 0
        self.pending: List = []          # deferred (job, batch) tasks

    def shrink_to(self, limit: int):
        with self._lock:
            self.limit = max(1, min(self.limit, limit))

    def try_acquire(self, task) -> bool:
        """Take a slot, or park ``task`` for redelivery on release."""
        with self._lock:
            if self.active < self.limit:
                self.active += 1
                return True
            self.pending.append(task)
            return False

    def release_and_next(self):
        """Free the slot; if work is parked, keep the slot and return
        the next task for the caller to run inline.  A slot is only
        handed off while ``active`` respects the (possibly shrunk)
        limit — excess in-flight slots drain instead, so 'most
        restrictive wins' holds even mid-queue."""
        with self._lock:
            if self.pending and self.active <= self.limit:
                return self.pending.pop(0)
            self.active -= 1
            return None


# co-packing thresholds: a tail batch enters the packing queue only when
# its fill fraction leaves room worth merging into, and a merged batch
# this full dispatches immediately instead of waiting out the linger
_PACK_FILL_MAX = 0.85
_PACK_FLUSH_FILL = 0.9

# deadline policy for parked tails: with calibration data a rider is
# expected within ~one request service time (concurrently-dispatched
# group members start together), so the expected-arrival window is a
# fraction of the model's observed p50 request latency — floored so
# timer granularity cannot starve a real rider, and always capped by
# the scheduler's configured ``pack_linger_s`` (the uncalibrated
# fallback and hard upper bound)
PACK_LINGER_LATENCY_FRACTION = 0.5
PACK_LINGER_MIN_S = 0.002


class _PackSegment:
    """One job's parked tail batch inside a pending co-pack."""
    __slots__ = ("job", "positions", "rows", "weight")

    def __init__(self, job, positions, rows, weight):
        self.job = job
        self.positions = positions      # job-local positions (into keys)
        self.rows = rows                # provider-facing row payloads
        self.weight = weight            # budget weight (prompt + output)


class _PendingPack:
    """A short-lived per-(model, prefix) packing-queue entry: part-filled
    tail batches accumulate here until the merged batch is dense enough,
    the last expected same-identity rider arrives, or the per-pack
    deadline expires.  ``deadline`` is fixed at creation (merging never
    extends it), so no parked segment is ever older than one window."""
    __slots__ = ("key", "model", "budget", "max_batch", "call",
                 "segments", "tokens", "flushed", "timer", "deadline")

    def __init__(self, key, model, budget, max_batch, call, segment):
        self.key = key
        self.model = model
        self.budget = budget
        self.max_batch = max_batch
        self.call = call                # rows -> per-row results
        self.segments: List[_PackSegment] = [segment]
        self.tokens = segment.weight
        self.flushed = False
        self.timer: Optional[threading.Timer] = None
        self.deadline: float = 0.0      # monotonic flush-by time

    def size(self) -> int:
        return sum(len(s.positions) for s in self.segments)


@dataclass
class SchedulerStats:
    jobs: int = 0
    requests: int = 0
    retries: int = 0
    nulls: int = 0
    coalesced: int = 0          # keys served by another job's request
    max_inflight: int = 0       # peak concurrently-executing requests
    packed_requests: int = 0    # merged (co-packed) provider requests
    packed_batches: int = 0     # tail batches folded into merged requests
    repacked_tails: int = 0     # overflow-split remainders re-queued
    #                             into the packing queue
    spec_dispatched: int = 0    # speculative tasks that started running
    spec_cancelled: int = 0     # speculative tasks dropped before dispatch
    #                             (their rows were proven dead upstream)
    spec_wasted_rows: int = 0   # rows speculated on that the serial plan
    #                             would never have evaluated

    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    def add(self, **deltas: int):
        with self._lock:
            for k, v in deltas.items():
                setattr(self, k, getattr(self, k) + v)


class DispatchJob:
    """Future for one submitted batch-request job (one plan-node stage).

    ``result()`` blocks until every owned batch has executed (including
    overflow requeues) and every borrowed key has been resolved by its
    owning job, then returns ``(values, stats)`` aligned with the
    submitted key list.  ``coalesced`` counts borrowed keys."""

    def __init__(self, scheduler: "RequestScheduler", keys: Sequence[str],
                 run: Callable[[List[int]], list], model: ModelResource,
                 cache=None):
        self.scheduler = scheduler
        self.keys = list(keys)
        self.run = run
        self.model = model
        self.cache = cache
        self.pack: Optional[dict] = None    # co-pack opts (set by submit)
        self.values: List = [None] * len(self.keys)
        self.stats = BatchStats()
        self.coalesced = 0      # keys served by another job's request
        self.late_hits = 0      # keys found in cache at submit time
        self._borrowed: List[tuple[int, _InflightEntry]] = []
        self._owned_entries: Dict[int, _InflightEntry] = {}
        self._lock = threading.Lock()
        self._pending = 0
        self._done = threading.Event()
        self._error: Optional[BaseException] = None

    # ---- owner-side bookkeeping (called by scheduler workers) -------------
    def _batch_started(self, n: int = 1):
        with self._lock:
            self._pending += n

    def _batch_finished(self):
        with self._lock:
            self._pending -= 1
            if self._pending <= 0:
                self._done.set()

    def _fail(self, exc: BaseException):
        with self._lock:
            self._error = exc
            self._pending = 0
            self._done.set()
        # release owned single-flight entries so borrower jobs waiting on
        # this job's keys unblock — carrying the error, not a silent None
        for pos, entry in self._owned_entries.items():
            if not entry.event.is_set():
                entry.resolve_error(exc)
                key = self.keys[pos]
                with self.scheduler._lock:
                    if self.scheduler._inflight.get(key) is entry:
                        del self.scheduler._inflight[key]

    # ---- consumer side ----------------------------------------------------
    def result(self, timeout: Optional[float] = None
               ) -> tuple[list, BatchStats]:
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        if not self._done.wait(timeout):
            raise TimeoutError("scheduler job did not complete in time")
        if self._error is not None:
            raise self._error
        for pos, entry in self._borrowed:
            remaining = (None if deadline is None
                         else max(0.0, deadline - time.monotonic()))
            if not entry.event.wait(remaining):
                raise TimeoutError(
                    "borrowed in-flight key did not resolve in time")
            if entry.error is not None:
                raise entry.error
            self.values[pos] = entry.value
            if entry.value is None:
                # the owner overflow-nulled this key; adopt the NULL and
                # account for it (the serial path would re-issue, fail
                # the same way, and count a null of its own)
                self.stats.nulls += 1
        return self.values, self.stats


class RequestScheduler:
    """Bounded concurrent dispatch engine shared by all plan nodes of a
    session.  Construct once, pass as ``SemanticContext(scheduler=...)``;
    ``shutdown()`` (or use as a context manager) drains the pool."""

    def __init__(self, max_workers: int = 16,
                 pack_linger_s: float = 0.02):
        self.max_workers = max_workers
        # how long a part-filled tail batch waits in the packing queue
        # for a same-prefix partner before dispatching alone
        self.pack_linger_s = pack_linger_s
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="flockjax-sched")
        self._lock = threading.Lock()
        self._inflight: Dict[str, _InflightEntry] = {}
        self._gates: Dict[str, _ModelGate] = {}
        self._packs: Dict[tuple, _PendingPack] = {}
        # rider-expectation registry: pack key -> outstanding same-
        # identity submitters announced via pack_expect().  Every
        # arriving submitter decrements; at zero no mergeable rider can
        # be in flight, so the parked pack flushes immediately (last-
        # tail-out).  Keys never registered stay in "unknown" mode and
        # fall back to pure deadline-based lingering.
        self._pack_expected: Dict[tuple, int] = {}
        self._pack_lock = threading.Lock()
        self._executing = 0
        self.stats = SchedulerStats()

    # ---- lifecycle ---------------------------------------------------------
    def shutdown(self, wait: bool = True):
        # flush parked tails first: their jobs' result() calls would
        # otherwise hang on batches the pool will never run
        with self._pack_lock:
            pending = list(self._packs.values())
        for p in pending:
            self._flush_pack(p)
        self._pool.shutdown(wait=wait)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    # ---- per-model concurrency gate ---------------------------------------
    def _model_gate(self, model: ModelResource) -> _ModelGate:
        limit = max(1, int(getattr(model, "max_concurrency", 1) or 1))
        with self._lock:
            gate = self._gates.get(model.ref)
            if gate is None:
                gate = _ModelGate(limit)
                self._gates[model.ref] = gate
            else:
                gate.shrink_to(limit)
            return gate

    # ---- submission --------------------------------------------------------
    def submit(self, model: ModelResource, keys: Sequence[str],
               run: Callable[[List[int]], list],
               batches: Optional[Sequence[List[int]]] = None, cache=None,
               single_flight: bool = True,
               plan: Optional[Callable[[List[int]],
                                       List[List[int]]]] = None,
               pack: Optional[dict] = None) -> DispatchJob:
        """Enqueue pre-planned ``batches`` (position lists into ``keys``)
        for concurrent execution.  With ``single_flight``, positions
        whose key is already in flight (submitted by ANOTHER job) are
        coalesced instead of re-issued, and positions whose key landed
        in ``cache`` since the caller's lookup are served from it —
        exactly the requests a serialized execution would have saved as
        cache hits, so request counts match the serial path.

        Duplicate keys WITHIN one job never self-coalesce (they only
        exist with dedup disabled, where the serial path issues every
        duplicate), and callers that disabled caching must pass
        ``single_flight=False``: coalescing is an extension of the
        prediction cache, and without it a borrower would share
        responses the caller asked to keep independent.

        ``plan`` (owned positions -> batches), when given, re-plans the
        batches AFTER coalescing so the surviving positions pack densely
        — filtering borrowed keys out of pre-planned ``batches`` would
        leave sparse batches and more requests than the serial path.

        ``pack`` opts the job's part-filled TAIL batch into the cross-job
        co-packing queue: ``{"key": prefix identity, "rows": per-position
        provider payloads, "call": rows -> per-row results, "weights":
        per-position budget weights, "budget": packed-request budget,
        "max_batch": per-request tuple cap}``.  Tails from different
        jobs sharing ``(model.ref, key)`` merge into one provider
        request, demultiplexed back by position."""
        job = DispatchJob(self, keys, run, model, cache)
        self.stats.add(jobs=1)

        owned_pos: set[int] = set()
        if not single_flight:
            owned_pos = set(range(len(job.keys)))
        else:
            # duplicate keys within a job (dedup disabled) inherit the
            # first occurrence's disposition: borrowed and late-hit
            # firsts would be cache hits for every duplicate on the
            # serial path (0 requests), owned firsts would be misses
            # for every duplicate (all requested) — count parity holds
            # either way
            disposition: Dict[str, tuple] = {}
            with self._lock:
                for pos, key in enumerate(job.keys):
                    disp = disposition.get(key)
                    if disp is None:
                        entry = self._inflight.get(key)
                        if entry is not None:
                            disp = ("borrow", entry)
                        else:
                            disp = ("own", None)
                            if cache is not None:
                                # landed in the cache since the
                                # caller's lookup: a late hit, not
                                # in-flight sharing
                                hit, val = cache.peek(key)
                                if hit:
                                    disp = ("hit", val)
                            if disp[0] == "own":
                                entry = _InflightEntry()
                                self._inflight[key] = entry
                                job._owned_entries[pos] = entry
                        disposition[key] = disp
                    kind, payload = disp
                    if kind == "borrow":
                        job._borrowed.append((pos, payload))
                    elif kind == "hit":
                        job.values[pos] = payload
                        job.late_hits += 1
                    else:
                        owned_pos.add(pos)
            if job._borrowed:
                job.coalesced = len(job._borrowed)
                self.stats.add(coalesced=len(job._borrowed))

        if plan is not None:
            owned_batches = plan(sorted(owned_pos)) if owned_pos else []
        else:
            owned_batches = [[p for p in b if p in owned_pos]
                             for b in (batches or [])]
            owned_batches = [b for b in owned_batches if b]
        parked: Optional[List[int]] = None
        job.pack = pack         # kept for overflow-remainder repacking
        if pack is not None and owned_batches:
            tail = owned_batches[-1]
            tail_w = sum(pack["weights"][p] for p in tail)
            if tail_w <= _PACK_FILL_MAX * pack["budget"]:
                parked = tail
                owned_batches = owned_batches[:-1]
        if not owned_batches and parked is None:
            # this submitter arrived with nothing to park (all coalesced
            # / cached, or a too-full tail of zero batches): riders
            # parked on the identity must not keep waiting for it
            if pack is not None:
                self.pack_arrived((model.ref, pack["key"]))
            job._done.set()
            return job
        job._batch_started(len(owned_batches) + (parked is not None))
        try:
            for b in owned_batches:
                self._pool.submit(self._run_batch, job, b)
        except BaseException as exc:  # flocklint: ignore[FLKL105]
            # e.g. pool already shut down: _fail releases this job's
            # registered in-flight entries (with the error) so no later
            # borrower hangs on them, then the caller sees the error
            job._fail(exc)
            raise
        if parked is not None:
            self._register_pack(job, parked, pack)
        elif pack is not None:
            # dispatched everything as full batches: still an arrival
            self.pack_arrived((model.ref, pack["key"]))
        return job

    def submit_map(self, model: ModelResource, keys: Sequence[str],
                   token_costs: Sequence[int], prefix_tokens: int,
                   run: Callable[[List[int]], list], cache=None,
                   max_batch: int = 0,
                   context_window: Optional[int] = None,
                   single_flight: bool = True, headroom: float = 1.0,
                   pack_key=None,
                   pack_rows: Optional[Sequence] = None,
                   pack_call: Optional[Callable[[list], list]] = None,
                   pack_linger: Optional[float] = None
                   ) -> DispatchJob:
        """Dispatch with context-window batch planning that runs AFTER
        single-flight coalescing, so the positions this job actually
        owns pack as densely as a serial execution would.

        ``headroom`` (from ``SemanticContext.batch_headroom``) shrinks
        the planned budget for models with observed overflow retries.
        ``pack_key``/``pack_rows``/``pack_call`` opt the job's
        part-filled tail batch into cross-job co-packing: ``pack_key``
        is the metaprompt-prefix identity shared by co-packable jobs,
        ``pack_rows[p]`` the provider payload for position ``p``, and
        ``pack_call(rows)`` one provider request over rows drawn from
        any number of same-prefix jobs.  ``pack_linger`` overrides the
        scheduler's default deadline for a tail parked by THIS job —
        the calibrated expected-arrival window — and never exceeds it
        in practice (callers clamp to ``pack_linger_s``)."""
        window = (context_window if context_window is not None
                  else model.context_window)

        def plan(owned: List[int]) -> List[List[int]]:
            bp = plan_batches([token_costs[p] for p in owned],
                              prefix_tokens, window,
                              model.max_output_tokens, max_batch,
                              headroom=headroom)
            return [[owned[j] for j in b] for b in bp.batches]

        pack = None
        if (pack_key is not None and pack_rows is not None
                and pack_call is not None):
            budget = int((window - prefix_tokens) * headroom)
            if budget > 0:
                pack = {"key": pack_key, "rows": pack_rows,
                        "call": pack_call, "budget": budget,
                        "max_batch": max_batch,
                        "linger_s": pack_linger,
                        "weights": [c + model.max_output_tokens
                                    for c in token_costs]}
        return self.submit(model, keys, run, cache=cache,
                           single_flight=single_flight, plan=plan,
                           pack=pack)

    # ---- co-packing stage --------------------------------------------------
    def pack_expect(self, key, n: int = 1):
        """Announce ``n`` same-identity submitters about to dispatch
        under pack ``key`` (``(model.ref, identity)``).  Driven by the
        context's ``copack_begin``: while the expectation is positive a
        parked pack lingers for its riders; once every expected
        submitter has arrived it flushes immediately."""
        if n <= 0:
            return
        with self._pack_lock:
            self._pack_expected[key] = self._pack_expected.get(key, 0) + n

    def pack_arrived(self, key):
        """One expected submitter has dispatched (or resolved with
        nothing to send).  When it was the last one, no mergeable rider
        can still be in flight — flush any pack parked under the key."""
        to_flush = None
        with self._pack_lock:
            if self._pack_note_arrival_locked(key) is True:
                to_flush = self._packs.get(key)
        if to_flush is not None:
            self._flush_pack(to_flush)

    def pack_retire(self, key, n: int = 1):
        """Withdraw up to ``n`` outstanding expectations (the group
        closed; some registered submitters never dispatched).  An
        identity with no expectations left cannot receive a rider, so a
        pack still parked under it flushes immediately instead of
        waiting out its deadline."""
        to_flush = None
        with self._pack_lock:
            cur = self._pack_expected.get(key)
            if cur is not None:
                cur -= n
                if cur > 0:
                    self._pack_expected[key] = cur
                else:
                    self._pack_expected.pop(key, None)
                    cur = 0
            if not cur:
                to_flush = self._packs.get(key)
        if to_flush is not None:
            self._flush_pack(to_flush)

    def _pack_note_arrival_locked(self, key) -> Optional[bool]:
        """Decrement the rider expectation for ``key`` (caller holds
        ``_pack_lock``).  True = that was the last expected submitter;
        False = riders still outstanding; None = key never registered
        (unknown mode: deadline-based lingering governs)."""
        n = self._pack_expected.get(key)
        if n is None:
            return None
        n -= 1
        if n <= 0:
            self._pack_expected.pop(key, None)
            return True
        self._pack_expected[key] = n
        return False

    def _register_pack(self, job: DispatchJob, positions: List[int],
                       pack: dict, arrival: bool = True,
                       opportunistic: bool = False) -> bool:
        """Park a part-filled tail batch in the per-(model, prefix)
        packing queue.  Merges into an already-parked compatible entry
        when the combined batch fits the budget; flushes immediately
        once the merged batch is dense enough OR the last expected
        same-identity submitter has arrived (last-tail-out), otherwise
        the per-pack deadline timer dispatches whatever accumulated.

        ``arrival=False`` registers without consuming a rider
        expectation (overflow-split remainders: their job already
        arrived at submit time).  ``opportunistic=True`` refuses to
        park — returns False — unless a pending pack or outstanding
        expectation makes a merge plausible, so a remainder with no
        conceivable partner requeues as a plain batch instead of
        idling until the deadline."""
        seg = _PackSegment(job, positions,
                           [pack["rows"][p] for p in positions],
                           sum(pack["weights"][p] for p in positions))
        key = (job.model.ref, pack["key"])
        flushes: List[_PendingPack] = []
        with self._pack_lock:
            if opportunistic and (key not in self._packs
                                  and self._pack_expected.get(key, 0)
                                  <= 0):
                return False
            last = (self._pack_note_arrival_locked(key) if arrival
                    else None)
            pending = self._packs.get(key)
            if pending is not None:
                fits = (pending.tokens + seg.weight
                        <= min(pending.budget, pack["budget"]))
                size = pending.size() + len(positions)
                for cap in (pending.max_batch, pack["max_batch"]):
                    if cap and size > cap:
                        fits = False
                if fits:
                    pending.segments.append(seg)
                    pending.tokens += seg.weight
                    pending.budget = min(pending.budget, pack["budget"])
                    if pack["max_batch"] and (not pending.max_batch
                                              or pack["max_batch"]
                                              < pending.max_batch):
                        pending.max_batch = pack["max_batch"]
                    if self._pack_is_full(pending) or last is True:
                        flushes.append(pending)
                    pending = seg = None
                else:
                    flushes.append(pending)  # full: dispatch, repark
                    pending = None
            if seg is not None and pending is None:
                pending = _PendingPack(key, job.model, pack["budget"],
                                       pack["max_batch"], pack["call"],
                                       seg)
                linger = float(pack.get("linger_s")
                               or self.pack_linger_s)
                pending.deadline = time.monotonic() + linger
                if last is True:
                    # the last expected submitter has no one to wait
                    # for: dispatch its lone tail without parking
                    flushes.append(pending)
                else:
                    self._packs[key] = pending
                    pending.timer = threading.Timer(
                        linger, self._flush_pack, (pending,))
                    pending.timer.daemon = True
                    pending.timer.start()
        for p in flushes:
            self._flush_pack(p)
        return True

    def _maybe_repack(self, job: DispatchJob,
                      positions: List[int]) -> bool:
        """Route an overflow-split remainder back into the packing
        queue when its job co-packs and a mergeable partner is still
        plausible (pending pack or outstanding rider expectation).
        Returns False — caller requeues as a plain batch — otherwise."""
        pack = job.pack
        if not pack or pack.get("budget", 0) <= 0:
            return False
        weight = sum(pack["weights"][p] for p in positions)
        if weight > _PACK_FILL_MAX * pack["budget"]:
            return False
        if not self._register_pack(job, positions, pack, arrival=False,
                                   opportunistic=True):
            return False
        self.stats.add(repacked_tails=1)
        return True

    @staticmethod
    def _pack_is_full(pending: _PendingPack) -> bool:
        """A merged batch that cannot usefully grow dispatches now
        instead of waiting out the linger: token fill near the budget,
        the per-request tuple cap reached, or no room left for even one
        more typical tuple."""
        if pending.tokens >= _PACK_FLUSH_FILL * pending.budget:
            return True
        size = pending.size()
        if pending.max_batch and size >= pending.max_batch:
            return True
        mean_weight = pending.tokens / max(size, 1)
        return pending.budget - pending.tokens < mean_weight

    def _flush_pack(self, pending: _PendingPack):
        """Dispatch a packing-queue entry: alone it runs as its job's
        ordinary batch (bit-identical to never having parked); merged it
        runs as ONE provider request demultiplexed across jobs."""
        with self._pack_lock:
            if pending.flushed:
                return
            pending.flushed = True
            if pending.timer is not None:
                pending.timer.cancel()
            if self._packs.get(pending.key) is pending:
                del self._packs[pending.key]
            segments = pending.segments
        try:
            if len(segments) == 1:
                self._pool.submit(self._run_batch, segments[0].job,
                                  segments[0].positions)
            else:
                self.stats.add(packed_requests=1,
                               packed_batches=len(segments))
                self._pool.submit(self._run_pack, pending)
        # pool shut down mid-linger  # flocklint: ignore[FLKL105]
        except BaseException as exc:
            for s in segments:
                s.job._fail(exc)

    # ---- worker ------------------------------------------------------------
    def _run_batch(self, job: DispatchJob, batch: List[int]):
        self._run_gated(job.model, ("batch", job, batch))

    def _run_pack(self, pending: _PendingPack):
        self._run_gated(pending.model, ("pack", pending))

    def _run_gated(self, model: ModelResource, task: tuple):
        """Pool-thread entry: admit the task through its model gate (or
        park it — pool threads never block on a busy model, so one
        low-concurrency model cannot starve other models' jobs), then
        run it and keep draining parked same-model work inline (the slot
        hands off without a pool round-trip)."""
        gate = self._model_gate(model)
        if not gate.try_acquire(task):
            return          # parked on the gate; drained on release
        while task is not None:
            # any escape — provider errors, cache-put I/O failures,
            # requeue after shutdown — fails the owning job(s), never
            # strands result()
            try:
                if task[0] == "batch":
                    self._execute_admitted(task[1], task[2])
                else:
                    self._execute_pack(task[1])
            # surfaced at result()  # flocklint: ignore[FLKL105]
            except BaseException as exc:
                if task[0] == "batch":
                    task[1]._fail(exc)
                else:
                    for s in task[1].segments:
                        s.job._fail(exc)
            task = gate.release_and_next()

    def _execute_pack(self, pending: _PendingPack):
        """Run one merged co-packed request and demultiplex the per-row
        results back to each owning job by position.  The provider
        request is attributed to the FIRST segment's job (requests,
        batch size, latency); riders count it under ``stats.packed`` —
        summed across jobs the accounting matches the provider exactly.
        On overflow the merge is undone: each tail requeues as its own
        ordinary batch and the per-job adaptive protocol takes over."""
        segs = []
        for s in pending.segments:
            with s.job._lock:
                dead = s.job._error is not None
            if not dead:
                segs.append(s)
        if not segs:
            return
        with self._lock:
            self._executing += 1
            if self._executing > self.stats.max_inflight:
                self.stats.max_inflight = self._executing
        rows = [r for s in segs for r in s.rows]
        t0 = time.monotonic()
        try:
            out = pending.call(rows)
        except ContextOverflowError:
            with segs[0].job._lock:
                segs[0].job.stats.retries += 1
            self.stats.add(retries=1)
            for s in segs:
                self._pool.submit(self._run_batch, s.job, s.positions)
            return
        finally:
            with self._lock:
                self._executing -= 1
        dt = time.monotonic() - t0
        off = 0
        for k, s in enumerate(segs):
            vals = out[off:off + len(s.positions)]
            off += len(s.positions)
            with s.job._lock:
                if k == 0:
                    s.job.stats.requests += 1
                    s.job.stats.batch_sizes.append(len(rows))
                    s.job.stats.latencies.append(dt)
                else:
                    s.job.stats.packed += 1
            for pos, val in zip(s.positions, vals):
                self._resolve(s.job, pos, val)
            s.job._batch_finished()
        self.stats.add(requests=1)

    def _execute_admitted(self, job: DispatchJob, batch: List[int]):
        with job._lock:
            dead = job._error is not None
        if dead:
            return      # job already failed; don't pay for its batches
        with self._lock:
            self._executing += 1
            if self._executing > self.stats.max_inflight:
                self.stats.max_inflight = self._executing
        t0 = time.monotonic()
        try:
            out = job.run(batch)
        except ContextOverflowError:
            with job._lock:
                job.stats.retries += 1
            self.stats.add(retries=1)
            if len(batch) == 1:
                self._resolve(job, batch[0], None)
                with job._lock:
                    job.stats.nulls += 1
                self.stats.add(nulls=1)
                job._batch_finished()
                return
            head, tail = split_batch(batch)
            job._batch_started(1)        # one batch became two
            self._pool.submit(self._run_batch, job, head)
            # the shrunken remainder is exactly a part-filled tail: let
            # it ride a pending same-identity pack when one is plausible
            # instead of paying a sparse request of its own
            if not self._maybe_repack(job, tail):
                self._pool.submit(self._run_batch, job, tail)
            return
        finally:
            with self._lock:
                self._executing -= 1
        with job._lock:
            job.stats.requests += 1
            job.stats.batch_sizes.append(len(batch))
            job.stats.latencies.append(time.monotonic() - t0)
        self.stats.add(requests=1)
        for pos, val in zip(batch, out):
            self._resolve(job, pos, val)
        job._batch_finished()

    def _resolve(self, job: DispatchJob, pos: int, value):
        job.values[pos] = value
        key = job.keys[pos]
        if job.cache is not None and value is not None:
            job.cache.put(key, value)
        entry = job._owned_entries.get(pos)
        if entry is not None:
            entry.resolve(value)
            with self._lock:
                if self._inflight.get(key) is entry:
                    del self._inflight[key]


# ---------------------------------------------------------------------------
# speculative fan-out/join dispatch group
# ---------------------------------------------------------------------------
# default cap on rows concurrently being speculated on across one join
# (each task declares how many rows it covers; tasks park until budget
# frees up, except when nothing is in flight — progress is guaranteed)
SPEC_INFLIGHT_ROWS_CAP = 4096


@dataclass
class SpecTask:
    """One unit of speculative work for a :class:`SpeculativeJoin`.

    ``rows`` is the number of input rows the thunk covers (drives the
    in-flight row cap and waste accounting); ``mandatory`` marks work
    the serial plan needs regardless (never cancelled, never counted
    as speculative dispatch)."""
    thunk: Callable[[], object]
    rows: int = 0
    label: str = ""
    mandatory: bool = False


class SpeculativeJoin:
    """Bounded fan-out/join for heterogeneous speculative tasks: filter
    masks, row completions, rerank warmups.

    Serial execution of a dependent edge pays the upstream round-trip
    before the downstream one; speculation runs both concurrently over
    the upstream INPUT and reconciles afterwards — outputs stay
    bit-identical (per-tuple results are independent of batch
    composition), at the cost of requests over rows the upstream stage
    would have eliminated (the wasted-request budget the optimizer
    bounds via recorded selectivity).

    Tasks run on a BOUNDED set of dedicated runner threads, not the
    scheduler's worker pool: each task blocks in
    ``DispatchJob.result()`` while its batches execute on the pool,
    and parking that wait on a pool thread could deadlock a small
    pool.  The runner count is capped relative to the scheduler's
    ``max_workers`` (and the total speculative in-flight rows by
    ``max_inflight_rows``), so a deep chain fans out a few members at
    a time instead of spawning one thread per member.  Batch dispatch
    itself still rides ``RequestScheduler.submit_map``: identical
    cache keys coalesce through the single-flight registry, every
    batch respects the per-model concurrency gates, and part-filled
    tails ride the co-packing queue.

    Cancellation: ``cancel(i)`` drops task *i* if it has not started —
    the thunk never runs and no request reaches the provider (counted
    in ``SchedulerStats.spec_cancelled``).  Thunks may cancel sibling
    tasks (an upstream mask resolving proves speculative rows dead).
    A task that fails with a non-overflow error fails the whole join
    and cancels everything not yet started (overflow handling stays
    inside the dispatch engine: an overflow-NULLed tuple resolves the
    same way it would serially)."""

    def __init__(self, scheduler: Optional["RequestScheduler"] = None,
                 max_runners: Optional[int] = None,
                 max_inflight_rows: Optional[int] = None):
        workers = scheduler.max_workers if scheduler is not None else 16
        self.max_runners = max_runners or max(2, min(8, workers // 2))
        self.max_inflight_rows = max_inflight_rows or SPEC_INFLIGHT_ROWS_CAP
        self.stats = scheduler.stats if scheduler is not None else None
        self._cond = threading.Condition()
        self._cancelled: set = set()
        self._started: set = set()
        self._inflight_rows = 0
        self.cancelled: List[int] = []      # indices dropped, in order

    # ---- cancellation ------------------------------------------------------
    def cancel(self, index: int) -> bool:
        """Drop task ``index`` if it has not started; returns True when
        the cancellation took effect (the thunk will never run)."""
        with self._cond:
            if index in self._started or index in self._cancelled:
                return False
            self._cancelled.add(index)
            return True

    def note_wasted(self, rows: int):
        """Record rows speculated on that the serial plan would never
        have evaluated (the caller knows after reconciling masks)."""
        if self.stats is not None and rows > 0:
            self.stats.add(spec_wasted_rows=rows)

    # ---- execution ---------------------------------------------------------
    def _admit(self, task: SpecTask, index: int) -> bool:
        """Claim the right to run ``index``; blocks for row budget.
        Returns False when the task was cancelled before starting."""
        with self._cond:
            while True:
                if index in self._cancelled and not task.mandatory:
                    return False
                if (self._inflight_rows == 0
                        or self._inflight_rows + task.rows
                        <= self.max_inflight_rows):
                    self._started.add(index)
                    self._inflight_rows += task.rows
                    return True
                self._cond.wait(0.05)

    def _retire(self, task: SpecTask):
        with self._cond:
            self._inflight_rows -= task.rows
            self._cond.notify_all()

    def run(self, tasks: Sequence[SpecTask]) -> list:
        """Run the tasks concurrently on bounded runner threads; returns
        results in task order (``None`` for cancelled tasks — their
        indices land in ``self.cancelled``)."""
        tasks = list(tasks)
        results: List = [None] * len(tasks)
        errors: List[BaseException] = []
        order = list(range(len(tasks)))
        next_lock = threading.Lock()

        def worker():
            while True:
                with next_lock:
                    if not order or errors:
                        return
                    k = order.pop(0)
                task = tasks[k]
                if not self._admit(task, k):
                    if self.stats is not None:
                        self.stats.add(spec_cancelled=1)
                    with next_lock:
                        self.cancelled.append(k)
                    continue
                if self.stats is not None and not task.mandatory:
                    self.stats.add(spec_dispatched=1)
                try:
                    results[k] = task.thunk()
                # re-raised on the caller  # flocklint: ignore[FLKL105]
                except BaseException as exc:
                    errors.append(exc)
                    with self._cond:     # fail fast: drop unstarted work
                        self._cancelled.update(
                            i for i in range(len(tasks))
                            if i not in self._started)
                finally:
                    self._retire(task)

        n_threads = min(len(tasks), self.max_runners)
        threads = [threading.Thread(target=worker,
                                    name=f"flockjax-spec-{i}")
                   for i in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if errors:
            raise errors[0]
        self.cancelled.sort()
        return results


class SpeculativeMaskJoin:
    """Mask-specific facade over :class:`SpeculativeJoin` for
    ``llm_filter`` chains: fan every member out over the chain's INPUT
    tuple stream and reconcile the boolean masks with AND.  The
    surviving tuple stream is identical to serial chain execution
    (per-tuple verdicts are independent of batch composition), but the
    chain's critical path collapses toward one round-trip."""

    @staticmethod
    def run(thunks: Sequence[Callable[[], List[bool]]],
            scheduler: Optional["RequestScheduler"] = None,
            rows: int = 0) -> tuple[List[List[bool]], List[bool]]:
        """Run every member thunk concurrently; returns ``(member_masks,
        combined)`` where ``combined[i] = AND(member[i] for members)``."""
        join = SpeculativeJoin(scheduler)
        masks = join.run([SpecTask(th, rows=rows, label=f"member-{k}")
                          for k, th in enumerate(thunks)])
        lengths = {len(m) for m in masks}
        if len(lengths) > 1:
            raise ValueError(
                f"speculative members returned masks of differing "
                f"lengths {sorted(lengths)}")
        combined = [all(col) for col in zip(*masks)]
        return [list(m) for m in masks], combined


