"""Semantic scalar & aggregate functions (paper Table 1) + the staged
execution path that backs them: dedup -> cache -> batch-plan -> dispatch.

Scalar (map) functions — one output per input tuple:
    llm_complete, llm_complete_json, llm_filter, llm_embedding
Aggregate (reduce) functions — one output per tuple group:
    llm_reduce, llm_reduce_json, llm_rerank, llm_first, llm_last
plus ``fusion`` (see fusion.py) for hybrid-search score combination.

Every function takes ``{'model_name': ...}``-style model/prompt argument
dicts like FlockMTL: either a registered resource name (+optional @version)
or an inline spec, so SQL pipelines stay fixed while admins swap resources.

The dispatch stage has two modes: with ``SemanticContext(scheduler=...)``
batch requests go to the concurrent ``RequestScheduler`` (overlapped
in-flight requests, single-flight key dedup); with ``scheduler=None``
they run through the serial adaptive loop — same batches, same results.

Every dispatch additionally folds its ``BatchStats`` (request/retry
counts, batch sizes, per-request latencies) into the context's
``calibration_stats`` — persisted by the ``CalibrationStore`` sidecar —
so the plan optimizer's cost model is calibrated from observed execution
statistics rather than static heuristics.  The ``speculate`` knob
(``False`` | ``True``/``"auto"`` | ``"always"``) opts a session into
speculative ``llm_filter``-chain dispatch: the optimizer fans a chain's
members out over the chain *input* concurrently and ANDs the masks,
trading wasted requests — expected waste is predicted from recorded
selectivity and capped at ``speculate_waste_cap`` x the serial chain's
request count — for k-1 saved round-trips.
"""

from __future__ import annotations

import json
import re
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .cache import (CALIBRATION_COUNT_WINDOW, CALIBRATION_WINDOW,
                    CalibrationStore, IndexStore, PredictionCache,
                    SelectivityStore, bound_observations, cache_key,
                    headroom_factor)
from .batching import plan_batches
from .metaprompt import (build_metaprompt, build_multi_task, build_prefix,
                         serialize_tuple)
from .provider import BaseProvider, MockProvider, estimate_tokens
from .resources import Catalog, ModelResource
from .scheduler import (PACK_LINGER_LATENCY_FRACTION, PACK_LINGER_MIN_S,
                        RequestScheduler, execute_serial)


@dataclass
class ExecutionReport:
    """Per-call optimizer trace (feeds the plan-inspection UI)."""
    function: str = ""
    n_tuples: int = 0
    n_unique: int = 0
    cache_hits: int = 0
    requests: int = 0
    retries: int = 0
    nulls: int = 0
    batch_sizes: List[int] = field(default_factory=list)
    serialization: str = "xml"
    meta_prompt_prefix: str = ""
    chosen_batch_size: str = "auto"
    selectivity: Optional[float] = None   # filter calls: pass rate
    coalesced: int = 0    # keys served by another job's in-flight request
    packed: int = 0       # tail batches that rode another job's request
    # wall seconds per successful provider request (completion order);
    # aggregated into the CalibrationStore for the calibrated cost model
    latencies: List[float] = field(default_factory=list)


class SemanticContext:
    """Catalog + provider + cache + knobs — one per database session."""

    def __init__(self, catalog: Optional[Catalog] = None,
                 provider: Optional[BaseProvider] = None,
                 cache: Optional[PredictionCache] = None,
                 serialization: str = "xml",
                 enable_cache: bool = True, enable_dedup: bool = True,
                 enable_batching: bool = True, max_batch: int = 0,
                 scheduler: Optional[RequestScheduler] = None,
                 selectivity_path: Optional[str] = None,
                 speculate=False, speculate_waste_cap: float = 1.0,
                 calibration_path: Optional[str] = None,
                 copack: bool = True,
                 index_path: Optional[str] = None,
                 objective: str = "latency"):
        self.catalog = catalog or Catalog()
        self.provider = provider or MockProvider()
        self.cache = cache or PredictionCache()
        self.serialization = serialization
        self.enable_cache = enable_cache
        self.enable_dedup = enable_dedup
        self.enable_batching = enable_batching
        self.max_batch = max_batch
        # concurrent dispatch engine; None = serial (bit-identical) path
        self.scheduler = scheduler
        # speculative filter-chain dispatch: False = off, True/"auto" =
        # the optimizer speculates a chain only when the calibrated cost
        # model says it is cheaper, "always" = force every eligible
        # chain (tests/benchmarks).  ``speculate_waste_cap`` bounds the
        # expected wasted requests (those over tuples an earlier filter
        # would have eliminated, predicted from recorded selectivity)
        # to at most cap x the serial chain's request count.
        self.speculate = speculate
        self.speculate_waste_cap = speculate_waste_cap
        # cross-node batch co-packing: part-filled tail batches from
        # concurrently-dispatched map nodes that share a (model,
        # metaprompt-prefix) identity merge into one provider request.
        # copack=False is the escape hatch (results are bit-identical
        # either way; only request density changes).
        self.copack = copack
        # scheduling objective: "latency" flushes a parked co-pack the
        # moment no rider is plausibly in flight and bounds the linger
        # by the calibrated expected-arrival window; "cost" keeps the
        # full configured linger window (the density dial) and ranks
        # plans by token/request spend alone.  The optimizer prices
        # both frontiers either way (explain() shows them).
        if objective not in ("latency", "cost"):
            raise ValueError("objective must be 'latency' or 'cost', "
                             f"got {objective!r}")
        self.objective = objective
        # prefix identities currently eligible for co-packing: managed
        # by Pipeline._run_group (only node groups that actually contain
        # >= 2 same-prefix nodes pay the packing-queue linger)
        self._copack_active: Dict[Any, int] = {}
        self.reports: List[ExecutionReport] = []
        self._lock = threading.Lock()
        # selectivity gets its own lock: its save() does file I/O, which
        # must not stall add_report on concurrently dispatched map nodes
        self._sel_lock = threading.Lock()
        self._tl = threading.local()     # per-thread last report
        # per-prompt filter pass-rate observations: prompt_id -> [passed,
        # total].  Feeds the plan optimizer's cost-ordered filter chains.
        self.selectivity_stats: Dict[str, List[int]] = {}
        # persistence sidecar: survives sessions next to the prediction
        # cache, so recurring prompts are cost-ordered from real stats
        if selectivity_path is None and self.cache.persist_path is not None:
            selectivity_path = str(self.cache.persist_path) \
                + ".selectivity.json"
        self.selectivity_store = (SelectivityStore(selectivity_path)
                                  if selectivity_path else None)
        # debounce sidecar writes: at most one full-file rewrite per
        # interval on the hot path; flush_selectivity() forces the rest
        # (Pipeline.collect() calls it once per plan execution)
        self._sel_save_interval = 0.5
        self._sel_last_save = float("-inf")
        self._sel_dirty = False
        if self.selectivity_store is not None:
            loaded = SelectivityStore.prune_stale(
                self.selectivity_store.load(), self.catalog)
            self.selectivity_stats.update(loaded)
        # execution-statistics sidecar (calibrated cost model): per-model
        # request/retry/tuple counts + a bounded latency window, fed by
        # every dispatch and persisted next to the prediction cache
        self.calibration_stats: Dict[str, dict] = {}
        self._cal_lock = threading.Lock()
        self._cal_last_save = float("-inf")
        self._cal_dirty = False
        if calibration_path is None and self.cache.persist_path is not None:
            calibration_path = str(self.cache.persist_path) \
                + ".calibration.json"
        self.calibration_store = (CalibrationStore(calibration_path)
                                  if calibration_path else None)
        if self.calibration_store is not None:
            self.calibration_stats.update(CalibrationStore.prune_stale(
                self.calibration_store.load(), self.catalog))
        # vector-index memoisation (retrieval plan operators): a
        # session-local registry of built VectorIndex objects keyed by
        # (model ref, corpus fingerprint), plus the persistent
        # ``IndexStore`` sidecar so a repeated RAG query over an
        # unchanged corpus skips re-embedding entirely
        self._index_registry: Dict[Any, Any] = {}
        self._index_lock = threading.Lock()
        if index_path is None and self.cache.persist_path is not None:
            index_path = str(self.cache.persist_path) + ".index.json"
        self.index_store = IndexStore(index_path) if index_path else None
        if self.index_store is not None:
            self.index_store.prune(self.catalog)
        # calibration-aware batch sizing: per-model planning headroom is
        # SNAPSHOT from the loaded statistics (a model that routinely
        # overflowed last session plans smaller batches up front this
        # session) and stays fixed within the session — recomputing it
        # mid-flight would make concurrently-dispatched nodes' batch
        # plans depend on scheduling order, breaking determinism
        self._headroom: Dict[str, float] = {
            ref: headroom_factor(rec["requests"], rec["retries"])
            for ref, rec in self.calibration_stats.items()}

    # ---- report bookkeeping (thread-safe: nodes may run concurrently) ------
    def add_report(self, rep: ExecutionReport):
        with self._lock:
            self.reports.append(rep)
            slot = len(self.reports) - 1
        self._tl.last_report = rep
        self._tl.last_report_slot = slot

    def last_report(self) -> Optional[ExecutionReport]:
        """The report appended by the current thread's most recent
        semantic call (``reports[-1]`` is racy under the scheduler's
        concurrent node dispatch)."""
        return getattr(self._tl, "last_report", None)

    def last_report_slot(self) -> Optional[int]:
        """Index of ``last_report()`` in ``reports`` — recorded at
        append time so plan bookkeeping stays O(1) on long-lived
        contexts."""
        return getattr(self._tl, "last_report_slot", None)

    # ---- co-packing eligibility (managed by Pipeline._run_group) -----------
    @staticmethod
    def _copack_counts(identities) -> Dict[Any, int]:
        """Normalise a co-pack group spec — a ``{identity: expected
        submitter count}`` mapping or a plain iterable (one submitter
        per occurrence) — into a count dict."""
        if isinstance(identities, dict):
            return {i: int(n) for i, n in identities.items() if n > 0}
        counts: Dict[Any, int] = {}
        for ident in identities:
            counts[ident] = counts.get(ident, 0) + 1
        return counts

    @staticmethod
    def _pack_queue_key(identity):
        # the scheduler keys its packing queue (and rider-expectation
        # registry) by (model.ref, identity); identity[1] is the fully-
        # resolved ModelResource in every pack identity we mint
        return (identity[1].ref, identity)

    def copack_begin(self, identities):
        """Mark prefix identities as co-packable for the duration of a
        concurrent node-group dispatch (re-entrant: counted).

        ``identities`` maps each identity to the number of submitters
        the group expects to dispatch under it (an iterable counts one
        per occurrence).  The counts are registered with the scheduler
        as outstanding rider expectations, so a parked pack flushes the
        moment its LAST expected tail arrives instead of waiting out
        the linger deadline."""
        counts = self._copack_counts(identities)
        with self._lock:
            for ident in counts:
                self._copack_active[ident] = \
                    self._copack_active.get(ident, 0) + 1
        if self.scheduler is not None:
            for ident, n in counts.items():
                self.scheduler.pack_expect(self._pack_queue_key(ident), n)

    def copack_end(self, identities):
        """Close a co-pack group: drop eligibility and retire whatever
        rider expectations the group never delivered (members that
        resolved entirely from cache, raised, ...).  Retiring flushes
        packs still parked under a newly-riderless identity — a lone
        surviving tail must not wait out a window no partner can ever
        fill."""
        counts = self._copack_counts(identities)
        with self._lock:
            for ident in counts:
                n = self._copack_active.get(ident, 0) - 1
                if n <= 0:
                    self._copack_active.pop(ident, None)
                else:
                    self._copack_active[ident] = n
        if self.scheduler is not None:
            for ident, n in counts.items():
                self.scheduler.pack_retire(self._pack_queue_key(ident), n)

    def copack_skip(self, identity):
        """Signal that one expected co-pack submitter resolved WITHOUT
        dispatching (all rows deduped/cached): riders parked on the
        identity must not keep waiting for a tail that never comes."""
        if self.scheduler is not None and self.copack_eligible(identity):
            self.scheduler.pack_arrived(self._pack_queue_key(identity))

    def copack_eligible(self, identity) -> bool:
        if not (self.copack and self.scheduler is not None
                and self.enable_batching):
            return False
        with self._lock:
            return identity in self._copack_active

    def copack_linger(self, model_ref: str) -> Optional[float]:
        """Calibrated expected-arrival window for a parked tail batch:
        under the latency objective, a fraction of the model's observed
        p50 request latency (floored at ``PACK_LINGER_MIN_S``, capped by
        the scheduler's configured ``pack_linger_s``).  None — meaning
        the scheduler's fixed window governs — when uncalibrated or
        when the session optimizes for cost (the density dial)."""
        if self.scheduler is None or self.objective != "latency":
            return None
        lat = self.calibrated_latency(model_ref, 50.0)
        if lat is None:
            return None
        return min(self.scheduler.pack_linger_s,
                   max(PACK_LINGER_MIN_S,
                       PACK_LINGER_LATENCY_FRACTION * lat))

    # ---- vector-index registry (retrieval plan operators) ------------------
    def lookup_index(self, key):
        """Session-local built-index lookup: ``key`` is ``(model ref,
        corpus fingerprint)``; None when no node built it yet."""
        with self._index_lock:
            return self._index_registry.get(key)

    def store_index(self, key, index):
        with self._index_lock:
            self._index_registry[key] = index

    def index_entries(self, model_ref: str) -> list:
        """(fingerprint, n_rows) for every session-built index of this
        model — the prefix-append candidates ``ensure_index`` matches a
        grown corpus against."""
        with self._index_lock:
            return [(fp, len(idx.vectors))
                    for (ref, fp), idx in self._index_registry.items()
                    if ref == model_ref]

    def index_cached(self, model_ref: str, fingerprint: str) -> bool:
        """Would a retrieval node over this (model, corpus) skip the
        corpus embed?  Feeds the optimizer's cost model (an index found
        in the session registry or the persistent sidecar makes the
        node's embed estimate queries-only)."""
        with self._index_lock:
            if (model_ref, fingerprint) in self._index_registry:
                return True
        return (self.index_store is not None
                and self.index_store.has(model_ref, fingerprint))

    # ---- selectivity bookkeeping (filter reordering) -----------------------
    def record_selectivity(self, prompt_id: str, passed: int, total: int):
        if total <= 0:
            return
        # snapshot + save stay under one lock: concurrent filter nodes
        # saving stale snapshots out of order would lose observations
        with self._sel_lock:
            s = self.selectivity_stats.setdefault(prompt_id, [0, 0])
            s[0] += passed
            s[1] += total
            # bounded observation window (drift detection): rescale so
            # old observations decay and a shifted distribution
            # re-learns within ~one window
            s[0], s[1] = bound_observations(s[0], s[1])
            self._sel_dirty = True
            self._save_selectivity_locked()

    def flush_selectivity(self):
        """Persist any selectivity observations the debounce deferred."""
        with self._sel_lock:
            self._save_selectivity_locked(force=True)

    def _save_selectivity_locked(self, force: bool = False):
        if self.selectivity_store is None or not self._sel_dirty:
            return
        now = time.monotonic()
        if not force and now - self._sel_last_save < \
                self._sel_save_interval:
            return
        self.selectivity_store.save(
            {k: list(v) for k, v in self.selectivity_stats.items()})
        self._sel_last_save = now
        self._sel_dirty = False

    def expected_selectivity(self, prompt_id: str,
                             default: float = 0.5) -> float:
        s = self.selectivity_stats.get(prompt_id)
        if not s or s[1] == 0:
            return default
        return s[0] / s[1]

    # ---- calibration bookkeeping (calibrated cost model) -------------------
    def record_calibration(self, model_ref: str, requests: int,
                           retries: int, tuples: int,
                           latencies: Sequence[float]):
        """Fold one dispatch's ``BatchStats`` into the per-model
        execution statistics (debounced sidecar write, like
        selectivity)."""
        if requests <= 0 and retries <= 0:
            return
        with self._cal_lock:
            rec = self.calibration_stats.setdefault(
                model_ref, {"requests": 0, "retries": 0, "tuples": 0,
                            "latency_s": []})
            rec["requests"] += requests
            rec["retries"] += retries
            rec["tuples"] += tuples
            # bounded counters: beyond the window old admissions decay,
            # so retry rate and mean batch size track the model's
            # CURRENT behaviour (headroom re-learns after a fix)
            total = rec["requests"] + rec["retries"]
            if total > CALIBRATION_COUNT_WINDOW:
                scale = CALIBRATION_COUNT_WINDOW / total
                for k in ("requests", "retries", "tuples"):
                    rec[k] = int(round(rec[k] * scale))
            rec["latency_s"].extend(float(x) for x in latencies)
            del rec["latency_s"][:-CALIBRATION_WINDOW]
            self._cal_dirty = True
            self._save_calibration_locked()

    def flush_calibration(self):
        """Persist any calibration observations the debounce deferred."""
        with self._cal_lock:
            self._save_calibration_locked(force=True)

    def _save_calibration_locked(self, force: bool = False):
        if self.calibration_store is None or not self._cal_dirty:
            return
        now = time.monotonic()
        if not force and now - self._cal_last_save < \
                self._sel_save_interval:
            return
        self.calibration_store.save(
            {ref: {"requests": r["requests"], "retries": r["retries"],
                   "tuples": r["tuples"],
                   "latency_s": list(r["latency_s"])}
             for ref, r in self.calibration_stats.items()})
        self._cal_last_save = now
        self._cal_dirty = False

    def flush_stats(self):
        """Force both debounced sidecars (selectivity + calibration) to
        disk.  ``Pipeline.collect()`` calls this once per plan
        execution; using the context as a ``with`` block flushes on
        exit."""
        self.flush_selectivity()
        self.flush_calibration()

    def calibrated_latency(self, model_ref: str,
                           pct: float = 50.0) -> Optional[float]:
        """Observed per-request latency percentile for a model, from the
        recorded execution statistics; None when uncalibrated."""
        rec = self.calibration_stats.get(model_ref)
        lat = rec["latency_s"] if rec else None
        if not lat:
            return None
        return float(np.percentile(np.asarray(lat, dtype=float), pct))

    def calibrated_retry_rate(self, model_ref: str) -> float:
        """Observed overflow-retry fraction: retries / (requests +
        retries), 0.0 when uncalibrated.  Inflates calibrated request
        estimates — a model that routinely overflows pays more waves
        than the batch plan alone predicts."""
        rec = self.calibration_stats.get(model_ref)
        if not rec:
            return 0.0
        total = rec["requests"] + rec["retries"]
        return rec["retries"] / total if total else 0.0

    def batch_headroom(self, model_ref: str) -> float:
        """Planning headroom for ``plan_batches`` — the calibration
        feedback path.  Snapshot at session start from the persisted
        execution statistics (see ``__init__``); 1.0 (full budget) for
        models with no recorded overflow history."""
        return self._headroom.get(model_ref, 1.0)

    def refresh_headroom(self):
        """Recompute the per-model headroom snapshot from the current
        in-session calibration statistics.  Call between plan executions
        (never mid-dispatch) — e.g. after a warmup pass in a benchmark —
        to apply observed retry rates without a session restart."""
        with self._cal_lock:
            self._headroom = {
                ref: headroom_factor(rec["requests"], rec["retries"])
                for ref, rec in self.calibration_stats.items()}

    # ---- lifecycle ---------------------------------------------------------
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.flush_stats()
        return False

    # ---- resource resolution (name ref or inline spec) --------------------
    def resolve_model(self, spec: Dict[str, Any]) -> ModelResource:
        if "model_name" in spec:
            m = self.catalog.get_model(spec["model_name"])
            if m is None:
                raise KeyError(f"MODEL {spec['model_name']!r} not found")
            return m
        return ModelResource(
            name=spec.get("model", "inline"), version=0,
            arch=spec.get("arch", "mock"),
            context_window=int(spec.get("context_window", 4096)),
            max_output_tokens=int(spec.get("max_output_tokens", 32)),
            embedding_dim=int(spec.get("embedding_dim", 0)),
            max_concurrency=int(spec.get("max_concurrency", 4)))

    def resolve_prompt(self, spec: Dict[str, Any]) -> tuple[str, str]:
        """Returns (prompt_text, cache_identity)."""
        if "prompt_name" in spec:
            p = self.catalog.get_prompt(spec["prompt_name"])
            if p is None:
                raise KeyError(f"PROMPT {spec['prompt_name']!r} not found")
            return p.text, p.ref
        text = spec.get("prompt", "")
        return text, f"inline:{text}"


# ---------------------------------------------------------------------------
# map-function core, staged: dedup -> cache -> batch-plan -> dispatch
# ---------------------------------------------------------------------------
_LINE_RE = re.compile(r"^\s*(\d+)\s*:\s*(.*)$")


def _parse_rows(lines: Sequence[str], n: int) -> List[Optional[str]]:
    out: List[Optional[str]] = [None] * n
    for ln in lines:
        m = _LINE_RE.match(str(ln))
        if m and int(m.group(1)) < n:
            out[int(m.group(1))] = m.group(2).strip()
    return out


def _map_function(ctx: SemanticContext, kind: str, model_spec, prompt_spec,
                  tuples: Sequence[dict]) -> List[Optional[str]]:
    model = ctx.resolve_model(model_spec)
    prompt_text, prompt_id = ctx.resolve_prompt(prompt_spec)
    return _map_core(ctx, kind, model, prompt_text, prompt_id, tuples)


def _dedup_stage(ctx: SemanticContext, ser: Sequence[str]
                 ) -> tuple[List[str], List[int], List[int]]:
    """Stage 1 — predict only over distinct serialized inputs.

    Returns (order, first_idx, back): the distinct payloads in first-seen
    order, the original index carrying each, and the back-mapping from
    original positions to distinct positions."""
    if not ctx.enable_dedup:
        idx = list(range(len(ser)))
        return list(ser), idx, idx
    uniq: Dict[str, int] = {}
    order: List[str] = []
    first_idx: List[int] = []
    for i, s in enumerate(ser):
        if s not in uniq:
            uniq[s] = len(order)
            order.append(s)
            first_idx.append(i)
    return order, first_idx, [uniq[s] for s in ser]


def _cache_stage(ctx: SemanticContext, keys: Sequence[str],
                 rep: ExecutionReport
                 ) -> tuple[List[Optional[Any]], List[int]]:
    """Stage 2 — fill from the prediction cache; return the result slots
    plus the positions still needing a provider request."""
    results: List[Optional[Any]] = [None] * len(keys)
    todo: List[int] = []
    if not ctx.enable_cache:
        return results, list(range(len(keys)))
    for i, k in enumerate(keys):
        hit, val = ctx.cache.get(k)
        if hit:
            results[i] = val
            rep.cache_hits += 1
        else:
            todo.append(i)
    return results, todo


def _dispatch_stage(ctx: SemanticContext, model: ModelResource,
                    todo: List[int], keys: Sequence[str],
                    costs: List[int], prefix_tokens: int, call,
                    rep: ExecutionReport, pack_key=None, pack_rows=None,
                    pack_call=None) -> list:
    """Stage 3 — run the misses: batch-plan (with the model's calibrated
    headroom), then either hand the batches to the concurrent scheduler
    (overlapped per-model in-flight requests, single-flight key dedup,
    overflow split-and-requeue inside the engine) or fall back to the
    serial adaptive loop.  Both paths see identical batch plans and
    produce identical results and counts.  With a co-packable prefix
    identity active (``ctx.copack_eligible``), the scheduler may merge
    this dispatch's part-filled tail batch with another same-prefix
    job's — fewer, denser requests, same per-row results."""
    mb = ctx.max_batch if ctx.enable_batching else 1
    headroom = (ctx.batch_headroom(model.ref) if ctx.enable_batching
                else 1.0)
    window = (model.context_window if ctx.enable_batching
              else prefix_tokens + max(costs) + model.max_output_tokens + 1)
    if ctx.scheduler is not None:
        pack_kw = {}
        if pack_key is not None and ctx.copack_eligible(pack_key):
            pack_kw = dict(pack_key=pack_key, pack_rows=pack_rows,
                           pack_call=pack_call,
                           pack_linger=ctx.copack_linger(model.ref))
        job = ctx.scheduler.submit_map(
            model, [keys[i] for i in todo], costs, prefix_tokens, call,
            cache=ctx.cache if ctx.enable_cache else None,
            max_batch=mb, context_window=window,
            single_flight=ctx.enable_cache, headroom=headroom, **pack_kw)
        out, stats = job.result()
        rep.coalesced = job.coalesced
        rep.cache_hits += job.late_hits
        rep.packed = stats.packed
    else:
        out, stats = execute_serial(todo, costs, prefix_tokens, window,
                                    model.max_output_tokens, call,
                                    max_batch=mb, headroom=headroom)
        if ctx.enable_cache:
            for j, i in enumerate(todo):
                if out[j] is not None:
                    ctx.cache.put(keys[i], out[j])
    rep.requests, rep.retries, rep.nulls = (stats.requests, stats.retries,
                                            stats.nulls)
    rep.batch_sizes = stats.batch_sizes
    rep.latencies = stats.latencies
    ctx.record_calibration(model.ref, stats.requests, stats.retries,
                           sum(stats.batch_sizes), stats.latencies)
    return out


def _map_core(ctx: SemanticContext, kind: str, model: ModelResource,
              prompt_text: str, prompt_id: str,
              tuples: Sequence[dict]) -> List[Optional[str]]:
    rep = ExecutionReport(function=kind, n_tuples=len(tuples),
                          serialization=ctx.serialization)
    ctx.add_report(rep)
    if not tuples:
        return []

    ser = [serialize_tuple(t, ctx.serialization) for t in tuples]
    order, first_idx, back = _dedup_stage(ctx, ser)
    rep.n_unique = len(order)
    uniq_tuples = [tuples[i] for i in first_idx]

    keys = [cache_key(model.ref, prompt_id, kind, ctx.serialization, s)
            for s in order]
    results, todo = _cache_stage(ctx, keys, rep)

    if todo:
        prefix = build_prefix(kind, prompt_text, ctx.serialization)
        prefix_tokens = estimate_tokens(prefix)
        costs = [estimate_tokens(order[i]) for i in todo]

        # prefix identity: dispatches sharing this tuple render the SAME
        # static metaprompt prefix AND execute under the same model
        # limits, so their rows can ride one request (the scheduler's
        # co-packing stage; pipeline.copack_identity computes the
        # identical tuple from a plan node).  The provider instance and
        # the FULL resolved model (frozen dataclass — inline specs that
        # differ only in caps must not alias) are part of the identity.
        pack_key = (id(ctx.provider), model, kind, ctx.serialization,
                    prompt_text)
        pack_rows = [uniq_tuples[todo[j]] for j in range(len(todo))]

        def pack_call(rows: List[dict]) -> List[Optional[str]]:
            mp = build_metaprompt(kind, prompt_text, rows,
                                  ctx.serialization)
            raw = ctx.provider.complete(model, mp, len(rows))
            return _parse_rows(raw, len(rows))

        def call(batch_idx: List[int]) -> List[Optional[str]]:
            return pack_call([uniq_tuples[todo[j]] for j in batch_idx])

        out = _dispatch_stage(ctx, model, todo, keys, costs, prefix_tokens,
                              call, rep, pack_key=pack_key,
                              pack_rows=pack_rows, pack_call=pack_call)
        for j, i in enumerate(todo):
            results[i] = out[j]
    elif ctx.scheduler is not None:
        # nothing to dispatch (all cached/deduped) still counts as this
        # submitter's arrival: a rider parked on the shared identity
        # must not wait out its deadline for a tail that never comes
        ctx.copack_skip((id(ctx.provider), model, kind,
                         ctx.serialization, prompt_text))

    return [results[b] for b in back]


# ---------------------------------------------------------------------------
# public scalar functions
# ---------------------------------------------------------------------------
def llm_complete(ctx, model_spec, prompt_spec, tuples):
    return _map_function(ctx, "complete", model_spec, prompt_spec, tuples)


def llm_complete_json(ctx, model_spec, prompt_spec, tuples):
    raw = _map_function(ctx, "complete_json", model_spec, prompt_spec,
                        tuples)
    out = []
    for r in raw:
        try:
            out.append(json.loads(r) if r is not None else None)
        except json.JSONDecodeError:
            out.append(None)
    return out


_TRUE = {"true", "yes", "1"}


def llm_filter(ctx, model_spec, prompt_spec, tuples) -> List[bool]:
    raw = _map_function(ctx, "filter", model_spec, prompt_spec, tuples)
    mask = [str(r).strip().lower() in _TRUE if r is not None else False
            for r in raw]
    _, prompt_id = ctx.resolve_prompt(prompt_spec)
    ctx.record_selectivity(prompt_id, sum(mask), len(mask))
    rep = ctx.last_report()
    if rep is not None:
        rep.selectivity = sum(mask) / len(mask) if mask else None
    return mask


# ---------------------------------------------------------------------------
# fused multi-output pass (the plan optimizer's semantic-fusion rule)
# ---------------------------------------------------------------------------
MULTI_KINDS = ("filter", "complete", "complete_json")


def _decode_multi_value(kind: str, val) -> Any:
    if kind == "filter":
        if isinstance(val, bool):
            return val
        return str(val).strip().lower() in _TRUE
    if kind == "complete_json":
        if isinstance(val, (dict, list)):
            return val
        try:
            return json.loads(val) if val is not None else None
        except (json.JSONDecodeError, TypeError):
            return None
    return None if val is None else str(val)


def llm_multi(ctx, model_spec, subtasks: Sequence[dict],
              tuples: Sequence[dict]) -> List[List[Any]]:
    """One metaprompt pass answering several sub-tasks per tuple.

    ``subtasks`` is a list of ``{"kind": filter|complete|complete_json,
    "prompt": <prompt spec>}`` dicts sharing one model and one tuple
    schema.  Returns one result list per subtask, aligned with ``tuples``
    (filter -> bool, complete -> str|None, complete_json -> obj|None).
    """
    model = ctx.resolve_model(model_spec)
    kinds, texts, ids = [], [], []
    for st in subtasks:
        if st["kind"] not in MULTI_KINDS:
            raise ValueError(f"unfusable sub-task kind {st['kind']!r}")
        text, pid = ctx.resolve_prompt(st["prompt"])
        kinds.append(st["kind"])
        texts.append(text)
        ids.append(f"{st['kind']}:{pid}")
    prompt_text = build_multi_task(kinds, texts)
    prompt_id = "multi|" + "|".join(ids)
    raw = _map_core(ctx, "multi", model, prompt_text, prompt_id, tuples)

    per_task: List[List[Any]] = [[] for _ in subtasks]
    n_filters = [0] * len(subtasks)
    for r in raw:
        try:
            obj = json.loads(r) if r is not None else {}
        except json.JSONDecodeError:
            obj = {}
        if not isinstance(obj, dict):
            obj = {}
        for k, kind in enumerate(kinds):
            v = _decode_multi_value(kind, obj.get(f"t{k}"))
            per_task[k].append(v)
            if kind == "filter" and v:
                n_filters[k] += 1
    for k, kind in enumerate(kinds):
        if kind == "filter":
            ctx.record_selectivity(ids[k].split(":", 1)[1],
                                   n_filters[k], len(tuples))
    return per_task


def embedding_pack_key(ctx: SemanticContext, model: ModelResource):
    """Metaprompt-prefix identity of an embedding dispatch.  Embeddings
    have no prompt and no serialization framing (raw text payloads), so
    two dispatches co-pack exactly when they target the same provider
    and the same fully-resolved model — mirrored by
    ``pipeline.copack_identity`` for ``llm_embedding`` plan nodes and by
    the retrieval operators' corpus/query embed pairing."""
    return (id(ctx.provider), model, "embedding", "raw", "")


def llm_embedding(ctx, model_spec, tuples) -> np.ndarray:
    """Embedding with dedup + cache (no prompt; paper: 48x from batching).

    Shares the staged path: dedup -> cache -> batch-plan -> dispatch.
    Batches are planned by ``plan_batches`` against the model's context
    window with its calibrated headroom (embeddings decode no output
    tokens, so the whole budget is payload) — NOT shipped as one
    unplanned mega-batch — and per-batch stats feed the calibration
    sidecar, so the cost model learns embedding batch sizes too.  With
    a scheduler the embed batches ride the same concurrent engine (and
    single-flight registry) as the chat-completion map functions, and a
    part-filled tail batch may co-pack with another embed dispatch that
    shares this model (``embedding_pack_key``)."""
    model = ctx.resolve_model(model_spec)
    rep = ExecutionReport(function="embedding", n_tuples=len(tuples),
                          serialization=ctx.serialization)
    ctx.add_report(rep)
    texts = [serialize_tuple(t, ctx.serialization) if isinstance(t, dict)
             else str(t) for t in tuples]
    order, _, back = _dedup_stage(ctx, texts)
    rep.n_unique = len(order)
    keys = [cache_key(model.ref, "", "embedding", "raw", t) for t in order]
    vecs, todo = _cache_stage(ctx, keys, rep)
    if todo:
        costs = [estimate_tokens(order[i]) for i in todo]
        mb = ctx.max_batch if ctx.enable_batching else 1
        headroom = (ctx.batch_headroom(model.ref) if ctx.enable_batching
                    else 1.0)
        window = model.context_window

        def run(positions: List[int]) -> List[list]:
            em = ctx.provider.embed(model,
                                    [order[todo[p]] for p in positions])
            return [em[j].tolist() for j in range(len(positions))]

        if ctx.scheduler is not None:
            def plan(owned: List[int]) -> List[List[int]]:
                bp = plan_batches([costs[p] for p in owned], 0, window,
                                  0, mb, headroom=headroom)
                return [[owned[j] for j in b] for b in bp.batches]

            pack = None
            pack_key = embedding_pack_key(ctx, model)
            if ctx.copack_eligible(pack_key):
                def pack_call(rows: List[str]) -> List[list]:
                    em = ctx.provider.embed(model, rows)
                    return [em[j].tolist() for j in range(len(rows))]

                pack = {"key": pack_key,
                        "rows": [order[i] for i in todo],
                        "call": pack_call,
                        "budget": int(window * headroom),
                        "max_batch": mb, "weights": costs,
                        "linger_s": ctx.copack_linger(model.ref)}
            job = ctx.scheduler.submit(
                model, [keys[i] for i in todo], run,
                cache=ctx.cache if ctx.enable_cache else None,
                single_flight=ctx.enable_cache, plan=plan, pack=pack)
            out, stats = job.result()
            rep.coalesced = job.coalesced
            rep.cache_hits += job.late_hits
            rep.packed = stats.packed
        else:
            out, stats = execute_serial(todo, costs, 0, window, 0, run,
                                        max_batch=mb, headroom=headroom)
            if ctx.enable_cache:
                for j, i in enumerate(todo):
                    if out[j] is not None:
                        ctx.cache.put(keys[i], out[j])
        rep.requests, rep.retries = stats.requests, stats.retries
        rep.batch_sizes = stats.batch_sizes
        rep.latencies = stats.latencies
        ctx.record_calibration(model.ref, stats.requests, stats.retries,
                               sum(stats.batch_sizes), stats.latencies)
        for j, i in enumerate(todo):
            vecs[i] = out[j]
    elif ctx.scheduler is not None:
        # fully cache-served embed dispatch: still signal arrival so a
        # rider parked on the shared embedding identity flushes now
        ctx.copack_skip(embedding_pack_key(ctx, model))
    return np.asarray([vecs[b] for b in back], np.float32)


# ---------------------------------------------------------------------------
# aggregate functions
# ---------------------------------------------------------------------------
def llm_reduce(ctx, model_spec, prompt_spec, tuples,
               kind: str = "reduce") -> Optional[str]:
    model = ctx.resolve_model(model_spec)
    prompt_text, prompt_id = ctx.resolve_prompt(prompt_spec)
    mp = build_metaprompt(kind, prompt_text, list(tuples),
                          ctx.serialization)
    key = cache_key(model.ref, prompt_id, kind, ctx.serialization,
                    mp.suffix)
    if ctx.enable_cache:
        hit, val = ctx.cache.get(key)
        if hit:
            return val
    out = ctx.provider.complete(model, mp, 1)
    val = out[0] if out else None
    if ctx.enable_cache and val is not None:
        ctx.cache.put(key, val)
    return val


def llm_reduce_json(ctx, model_spec, prompt_spec, tuples):
    raw = llm_reduce(ctx, model_spec, prompt_spec, tuples,
                     kind="reduce_json")
    try:
        return json.loads(raw) if raw is not None else None
    except json.JSONDecodeError:
        return None


def llm_rerank(ctx, model_spec, prompt_spec, tuples,
               window: int = 10, stride: int = 5) -> List[int]:
    """Zero-shot listwise rerank (Ma et al. [arXiv:2305.02156]): sliding
    windows from the tail so the best candidates bubble to the front.
    Returns a permutation of tuple indices, most relevant first."""
    model = ctx.resolve_model(model_spec)
    prompt_text, prompt_id = ctx.resolve_prompt(prompt_spec)
    n = len(tuples)
    perm = list(range(n))
    if n <= 1:
        return perm

    def rank_window(idxs: List[int]) -> List[int]:
        rows = [tuples[i] for i in idxs]
        mp = build_metaprompt("rerank", prompt_text, rows, ctx.serialization)
        key = cache_key(model.ref, prompt_id, "rerank", ctx.serialization,
                        mp.suffix)
        if ctx.enable_cache:
            hit, val = ctx.cache.get(key)
            if hit:
                return [idxs[j] for j in val]
        raw = ctx.provider.complete(model, mp, 1)
        order = _parse_permutation(raw[0] if raw else "", len(idxs))
        if ctx.enable_cache:
            ctx.cache.put(key, order)
        return [idxs[j] for j in order]

    start = max(0, n - window)
    while True:
        seg = perm[start:start + window]
        perm[start:start + window] = rank_window(seg)
        if start == 0:
            break
        start = max(0, start - stride)
    return perm


def _parse_permutation(raw: str, n: int) -> List[int]:
    seen, order = set(), []
    for tok in re.split(r"[^\d]+", str(raw)):
        if tok and tok.isdigit():
            i = int(tok)
            if 0 <= i < n and i not in seen:
                order.append(i)
                seen.add(i)
    order += [i for i in range(n) if i not in seen]
    return order


def llm_first(ctx, model_spec, prompt_spec, tuples):
    perm = llm_rerank(ctx, model_spec, prompt_spec, tuples)
    return tuples[perm[0]] if tuples else None


def llm_last(ctx, model_spec, prompt_spec, tuples):
    perm = llm_rerank(ctx, model_spec, prompt_spec, tuples)
    return tuples[perm[-1]] if tuples else None
