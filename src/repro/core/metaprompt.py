"""Meta-prompt construction (paper §2.3, Fig. 1).

The system composes the full prompt from a structured template:

  [STATIC PREFIX — identical across every call for a (model, prompt,
   function, serialization) tuple, so a serving stack can reuse its KV
   prefix across batches ("KV-cache friendly")]
      system instructions
      task: the user prompt text
      output contract (text / JSON / bool / ranking) + formatting rules
  [PER-CALL SUFFIX]
      serialized input tuples (XML — default, JSON, or Markdown)
      output stub

Tuple serialization is deterministic and column-ordered so identical
inputs render identically (prediction-cache hits, dedup).
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Sequence

SERIALIZATIONS = ("xml", "json", "markdown")

_OUTPUT_CONTRACT = {
    "complete": (
        "Return one line of plain text per input tuple, in order, formatted "
        "as `<id>: <answer>`."),
    "complete_json": (
        "Return one JSON object per input tuple, one per line, formatted as "
        "`<id>: <json>`.  The JSON must follow the schema implied by the "
        "task."),
    "filter": (
        "Return one line per input tuple formatted as `<id>: true` or "
        "`<id>: false`."),
    "reduce": (
        "Return a single text value that aggregates ALL input tuples."),
    "reduce_json": (
        "Return a single JSON object that aggregates ALL input tuples."),
    "rerank": (
        "Return the tuple ids ordered from most to least relevant, as a "
        "comma-separated list, e.g. `3,1,2`."),
    "multi": (
        "Several sub-tasks are listed above, each tagged `t<k> [<kind>]`. "
        "Return one line per input tuple formatted as `<id>: <json>` where "
        "the JSON object has one key per sub-task tag.  filter sub-tasks "
        "map to true/false, complete sub-tasks to a text string, "
        "complete_json sub-tasks to a nested JSON object."),
}


def build_multi_task(sub_kinds: Sequence[str],
                     sub_prompts: Sequence[str]) -> str:
    """Compose the user-prompt for a fused (multi-output) semantic pass.

    Each sub-task renders as ``t<k> [<kind>]: <prompt>`` — the tag doubles
    as the output JSON key, and the ``[<kind>]`` annotation is parseable by
    providers (MockProvider uses it to shape deterministic answers)."""
    lines = ["Perform ALL of the following sub-tasks on every input tuple:"]
    for k, (kind, prompt) in enumerate(zip(sub_kinds, sub_prompts)):
        lines.append(f"t{k} [{kind}]: {prompt}")
    return "\n".join(lines)


def serialize_tuple(tup: dict, fmt: str = "xml") -> str:
    keys = list(tup.keys())
    if fmt == "xml":
        cols = "".join(f"<{k}>{tup[k]}</{k}>" for k in keys)
        return f"<tuple>{cols}</tuple>"
    if fmt == "json":
        return json.dumps({k: tup[k] for k in keys}, sort_keys=False,
                          default=str)
    if fmt == "markdown":
        return "| " + " | ".join(str(tup[k]) for k in keys) + " |"
    raise ValueError(f"unknown serialization {fmt!r}")


def serialize_batch(tuples: Sequence[dict], fmt: str = "xml") -> str:
    lines = []
    if fmt == "markdown" and tuples:
        keys = list(tuples[0].keys())
        lines.append("| id | " + " | ".join(keys) + " |")
        lines.append("|" + "---|" * (len(keys) + 1))
    for i, t in enumerate(tuples):
        if fmt == "markdown":
            lines.append(f"| {i} " + serialize_tuple(t, fmt))
        else:
            lines.append(f'<row id="{i}">{serialize_tuple(t, fmt)}</row>'
                         if fmt == "xml"
                         else json.dumps({"id": i, "tuple": t}, default=str))
    return "\n".join(lines)


@dataclass(frozen=True)
class MetaPrompt:
    """A rendered meta-prompt: static prefix + per-call suffix."""
    prefix: str          # shared across calls -> prefix-KV reusable
    suffix: str          # serialized tuples for this call
    function: str
    serialization: str

    @property
    def text(self) -> str:
        return self.prefix + self.suffix

    def token_estimate(self, tokens_per_char: float = 0.33) -> int:
        return int(len(self.text) * tokens_per_char) + 1


def build_prefix(function: str, user_prompt: str,
                 serialization: str = "xml") -> str:
    contract = _OUTPUT_CONTRACT[function]
    return (
        "You are a semantic SQL function executed inside an analytical "
        "database.  Follow the task exactly; answer only in the requested "
        "format, with no extra commentary.\n"
        f"## Task\n{user_prompt}\n"
        f"## Output contract\n{contract}\n"
        f"## Input serialization\nTuples arrive as {serialization} rows, "
        "each with an integer id.\n"
        "## Input tuples\n")


def build_metaprompt(function: str, user_prompt: str,
                     tuples: Sequence[dict],
                     serialization: str = "xml") -> MetaPrompt:
    if function not in _OUTPUT_CONTRACT:
        raise ValueError(f"unknown function kind {function!r}")
    prefix = build_prefix(function, user_prompt, serialization)
    suffix = serialize_batch(tuples, serialization) + "\n## Answer\n"
    return MetaPrompt(prefix=prefix, suffix=suffix, function=function,
                      serialization=serialization)
