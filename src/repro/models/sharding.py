"""Sharding specifications for params, caches and activations.

Layout (DESIGN.md §6):
  * mesh axes: ("data", "model") single pod; ("pod", "data", "model") for
    multi-pod.  Batch shards over DP = ("pod","data"); tensor-parallel dims
    over "model".
  * attention: Q heads sharded over model when divisible (configs pad the
    head count, see ModelConfig.padded_num_heads); KV heads sharded only if
    num_kv_heads % model_size == 0, else replicated (GQA with few KV heads).
  * FFN: d_ff column/row parallel.  MoE: experts replicated in count,
    per-expert d_ff tensor-parallel ("TP-within-expert") so dispatch stays
    local to the data shard.
  * vocab: embedding + head sharded over model (configs pad vocab).
  * decode KV caches: sequence dimension sharded over model
    (cross-chip flash-decode); batch over DP when it divides.
"""

from __future__ import annotations


import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .config import ATTN_KINDS, ModelConfig


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _dp(mesh: Mesh, size: int):
    """Batch axis spec: shard over DP only when it divides evenly."""
    axes = dp_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return axes if size % total == 0 else None


class MeshPolicy:
    """Activation-sharding policy bound to a mesh (see layers.NullPolicy)."""

    def __init__(self, mesh: Mesh, cfg: ModelConfig, batch: int):
        self.mesh = mesh
        self.cfg = cfg
        self.msize = mesh.shape["model"]
        self.dp = _dp(mesh, batch)
        self.dp_size = 1
        if self.dp is not None:
            for a in self.dp:
                self.dp_size *= mesh.shape[a]
        h_ok = cfg.padded_num_heads % self.msize == 0
        kv_ok = cfg.num_kv_heads % self.msize == 0
        di_ok = (cfg.d_inner % self.msize == 0) if cfg.d_inner else False
        specs = {
            "act": P(self.dp, None, None),
            "act_q": P(self.dp, None, "model" if h_ok else None, None),
            "act_q_decode": P(self.dp, None, None, None),
            "act_kv": P(self.dp, None, "model" if kv_ok else None, None),
            "act_ff": P(self.dp, None, "model"),
            "logits": P(self.dp, None, "model"),
            "moe_gathered": P(self.dp, None, None, None),
            "moe_hidden": P(self.dp, None, None, "model"),
            "act_inner": P(self.dp, None, "model" if di_ok else None),
            "act_inner2": P(self.dp, None, "model" if di_ok else None),
            "ssm_conv": P(self.dp, None, "model" if di_ok else None),
            "ssm_state": P(self.dp, "model" if di_ok else None),
            # decode KV cache: sequence over model (flash-decode layout);
            # when the batch cannot use the data axis (long_500k, B=1) the
            # sequence dim absorbs it too.
            "kv_cache": P(self.dp,
                          ("data", "model") if self.dp is None else "model",
                          None, None),
        }
        self.specs = specs

    def __call__(self, x, name: str):
        if (name in ("moe_gathered", "moe_hidden")
                and self.cfg.moe_gathered_spec == "auto"):
            return x                      # let GSPMD place dispatch tensors
        spec = self.specs.get(name)
        if spec is None:
            return x
        # ssm_state for mamba is (B, di, state): adjust rank
        if name == "ssm_state" and x.ndim == 3:
            spec = P(*spec, None)
        if name == "ssm_conv" and x.ndim == 3:
            pass
        if len(spec) != x.ndim:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


# --------------------------------------------------------------------------
# parameter PartitionSpecs (mirror init_params structure)
# --------------------------------------------------------------------------
def _layer_specs(cfg: ModelConfig, kind: str, msize: int, cross: bool):
    h_ok = cfg.padded_num_heads % msize == 0
    kv_ok = cfg.num_kv_heads % msize == 0
    di_ok = (cfg.d_inner % msize == 0) if cfg.d_inner else False
    H = "model" if h_ok else None
    KV = "model" if kv_ok else None
    DI = "model" if di_ok else None

    def norm_spec():
        if cfg.norm == "rmsnorm":
            return {"scale": P(None)}
        if cfg.norm == "layernorm":
            return {"scale": P(None), "bias": P(None)}
        return {}

    def attn_spec():
        s = {"wq": P(None, H, None), "wk": P(None, KV, None),
             "wv": P(None, KV, None), "wo": P(H, None, None)}
        if cfg.qkv_bias and not cross:
            s.update({"bq": P(H, None), "bk": P(KV, None), "bv": P(KV, None)})
        if cfg.qk_norm:
            s.update({"q_norm": P(None), "k_norm": P(None)})
        return s

    def xattn_spec():
        return {"wq": P(None, H, None), "wk": P(None, KV, None),
                "wv": P(None, KV, None), "wo": P(H, None, None)}

    def ffn_spec():
        s = {"w1": P(None, "model"), "w2": P("model", None)}
        if cfg.glu:
            s["w3"] = P(None, "model")
        return s

    p = {"ln1": norm_spec()}
    if kind in ATTN_KINDS:
        p["attn"] = attn_spec()
        if cross:
            p["ln_x"] = norm_spec()
            p["xattn"] = xattn_spec()
        p["ln2"] = norm_spec()
        if cfg.num_experts:
            moe = {"router": P(None, None),
                   "w1": P(None, None, "model"), "w2": P(None, "model", None)}
            if cfg.glu:
                moe["w3"] = P(None, None, "model")
            if cfg.num_shared_experts:
                moe["shared"] = ffn_spec()
            p["moe"] = moe
        else:
            p["ffn"] = ffn_spec()
    elif kind == "rec":
        p["rec"] = {"w_x": P(None, DI), "w_gate": P(None, DI),
                    "conv_w": P(None, DI), "conv_b": P(DI),
                    "rg_a": P(DI, None, None), "rg_a_b": P(DI),
                    "rg_x": P(DI, None, None), "rg_x_b": P(DI),
                    "lam": P(DI), "out_proj": P(DI, None)}
        p["ln2"] = norm_spec()
        p["ffn"] = ffn_spec()
    elif kind == "mamba":
        p["mamba"] = {"in_proj": P(None, DI), "conv_w": P(None, DI),
                      "conv_b": P(DI), "x_proj": P(DI, None),
                      "dt_proj": P(None, DI), "dt_bias": P(DI),
                      "A_log": P(DI, None), "D": P(DI),
                      "out_proj": P(DI, None)}
    return p


def _prepend(spec_tree, axis_spec=None):
    """Prepend a leading (stacked-repeats) dim to every PartitionSpec."""
    return jax.tree.map(lambda s: P(axis_spec, *s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def param_specs(cfg: ModelConfig, mesh: Mesh):
    msize = mesh.shape["model"]

    def norm_spec():
        if cfg.norm == "layernorm":
            return {"scale": P(None), "bias": P(None)}
        return {"scale": P(None)} if cfg.norm == "rmsnorm" else {}

    specs = {
        "embed": P("model", None),
        "final_norm": norm_spec(),
        "stages": [
            _prepend({f"b{j}": _layer_specs(cfg, kind, msize,
                                            cfg.is_encoder_decoder)
                      for j, kind in enumerate(pat)})
            for pat, reps in cfg.stages()
        ],
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, "model")
    if cfg.is_encoder_decoder:
        specs["encoder"] = {
            "stages": [
                _prepend({f"b{j}": _layer_specs(cfg, kind, msize, False)
                          for j, kind in enumerate(pat)})
                for pat, reps in cfg.encoder_stages()
            ],
            "final_norm": norm_spec(),
        }
    return specs


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int):
    """PartitionSpecs matching init_cache structure (stacked leading dim)."""
    dp = _dp(mesh, batch)
    msize = mesh.shape["model"]
    di_ok = (cfg.d_inner % msize == 0) if cfg.d_inner else False
    DI = "model" if di_ok else None
    seq_ax = ("data", "model") if dp is None else "model"

    def layer_cache_spec(kind):
        c = {}
        if kind in ATTN_KINDS:
            c["attn"] = {"k": P(None, dp, seq_ax, None, None),
                         "v": P(None, dp, seq_ax, None, None)}
            if cfg.kv_quant == "int8":
                c["attn"]["k_scale"] = P(None, dp, seq_ax, None, None)
                c["attn"]["v_scale"] = P(None, dp, seq_ax, None, None)
            if cfg.is_encoder_decoder:
                c["xattn"] = {"k": P(None, dp, None, None, None),
                              "v": P(None, dp, None, None, None)}
        elif kind == "rec":
            c["rec"] = {"conv": P(None, dp, None, DI),
                        "h": P(None, dp, DI)}
        elif kind == "mamba":
            c["mamba"] = {"conv": P(None, dp, None, DI),
                          "ssm": P(None, dp, DI, None)}
        return c

    return [
        {f"b{j}": layer_cache_spec(kind) for j, kind in enumerate(pat)}
        for pat, reps in cfg.stages()
    ]


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int, kind: str):
    """PartitionSpecs for the input batch dict."""
    dp = _dp(mesh, batch)
    specs = {"tokens": P(dp, None)}
    if kind == "train":
        specs["labels"] = P(dp, None)
    if cfg.is_encoder_decoder:
        specs["frames"] = P(dp, None, None)
    if cfg.frontend == "vision" and kind in ("train", "prefill"):
        specs["patches"] = P(dp, None, None)
    return specs


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
