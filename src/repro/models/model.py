"""Stack assembly: init / train forward / prefill / decode for every arch.

Layers are organised into *stages* (see config.stages()): parameters of a
stage are stacked along a leading ``repeats`` axis and executed with
``lax.scan`` so compile time is O(#stages), not O(#layers).  The decode and
prefill paths thread a cache pytree with the same stage structure through
the scan.

Batch dict convention (all optional keys absent when unused):
  tokens   (B, S_txt) int32          text tokens
  labels   (B, S_txt) int32          next-token labels (-1 = ignore)
  frames   (B, enc_seq, d) compute   audio-frontend stub embeddings (whisper)
  patches  (B, P, d) compute         vision-frontend stub embeddings (phi3v)
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from . import layers as L
from .config import ATTN_KINDS, ModelConfig

F32 = jnp.float32


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------
def _init_layer(cfg: ModelConfig, kind: str, key, cross: bool):
    ks = jax.random.split(key, 6)
    p = {"ln1": L.init_norm(cfg, ks[0], cfg.d_model)}
    if kind in ATTN_KINDS:
        p["attn"] = L.init_attention(cfg, ks[1])
        if cross:
            p["ln_x"] = L.init_norm(cfg, ks[4], cfg.d_model)
            p["xattn"] = L.init_attention(cfg, ks[5], cross=True)
        p["ln2"] = L.init_norm(cfg, ks[2], cfg.d_model)
        if cfg.num_experts:
            p["moe"] = L.init_moe(cfg, ks[3])
        else:
            p["ffn"] = L.init_ffn(cfg, ks[3])
    elif kind == "rec":
        p["rec"] = L.init_rglru(cfg, ks[1])
        p["ln2"] = L.init_norm(cfg, ks[2], cfg.d_model)
        p["ffn"] = L.init_ffn(cfg, ks[3])
    elif kind == "mamba":
        p["mamba"] = L.init_mamba(cfg, ks[1])
    else:
        raise ValueError(kind)
    return p


def _init_stage(cfg: ModelConfig, pattern, repeats: int, key, cross: bool):
    reps = []
    for r in range(repeats):
        key, sub = jax.random.split(key)
        blocks = {}
        for j, kind in enumerate(pattern):
            sub, k2 = jax.random.split(sub)
            blocks[f"b{j}"] = _init_layer(cfg, kind, k2, cross)
        reps.append(blocks)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *reps)


def init_params(cfg: ModelConfig, key):
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    Vp, d = cfg.padded_vocab, cfg.d_model
    params = {
        "embed": (jax.random.normal(ks[0], (Vp, d)) * d ** -0.5).astype(dt),
        "final_norm": L.init_norm(cfg, ks[1], d),
        "stages": [
            _init_stage(cfg, pat, reps, jax.random.fold_in(ks[2], i),
                        cross=cfg.is_encoder_decoder)
            for i, (pat, reps) in enumerate(cfg.stages())
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(ks[3], (d, Vp)) * d ** -0.5).astype(dt)
    if cfg.is_encoder_decoder:
        params["encoder"] = {
            "stages": [
                _init_stage(cfg, pat, reps, jax.random.fold_in(ks[4], i),
                            cross=False)
                for i, (pat, reps) in enumerate(cfg.encoder_stages())
            ],
            "final_norm": L.init_norm(cfg, ks[5], d),
        }
    return params


# --------------------------------------------------------------------------
# single-layer application (shared by train / prefill / decode)
# --------------------------------------------------------------------------
def apply_layer(cfg: ModelConfig, kind: str, p, x, *, mode: str, positions,
                pos=None, cache=None, policy=L.NULL_POLICY, enc_out=None,
                causal=True, cache_len=0):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), F32)
    new_cache = {}
    if kind in ATTN_KINDS:
        h = L.norm_apply(cfg, p.get("ln1", {}), x)
        if mode == "decode":
            y, new_attn = L.self_attention_decode(
                cfg, p["attn"], h, kind, cache["attn"], pos, policy)
        elif mode == "extend":
            y, new_attn = L.self_attention_extend(
                cfg, p["attn"], h, kind, cache["attn"], pos, policy)
        else:
            y, (k, v) = L.self_attention_train(
                cfg, p["attn"], h, kind, positions, policy, causal=causal)
            if mode == "prefill":
                S = k.shape[1]
                pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
                if cfg.kv_quant == "int8":
                    kq, ks = L.quantize_kv(k)
                    vq, vs = L.quantize_kv(v)
                    new_attn = {
                        "k": policy(jnp.pad(kq, pad), "kv_cache"),
                        "v": policy(jnp.pad(vq, pad), "kv_cache"),
                        "k_scale": policy(jnp.pad(ks, pad), "kv_cache"),
                        "v_scale": policy(jnp.pad(vs, pad), "kv_cache"),
                    }
                else:
                    new_attn = {"k": policy(jnp.pad(k, pad), "kv_cache"),
                                "v": policy(jnp.pad(v, pad), "kv_cache")}
        x = x + y
        if "xattn" in p:
            hx = L.norm_apply(cfg, p.get("ln_x", {}), x)
            if mode in ("decode", "extend"):
                ek, ev = cache["xattn"]["k"], cache["xattn"]["v"]
            else:
                ek, ev = L.encode_cross_kv(cfg, p["xattn"], enc_out, policy)
            x = x + L.cross_attention(cfg, p["xattn"], hx, ek, ev, policy)
            if mode in ("prefill", "decode", "extend"):
                new_cache["xattn"] = {"k": ek, "v": ev}
        h2 = L.norm_apply(cfg, p.get("ln2", {}), x)
        if cfg.num_experts:
            y2, aux = L.moe_apply(cfg, p["moe"], h2, policy)
        else:
            y2 = L.ffn_apply(cfg, p["ffn"], h2, policy)
        x = x + y2
        if mode in ("prefill", "decode", "extend"):
            new_cache["attn"] = new_attn
    elif kind == "rec":
        # the decode path handles any sequence length (conv + scan carry a
        # state), so prefill == decode-with-zero-state, extend == decode.
        h = L.norm_apply(cfg, p.get("ln1", {}), x)
        if mode == "train":
            y = L.rglru_apply_train(cfg, p["rec"], h, policy)
        else:
            c = (cache["rec"] if mode in ("decode", "extend")
                 else L.init_rglru_cache(cfg, x.shape[0],
                                         jnp.dtype(cfg.compute_dtype)))
            y, new_cache["rec"] = L.rglru_apply_decode(cfg, p["rec"], h, c,
                                                       policy)
        x = x + y
        x = x + L.ffn_apply(cfg, p["ffn"], L.norm_apply(cfg, p.get("ln2", {}), x),
                            policy)
    elif kind == "mamba":
        h = L.norm_apply(cfg, p.get("ln1", {}), x)
        if mode == "train":
            y = L.mamba_apply_train(cfg, p["mamba"], h, policy)
        else:
            c = (cache["mamba"] if mode in ("decode", "extend")
                 else L.init_mamba_cache(cfg, x.shape[0],
                                         jnp.dtype(cfg.compute_dtype)))
            y, new_cache["mamba"] = L.mamba_apply_decode(cfg, p["mamba"], h,
                                                         c, policy)
        x = x + y
    else:
        raise ValueError(kind)
    return policy(x, "act"), new_cache, aux


# --------------------------------------------------------------------------
# stage execution (scan over stacked repeats)
# --------------------------------------------------------------------------
def _run_stages(cfg: ModelConfig, stages_params, pattern_list, x, *, mode,
                positions, pos=None, caches=None, policy=L.NULL_POLICY,
                enc_out=None, causal=True, cache_len=0):
    """pattern_list: list of (pattern, repeats) matching stages_params."""
    new_caches = []
    total_aux = jnp.zeros((), F32)

    for si, ((pattern, repeats), sp) in enumerate(
            zip(pattern_list, stages_params)):
        stage_cache = None if caches is None else caches[si]

        def body(carry, inp, _pattern=pattern):
            xc, aux_c = carry
            lp, lc = inp
            ncs = {}
            for j, kind in enumerate(_pattern):
                xc, nc, aux = apply_layer(
                    cfg, kind, lp[f"b{j}"], xc, mode=mode,
                    positions=positions, pos=pos,
                    cache=None if lc is None else lc[f"b{j}"],
                    policy=policy, enc_out=enc_out, causal=causal,
                    cache_len=cache_len)
                ncs[f"b{j}"] = nc
                aux_c = aux_c + aux
            return (xc, aux_c), ncs

        if cfg.remat:
            pol = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                   if cfg.remat_policy == "dots" else None)
            body = jax.checkpoint(body, policy=pol)

        if cfg.unroll_layers:
            # python loop over repeats (cost-probe lowering; see dryrun.py)
            carry, caches_out = (x, total_aux), []
            for r in range(repeats):
                lp = jax.tree.map(lambda a: a[r], sp)
                lc = (None if stage_cache is None
                      else jax.tree.map(lambda a: a[r], stage_cache))
                carry, nc = body(carry, (lp, lc))
                caches_out.append(nc)
            (x, total_aux) = carry
            stage_new_cache = (jax.tree.map(lambda *xs: jnp.stack(xs),
                                            *caches_out)
                               if caches_out and caches_out[0] else None)
        elif stage_cache is None:
            (x, total_aux), stage_new_cache = jax.lax.scan(
                lambda c, p_: body(c, (p_, None)), (x, total_aux), sp)
        else:
            (x, total_aux), stage_new_cache = jax.lax.scan(
                body, (x, total_aux), (sp, stage_cache))
        new_caches.append(stage_new_cache)
    return x, new_caches, total_aux


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------
def _embed_tokens(cfg: ModelConfig, params, tokens, policy):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return policy(x.astype(cfg.compute_dtype), "act")


def _logits(cfg: ModelConfig, params, x, policy):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return policy(logits.astype(F32), "logits")


def _assemble_input(cfg: ModelConfig, params, batch, policy):
    """Token embeddings (+ modality prefix).  Returns (x, positions)."""
    x = _embed_tokens(cfg, params, batch["tokens"], policy)
    if cfg.frontend == "vision" and "patches" in batch:
        x = jnp.concatenate(
            [batch["patches"].astype(x.dtype), x], axis=1)
        x = policy(x, "act")
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def _run_encoder(cfg: ModelConfig, params, frames, policy):
    x = frames.astype(cfg.compute_dtype)
    x = x + L.sinusoid_pos(x.shape[1], cfg.d_model, dtype=x.dtype)
    x = policy(x, "act")
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    enc = params["encoder"]
    x, _, _ = _run_stages(cfg, enc["stages"], list(cfg.encoder_stages()), x,
                          mode="train", positions=positions, policy=policy,
                          causal=False)
    return L.norm_apply(cfg, enc.get("final_norm", {}), x)


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------
def forward_train(cfg: ModelConfig, params, batch, policy=L.NULL_POLICY):
    """Full-sequence teacher-forced forward. Returns (logits, aux)."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _run_encoder(cfg, params, batch["frames"], policy)
    x, positions = _assemble_input(cfg, params, batch, policy)
    x, _, aux = _run_stages(cfg, params["stages"], list(cfg.stages()), x,
                            mode="train", positions=positions, policy=policy,
                            enc_out=enc_out)
    x = L.norm_apply(cfg, params.get("final_norm", {}), x)
    return _logits(cfg, params, x, policy), aux


def loss_fn(cfg: ModelConfig, params, batch, policy=L.NULL_POLICY):
    logits, aux = forward_train(cfg, params, batch, policy)
    labels = batch["labels"]
    if cfg.frontend == "vision" and "patches" in batch:
        P = batch["patches"].shape[1]
        logits = logits[:, P:]
    if cfg.padded_vocab != cfg.vocab_size:
        mask_v = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask_v, logits, -jnp.inf)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(F32)
    nll = jnp.sum((lse - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    total = nll + cfg.router_aux_weight * aux
    return total, {"loss": nll, "aux_loss": aux, "tokens": mask.sum()}


def init_cache(cfg: ModelConfig, B: int, cache_len: int):
    """Zero cache pytree matching the stage structure."""
    dt = jnp.dtype(cfg.compute_dtype)
    hd, KH = cfg.resolved_head_dim, cfg.padded_num_kv_heads

    def layer_cache(kind):
        c = {}
        if kind in ATTN_KINDS:
            if cfg.kv_quant == "int8":
                c["attn"] = {
                    "k": jnp.zeros((B, cache_len, KH, hd), jnp.int8),
                    "v": jnp.zeros((B, cache_len, KH, hd), jnp.int8),
                    "k_scale": jnp.zeros((B, cache_len, KH, 1),
                                         jnp.float32),
                    "v_scale": jnp.zeros((B, cache_len, KH, 1),
                                         jnp.float32),
                }
            else:
                c["attn"] = {"k": jnp.zeros((B, cache_len, KH, hd), dt),
                             "v": jnp.zeros((B, cache_len, KH, hd), dt)}
            if cfg.is_encoder_decoder:
                c["xattn"] = {"k": jnp.zeros((B, cfg.encoder_seq, KH, hd), dt),
                              "v": jnp.zeros((B, cfg.encoder_seq, KH, hd), dt)}
        elif kind == "rec":
            c["rec"] = L.init_rglru_cache(cfg, B, dt)
        elif kind == "mamba":
            c["mamba"] = L.init_mamba_cache(cfg, B, dt)
        return c

    caches = []
    for pattern, repeats in cfg.stages():
        one = {f"b{j}": layer_cache(k) for j, k in enumerate(pattern)}
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (repeats, *a.shape)).copy(), one))
    return caches


def prefill(cfg: ModelConfig, params, batch, cache_len: int,
            policy=L.NULL_POLICY):
    """Process the prompt; returns (last-token logits, cache, next_pos)."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _run_encoder(cfg, params, batch["frames"], policy)
    x, positions = _assemble_input(cfg, params, batch, policy)
    x, caches, _ = _run_stages(cfg, params["stages"], list(cfg.stages()), x,
                               mode="prefill", positions=positions,
                               policy=policy, enc_out=enc_out,
                               cache_len=cache_len)
    x = L.norm_apply(cfg, params.get("final_norm", {}), x)
    logits = _logits(cfg, params, x[:, -1:], policy)
    return logits, caches, x.shape[1]


def prefill_chunk(cfg: ModelConfig, params, tokens, cache, off,
                  policy=L.NULL_POLICY):
    """Chunked (Sarathi-style) prefill: extend the cache with C prompt
    tokens.  tokens: (B, C) int32; off: scalar or (B,) tokens already
    cached.  Returns (logits (B,C,V), new_cache).  Exact for every arch —
    recurrent state and conv state carry across chunks."""
    x = _embed_tokens(cfg, params, tokens, policy)
    x, caches, _ = _run_stages(cfg, params["stages"], list(cfg.stages()), x,
                               mode="extend", positions=None, pos=off,
                               caches=cache, policy=policy)
    x = L.norm_apply(cfg, params.get("final_norm", {}), x)
    return _logits(cfg, params, x, policy), caches


def encode_for_cache(cfg: ModelConfig, params, frames, B, cache_len,
                     policy=L.NULL_POLICY):
    """Enc-dec: run the encoder and produce a fresh cache pre-filled with
    per-layer cross-attention K/V (decoder cache empty, pos=0)."""
    cache = init_cache(cfg, B, cache_len)
    enc_out = _run_encoder(cfg, params, frames, policy)
    new_caches = []
    for si, ((pattern, repeats), sp) in enumerate(
            zip(list(cfg.stages()), params["stages"])):
        def body(carry, inp, _pattern=pattern):
            lp, lc = inp
            for j, kind in enumerate(_pattern):
                if kind in ATTN_KINDS:
                    ek, ev = L.encode_cross_kv(cfg, lp[f"b{j}"]["xattn"],
                                               enc_out, policy)
                    lc[f"b{j}"]["xattn"] = {"k": ek, "v": ev}
            return carry, lc
        _, nc = jax.lax.scan(body, 0, (sp, cache[si]))
        new_caches.append(nc)
    return new_caches


def decode_step(cfg: ModelConfig, params, tokens, cache, pos,
                policy=L.NULL_POLICY):
    """One decode step.  tokens: (B, 1) int32; pos: scalar int32 position of
    this token.  Returns (logits (B,1,V), new_cache)."""
    x = _embed_tokens(cfg, params, tokens, policy)
    positions = None  # decode positions derived from ``pos`` inside layers
    x, caches, _ = _run_stages(cfg, params["stages"], list(cfg.stages()), x,
                               mode="decode", positions=positions, pos=pos,
                               caches=cache, policy=policy)
    x = L.norm_apply(cfg, params.get("final_norm", {}), x)
    return _logits(cfg, params, x, policy), caches
