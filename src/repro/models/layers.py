"""Layer primitives for the FlockJAX model zoo (pure JAX reference path).

Every primitive comes as an ``init_*`` (parameter pytree) + ``*_apply`` pair
of pure functions.  Attention uses a chunked online-softmax formulation
(flash-attention structure) so peak memory is O(Sq * block_k) — this is also
the oracle the Pallas kernels are validated against.

Sharding is injected through a ``Policy`` object (see sharding.py); the
default ``NULL_POLICY`` makes every constraint a no-op so the same code runs
un-meshed in unit tests.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig

F32 = jnp.float32


# --------------------------------------------------------------------------
# sharding policy indirection
# --------------------------------------------------------------------------
class NullPolicy:
    """No-op activation-sharding policy (single-device tests)."""

    dp_size = 1     # data-parallel world size (MoE decode grouping hint)

    def __call__(self, x, name: str):
        return x


NULL_POLICY = NullPolicy()


# --------------------------------------------------------------------------
# normalisation
# --------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, key, d: int):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((d,), F32)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), F32), "bias": jnp.zeros((d,), F32)}
    return {}  # nonparam_ln (OLMo)


def norm_apply(cfg: ModelConfig, p, x):
    dt = x.dtype
    x = x.astype(F32)
    if cfg.norm == "rmsnorm":
        x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)
        x = x * p["scale"]
    else:
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-5)
        if p:
            x = x * p["scale"] + p["bias"]
    return x.astype(dt)


def rms_head_norm(scale, x):
    """Per-head RMS norm (gemma3 qk-norm); x: (..., hd)."""
    dt = x.dtype
    x = x.astype(F32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)
    return (x * scale).astype(dt)


# --------------------------------------------------------------------------
# rotary / sinusoidal positions
# --------------------------------------------------------------------------
def rope_apply(x, positions, theta: float):
    """x: (B, S, H, hd), positions: (B, S) or (S,) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(F32) * freqs          # (B,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_pos(seq: int, d: int, offset=0, dtype=jnp.bfloat16):
    pos = jnp.arange(seq, dtype=F32) + offset
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=F32) * (math.log(10_000.0) / max(half - 1, 1)))
    ang = pos[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# --------------------------------------------------------------------------
# chunked (flash-style) attention — the jnp oracle
# --------------------------------------------------------------------------
def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      q_offset=0, kv_valid_len=None, block_k: int = 512,
                      unroll: bool = False, scale: float | None = None):
    """Online-softmax attention, scanning KV blocks.

    q: (B, Sq, H, hd);  k, v: (B, Sk, KH, hd) with H % KH == 0.
    GQA is computed with grouped einsums (q reshaped to (KH, G) heads) so
    K/V are never materialised per-q-head, and K/V stay in their storage
    dtype (f32 accumulation via preferred_element_type).
    ``q_offset``: absolute position of q[0] (prefill continuation).
    ``window`` > 0: sliding-window (local) mask  q_pos - k_pos < window.
    ``kv_valid_len``: mask out k positions >= this (padded caches).
    Returns (B, Sq, H, hd) in q.dtype.
    """
    B, Sq, H, hd = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else hd ** -0.5
    bk = min(block_k, Sk)
    nblk = -(-Sk // bk)
    pad = nblk * bk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, bk, KH, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, bk, KH, hd).transpose(1, 0, 2, 3, 4)

    # q_offset may be scalar or per-row (B,) (continuous batching slots)
    q_off = jnp.asarray(q_offset)
    if q_off.ndim == 0:
        q_off = jnp.broadcast_to(q_off, (B,))
    q_pos = q_off[:, None] + jnp.arange(Sq)[None, :]           # (B, Sq)
    valid_limit = Sk if kv_valid_len is None else kv_valid_len

    qg = (q.astype(F32) * scale).reshape(B, Sq, KH, G, hd)

    def block(carry, inp):
        m, l, acc = carry                       # (B,KH,G,Sq), ..., (..,hd)
        idx, kblk, vblk = inp                   # (B,bk,KH,hd) storage dtype
        s = jnp.einsum("bqkgh,btkh->bkgqt", qg, kblk,
                       preferred_element_type=F32)
        k_pos = idx * bk + jnp.arange(bk)
        mask = jnp.broadcast_to(k_pos[None, None, :] < valid_limit,
                                (B, Sq, bk))
        if causal:
            mask = mask & (k_pos[None, None, :] <= q_pos[:, :, None])
        if window:
            mask = mask & (q_pos[:, :, None] - k_pos[None, None, :] < window)
        neg = jnp.asarray(-1e30, F32)
        s = s + jnp.where(mask[:, None, None], 0.0, neg)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqt,btkh->bkgqh", p, vblk,
                        preferred_element_type=F32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((B, KH, G, Sq), -1e30, F32)
    l0 = jnp.zeros((B, KH, G, Sq), F32)
    a0 = jnp.zeros((B, KH, G, Sq, hd), F32)
    if unroll:
        carry = (m0, l0, a0)
        for i in range(nblk):
            carry, _ = block(carry, (jnp.int32(i), kb[i], vb[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            block, (m0, l0, a0), (jnp.arange(nblk), kb, vb))
    out = acc / jnp.maximum(l, 1e-37)[..., None]     # (B,KH,G,Sq,hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def blocked_attention(q, k, v, *, causal: bool, window: int = 0,
                      block_q: int = 512, block_k: int = 512,
                      scale: float | None = None, unroll: bool = False):
    """Static block-pair attention: enumerate only (q-block, kv-block)
    pairs that the causal/window mask can reach, scan over that list, and
    scatter finished q-blocks to the output.

    vs ``chunked_attention`` (which visits all Sq*Sk tiles and masks), this
    does ~2x less matmul work for causal and ~S/W less for sliding-window —
    the jnp-path analogue of the Pallas kernel's pl.when block skipping.
    Requires uniform q_offset=0 (training/prefill-from-scratch shapes).
    """
    B, Sq, H, hd = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else hd ** -0.5
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq, nk = -(-Sq // bq), -(-Sk // bk)
    pad_q, pad_k = nq * bq - Sq, nk * bk - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    # static pair list (row-major in qi so each q block's pairs are
    # contiguous -> single online-softmax carry, flushed on qi change)
    pairs = []
    for qi in range(nq):
        q_lo, q_hi = qi * bq, qi * bq + bq - 1
        for ki in range(nk):
            k_lo, k_hi = ki * bk, ki * bk + bk - 1
            if causal and k_lo > q_hi:
                continue
            if window and q_lo - k_hi >= window:
                continue
            pairs.append((qi, ki))
    qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)
    # flag marking the last pair of each q block (flush point)
    last = jnp.asarray(
        [i + 1 == len(pairs) or pairs[i + 1][0] != pairs[i][0]
         for i in range(len(pairs))], bool)

    qb = q.reshape(B, nq, bq, KH, G, hd).astype(F32) * scale
    kb = k.reshape(B, nk, bk, KH, hd)
    vb = v.reshape(B, nk, bk, KH, hd)
    out0 = jnp.zeros((B, nq, bq, KH, G, hd), F32)

    def step(carry, inp):
        m, l, acc, out = carry
        qi, ki, flush = inp
        qt = jax.lax.dynamic_index_in_dim(qb, qi, 1, keepdims=False)
        kt = jax.lax.dynamic_index_in_dim(kb, ki, 1, keepdims=False)
        vt = jax.lax.dynamic_index_in_dim(vb, ki, 1, keepdims=False)
        s = jnp.einsum("bqkgh,btkh->bkgqt", qt, kt,
                       preferred_element_type=F32)
        q_pos = qi * bq + jnp.arange(bq)
        k_pos = ki * bk + jnp.arange(bk)
        mask = k_pos[None, :] < Sk
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        s = s + jnp.where(mask[None, None, None], 0.0, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqt,btkh->bkgqh", p, vt, preferred_element_type=F32)
        o_blk = (acc / jnp.maximum(l, 1e-37)[..., None]).transpose(
            0, 3, 1, 2, 4)                                     # (B,bq,KH,G,hd)
        out = jax.lax.cond(
            flush,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, o_blk, qi, 1),
            lambda o: o, out)
        # reset accumulators when flushing (next pair starts a new q block)
        def rst(x, fill):
            return jnp.where(flush, jnp.full_like(x, fill), x)
        return (rst(m_new, -1e30), rst(l, 0.0), rst(acc, 0.0), out), None

    m0 = jnp.full((B, KH, G, bq), -1e30, F32)
    l0 = jnp.zeros((B, KH, G, bq), F32)
    a0 = jnp.zeros((B, KH, G, bq, hd), F32)
    if unroll:     # cost-probe lowering: python loop so flops are counted
        carry = (m0, l0, a0, out0)
        for i in range(len(pairs)):
            carry, _ = step(carry, (qi_arr[i], ki_arr[i], last[i]))
        out = carry[3]
    else:
        (_, _, _, out), _ = jax.lax.scan(step, (m0, l0, a0, out0),
                                         (qi_arr, ki_arr, last))
    out = out.reshape(B, nq * bq, H, hd)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     scale: float | None = None):
    """Single-step attention over a (possibly padded) cache.

    q: (B, 1, H, hd); caches: (B, Smax, KH, hd); pos: scalar int32 = the
    current token's absolute position (its K/V already written).  Grouped
    einsums keep the cache unexpanded and in storage dtype; the softmax
    reduction over a sequence-sharded cache lowers to tiny all-reduces
    (cross-chip flash-decode).
    """
    B, _, H, hd = q.shape
    Smax, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = scale if scale is not None else hd ** -0.5
    qg = (q.astype(F32) * scale).reshape(B, KH, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                   preferred_element_type=F32)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))       # scalar or (B,)
    k_pos = jnp.arange(Smax)
    mask = k_pos[None, :] <= pos_b[:, None]
    if window:
        mask = mask & (pos_b[:, None] - k_pos[None, :] < window)
    s = s + jnp.where(mask[:, None, None], 0.0, jnp.asarray(-1e30, F32))
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskh->bkgh", p / jnp.maximum(l, 1e-37), v_cache,
                     preferred_element_type=F32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# attention block (proj + rope + residual-ready output)
# --------------------------------------------------------------------------
def init_attention(cfg: ModelConfig, key, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KH = cfg.padded_num_heads, cfg.padded_num_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    sd = d ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d, H, hd)) * sd).astype(dt),
        "wk": (jax.random.normal(k2, (d, KH, hd)) * sd).astype(dt),
        "wv": (jax.random.normal(k3, (d, KH, hd)) * sd).astype(dt),
        "wo": (jax.random.normal(k4, (H, hd, d)) * (H * hd) ** -0.5).astype(dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KH, hd), dt)
        p["bv"] = jnp.zeros((KH, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), F32)
        p["k_norm"] = jnp.ones((hd,), F32)
    return p


def attn_qkv(cfg: ModelConfig, p, x, positions, kind: str, policy,
             rope: bool = True):
    """Project to q, k, v (+bias, qk-norm, rope)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if rope:
        theta = cfg.rope_theta if kind in ("attn", "global") else cfg.theta_local
        q = rope_apply(q, positions, theta)
        k = rope_apply(k, positions, theta)
    q = policy(q, "act_q")
    k = policy(k, "act_kv")
    v = policy(v, "act_kv")
    return q, k, v


def attn_out(p, o, policy):
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return policy(y, "act")


def self_attention_train(cfg: ModelConfig, p, x, kind: str, positions,
                         policy, causal: bool = True):
    q, k, v = attn_qkv(cfg, p, x, positions, kind, policy)
    window = cfg.window_size if kind in ("local", "swa") else 0
    if cfg.use_pallas:
        from repro.kernels.flash_attention.ops import flash_attention
        o = flash_attention(q, k, v, causal=causal, window=window,
                            interpret=jax.default_backend() != "tpu")
    elif cfg.attn_impl == "blocked":
        o = blocked_attention(q, k, v, causal=causal, window=window,
                              block_q=cfg.attn_block_k,
                              block_k=cfg.attn_block_k,
                              unroll=cfg.unroll_inner)
    else:
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              block_k=cfg.attn_block_k,
                              unroll=cfg.unroll_inner)
    o = policy(o, "act_q")
    return attn_out(p, o, policy), (k, v)


def quantize_kv(x):
    """Symmetric int8 per-(token, head) quantization:
    x (B, S, KH, hd) -> (int8 values, f32 scales (B, S, KH, 1))."""
    scale = jnp.max(jnp.abs(x.astype(F32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(
        jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(F32) * scale).astype(dtype)


def self_attention_decode(cfg: ModelConfig, p, x, kind: str, cache, pos,
                          policy):
    """x: (B, 1, d). cache: {"k","v"}: (B, Smax, KH, hd). Returns (y, cache).

    The cache write uses a masked ``where`` along the (sharded) sequence dim
    instead of dynamic_update_slice: a runtime-dynamic DUS on a sharded axis
    makes GSPMD all-gather the whole cache (verified on the 16x16 mesh),
    while the masked write stays shard-local.

    kv_quant="int8" stores the cache as int8 with per-(token, head) scales:
    ~2x less decode HBM traffic and cache footprint — what lets
    qwen1.5-32b's 5.5TB bf16 decode_32k cache fit one pod (§Perf).
    """
    B = x.shape[0]
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))       # scalar or (B,)
    positions = pos_b[:, None].astype(jnp.int32)
    q, k, v = attn_qkv(cfg, p, x, positions, kind, policy)
    sel = (jnp.arange(cache["k"].shape[1])[None, :]
           == pos_b[:, None])[:, :, None, None]
    if cfg.kv_quant == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        ck = policy(jnp.where(sel, kq, cache["k"]), "kv_cache")
        cv = policy(jnp.where(sel, vq, cache["v"]), "kv_cache")
        cks = policy(jnp.where(sel, ks, cache["k_scale"]), "kv_cache")
        cvs = policy(jnp.where(sel, vs, cache["v_scale"]), "kv_cache")
        k_use = dequantize_kv(ck, cks, cfg.compute_dtype)
        v_use = dequantize_kv(cv, cvs, cfg.compute_dtype)
        new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
    else:
        ck = policy(jnp.where(sel, k.astype(cache["k"].dtype), cache["k"]),
                    "kv_cache")
        cv = policy(jnp.where(sel, v.astype(cache["v"].dtype), cache["v"]),
                    "kv_cache")
        k_use, v_use = ck, cv
        new_cache = {"k": ck, "v": cv}
    window = cfg.window_size if kind in ("local", "swa") else 0
    q = policy(q, "act_q_decode")
    if cfg.use_pallas:
        from repro.kernels.decode_attention.ops import \
            decode_attention as decode_attention_pallas
        o = decode_attention_pallas(q, k_use, v_use, pos_b, window=window,
                                    interpret=jax.default_backend() != "tpu")
    else:
        o = decode_attention(q, k_use, v_use, pos, window=window)
    return attn_out(p, o, policy), new_cache


def self_attention_extend(cfg: ModelConfig, p, x, kind: str, cache, off,
                          policy):
    """Chunked-prefill (Sarathi-style): process a chunk of C prompt tokens
    against an existing cache.  x: (B, C, d); off: scalar or (B,) — number
    of tokens already cached per row.  Exact for every arch (no padding).
    """
    B, C, _ = x.shape
    off_b = jnp.broadcast_to(jnp.asarray(off), (B,))
    positions = off_b[:, None] + jnp.arange(C)[None, :]
    q, k, v = attn_qkv(cfg, p, x, positions, kind, policy)
    # write the chunk into the cache at [off, off+C) (gather-style select,
    # shard-local on a sequence-sharded cache)
    Smax = cache["k"].shape[1]
    idx = jnp.arange(Smax)[None, :] - off_b[:, None]           # (B, Smax)
    sel = (idx >= 0) & (idx < C)
    safe = jnp.clip(idx, 0, C - 1)
    def put(cache_arr, chunk):
        gathered = jnp.take_along_axis(
            chunk.astype(cache_arr.dtype), safe[:, :, None, None], axis=1)
        return jnp.where(sel[:, :, None, None], gathered, cache_arr)
    ck = policy(put(cache["k"], k), "kv_cache")
    cv = policy(put(cache["v"], v), "kv_cache")
    window = cfg.window_size if kind in ("local", "swa") else 0
    q = policy(q, "act_q")
    o = chunked_attention(q, ck, cv, causal=True, window=window,
                          q_offset=off_b, block_k=cfg.attn_block_k,
                          unroll=cfg.unroll_inner)
    o = policy(o, "act_q")
    return attn_out(p, o, policy), {"k": ck, "v": cv}


def cross_attention(cfg: ModelConfig, p, x, enc_k, enc_v, policy):
    """Decoder cross-attention over precomputed encoder K/V (no rope)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = policy(q, "act_q")
    o = chunked_attention(q, enc_k, enc_v, causal=False,
                          block_k=cfg.attn_block_k, unroll=cfg.unroll_inner)
    o = policy(o, "act_q")
    return attn_out(p, o, policy)


def encode_cross_kv(cfg: ModelConfig, p, enc_out, policy):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return policy(k, "act_kv"), policy(v, "act_kv")


# --------------------------------------------------------------------------
# dense FFN
# --------------------------------------------------------------------------
def init_ffn(cfg: ModelConfig, key, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w1": (jax.random.normal(k1, (d, f)) * d ** -0.5).astype(dt),
         "w2": (jax.random.normal(k2, (f, d)) * f ** -0.5).astype(dt)}
    if cfg.glu:
        p["w3"] = (jax.random.normal(k3, (d, f)) * d ** -0.5).astype(dt)
    return p


def _act(cfg: ModelConfig, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def ffn_apply(cfg: ModelConfig, p, x, policy):
    h = _act(cfg, jnp.einsum("bsd,df->bsf", x, p["w1"]))
    if cfg.glu:
        h = h * jnp.einsum("bsd,df->bsf", x, p["w3"])
    h = policy(h, "act_ff")
    y = jnp.einsum("bsf,fd->bsd", h, p["w2"])
    return policy(y, "act")


# --------------------------------------------------------------------------
# Mixture-of-Experts FFN (top-k, shared experts, capacity-dropped dispatch)
# --------------------------------------------------------------------------
def init_moe(cfg: ModelConfig, key):
    d, E, fe = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * d ** -0.5).astype(F32),
        "w1": (jax.random.normal(ks[1], (E, d, fe)) * d ** -0.5).astype(dt),
        "w2": (jax.random.normal(ks[2], (E, fe, d)) * fe ** -0.5).astype(dt),
    }
    if cfg.glu:
        p["w3"] = (jax.random.normal(ks[3], (E, d, fe)) * d ** -0.5).astype(dt)
    if cfg.num_shared_experts:
        shared_cfg = cfg.replace(d_ff=cfg.moe_d_ff * cfg.num_shared_experts)
        p["shared"] = init_ffn(shared_cfg, ks[4], shared_cfg.d_ff)
    return p


def moe_apply(cfg: ModelConfig, p, x, policy):
    """Group-local capacity dispatch — see DESIGN.md §6.

    x: (B, S, d).  Dispatch groups are batch rows for full sequences, so
    every gather/scatter stays local to the data shard; for decode (S == 1)
    batch rows are regrouped into ``policy.dp_size`` groups so the capacity
    padding is amortised across the per-shard batch instead of paying
    E*C slots per single token.  Expert FFNs are tensor-parallel over
    ``model`` (experts replicated in count, sharded in d_ff).
    """
    B0, S0, d = x.shape
    orig_shape = x.shape
    if S0 == 1 and B0 > 1:
        G = min(B0, max(policy.dp_size, 1))
        x = x.reshape(G, B0 // G, d)
    B, S, _ = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = cfg.moe_capacity(S)

    logits = jnp.einsum("bsd,de->bse", x.astype(F32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                 # (B,S,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- slotting: rank of each (token, choice) within its expert ----
    ef = eidx.reshape(B, S * K)                          # (B, T)
    order = jnp.argsort(ef, axis=-1, stable=True)        # (B, T)
    sorted_e = jnp.take_along_axis(ef, order, axis=-1)
    counts = jax.nn.one_hot(ef, E, dtype=jnp.int32).sum(axis=1)     # (B, E)
    starts = jnp.cumsum(counts, axis=-1) - counts                   # (B, E)
    ranks = jnp.arange(S * K)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=-1)                                  # (B, T)
    keep = ranks < C
    dest = jnp.where(keep, sorted_e * C + ranks, E * C)  # OOB sentinel slot
    src_tok = order // K                                 # token of assignment
    wts = jnp.take_along_axis(gate.reshape(B, S * K), order, axis=-1)

    bidx = jnp.arange(B)[:, None]
    # token-index table (B, E*C+1): which token fills each expert slot
    table = jnp.full((B, E * C + 1), S, jnp.int32).at[bidx, dest].set(
        src_tok, mode="drop")[:, :E * C]
    wtab = jnp.zeros((B, E * C + 1), F32).at[bidx, dest].set(
        wts, mode="drop")[:, :E * C]

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    gathered = jnp.take_along_axis(x_pad, table[..., None], axis=1)
    gathered = gathered.reshape(B, E, C, d)
    gathered = policy(gathered, "moe_gathered")

    h = _act(cfg, jnp.einsum("becd,edf->becf", gathered, p["w1"]))
    if cfg.glu:
        h = h * jnp.einsum("becd,edf->becf", gathered, p["w3"])
    h = policy(h, "moe_hidden")
    out_e = jnp.einsum("becf,efd->becd", h, p["w2"])
    out_e = out_e.reshape(B, E * C, d) * wtab[..., None].astype(out_e.dtype)

    y = jnp.zeros((B, S + 1, d), out_e.dtype).at[bidx, table].add(out_e)[:, :S]
    y = y.reshape(orig_shape)
    x = x.reshape(orig_shape)
    y = policy(y, "act")

    if cfg.num_shared_experts:
        y = y + ffn_apply(cfg.replace(d_ff=cfg.moe_d_ff * cfg.num_shared_experts),
                          p["shared"], x, policy)

    # Switch-style load-balance aux loss (returned for train metrics)
    frac = counts.astype(F32).sum(0) / (B * S * K)           # (E,)
    imp = probs.mean(axis=(0, 1))                            # (E,)
    aux = E * jnp.sum(frac * imp)
    return y, aux


# --------------------------------------------------------------------------
# linear recurrence scan  h_t = a_t * h_{t-1} + b_t   (chunked, assoc within)
# --------------------------------------------------------------------------
def _assoc_combine(left, right):
    al, bl = left
    ar, br = right
    return ar * al, ar * bl + br


def linear_scan(a, b, h0=None, *, chunk: int = 256, unroll: bool = False):
    """Scan along axis 1.  a, b: (B, S, ...). Returns (h_all, h_last)."""
    B, S = a.shape[:2]
    ck = min(chunk, S)
    nchunk = -(-S // ck)
    pad = nchunk * ck - S
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    tail = a.shape[2:]
    ac = a.reshape(B, nchunk, ck, *tail).transpose(1, 0, 2, *range(3, a.ndim + 1))
    bc = b.reshape(B, nchunk, ck, *tail).transpose(1, 0, 2, *range(3, b.ndim + 1))

    if h0 is None:
        h0 = jnp.zeros((B, *tail), a.dtype)

    def chunk_step(h_in, inp):
        a_i, b_i = inp                                   # (B, ck, ...)
        A, Bv = jax.lax.associative_scan(_assoc_combine, (a_i, b_i), axis=1)
        h_chunk = Bv + A * h_in[:, None]
        return h_chunk[:, -1], h_chunk

    if unroll:
        outs, h = [], h0
        for i in range(nchunk):
            h, hc = chunk_step(h, (ac[i], bc[i]))
            outs.append(hc)
        h_all = jnp.stack(outs, 0)
    else:
        h, h_all = jax.lax.scan(chunk_step, h0, (ac, bc))
    h_all = h_all.transpose(1, 0, 2, *range(3, a.ndim + 1)).reshape(
        B, nchunk * ck, *tail)[:, :S]
    return h_all, h


# --------------------------------------------------------------------------
# causal depthwise conv (width 4) — shared by Mamba and RG-LRU blocks
# --------------------------------------------------------------------------
def causal_conv(x, w, b, state=None):
    """x: (B, S, C); w: (cw, C); state: (B, cw-1, C) prior context or None.

    Returns (y, new_state) where new_state is the trailing cw-1 inputs.
    """
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw)) + b
    new_state = xp[:, -(cw - 1):] if cw > 1 else state
    return y, new_state


# --------------------------------------------------------------------------
# Mamba-1 selective SSM block
# --------------------------------------------------------------------------
def init_mamba(cfg: ModelConfig, key):
    d, di, s, r, cw = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                       cfg.conv_width)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (cw, di)) * cw ** -0.5).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": (jax.random.normal(ks[2], (di, r + 2 * s)) * di ** -0.5).astype(dt),
        "dt_proj": (jax.random.normal(ks[3], (r, di)) * r ** -0.5).astype(dt),
        "dt_bias": jnp.full((di,), -2.0, F32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, s + 1, dtype=F32), (di, s)) + 0.0),
        "D": jnp.ones((di,), F32),
        "out_proj": (jax.random.normal(ks[4], (di, d)) * di ** -0.5).astype(dt),
    }


def fused_selective_scan(cfg, x_c, dt, Bm, Cm, A_log, D, h0=None,
                         unroll=False):
    """Chunked selective scan with discretisation + C-projection fused into
    the chunk body (jax.checkpoint'ed): the (B, chunk, di, state) tensors
    are transients of one chunk, never a full-sequence residual — the jnp
    mirror of the ssm_scan Pallas kernel's VMEM-only Ā/B̄u.
    Returns (y (B,S,di) f32, h_last (B,di,state) f32)."""
    B, S, di = x_c.shape
    s = Bm.shape[-1]
    ck = min(cfg.scan_chunk, S)
    nck = -(-S // ck)
    pad = nck * ck - S
    if pad:
        x_c = jnp.pad(x_c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    A = -jnp.exp(A_log.astype(F32))

    def to_chunks(t):
        return t.reshape(B, nck, ck, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    xs = (to_chunks(x_c), to_chunks(dt), to_chunks(Bm), to_chunks(Cm))

    @jax.checkpoint
    def chunk_body(h_in, inp):
        xq, dtq, Bq, Cq = inp
        dtf = dtq.astype(F32)
        a = jnp.exp(dtf[..., None] * A)                  # (B,ck,di,s)
        bu = (dtf * xq.astype(F32))[..., None] * Bq.astype(
            F32)[:, :, None, :]
        Ac, Buc = jax.lax.associative_scan(_assoc_combine, (a, bu), axis=1)
        hc = Buc + Ac * h_in[:, None]
        y = (hc * Cq.astype(F32)[:, :, None, :]).sum(-1)
        return hc[:, -1], y

    if h0 is None:
        h0 = jnp.zeros((B, di, s), F32)
    if unroll:
        ys, h = [], h0
        for i in range(nck):
            h, yc = chunk_body(h, tuple(t[i] for t in xs))
            ys.append(yc)
        y = jnp.stack(ys, 0)
    else:
        h, y = jax.lax.scan(chunk_body, h0, xs)
    y = y.transpose(1, 0, 2, 3).reshape(B, nck * ck, di)[:, :S]
    return y + D.astype(F32) * x_c.astype(F32)[:, :S], h


def _mamba_core(cfg, p, x_c, policy, h0=None, return_state=False):
    """x_c: (B, S, di) post-conv activations -> (y, h_last)."""
    r, s = cfg.dt_rank, cfg.ssm_state
    proj = jnp.einsum("bsi,ij->bsj", x_c, p["x_proj"])
    dt_raw, Bm, Cm = jnp.split(proj, [r, r + s], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_raw, p["dt_proj"]).astype(F32)
        + p["dt_bias"])                                          # (B,S,di)
    if cfg.use_pallas and h0 is None and not return_state:
        from repro.kernels.ssm_scan.ops import ssm_scan
        y = ssm_scan(x_c, dt.astype(x_c.dtype), Bm, Cm, p["A_log"], p["D"],
                     interpret=jax.default_backend() != "tpu")
        return y, None
    if cfg.ssm_fuse == "chunk":
        y, h_last = fused_selective_scan(cfg, x_c, dt, Bm, Cm, p["A_log"],
                                         p["D"], h0=h0,
                                         unroll=cfg.unroll_inner)
        return y.astype(x_c.dtype), (h_last if return_state else None)
    A = -jnp.exp(p["A_log"])                                     # (di, s)
    a = jnp.exp(dt[..., None] * A)                               # (B,S,di,s)
    bu = (dt * x_c.astype(F32))[..., None] * Bm.astype(F32)[:, :, None, :]
    h_all, h_last = linear_scan(a, bu, h0, chunk=cfg.scan_chunk,
                                unroll=cfg.unroll_inner)
    y = (h_all * Cm.astype(F32)[:, :, None, :]).sum(-1)          # (B,S,di)
    y = y + p["D"] * x_c.astype(F32)
    return y.astype(x_c.dtype), (h_last if return_state else None)


def mamba_apply_train(cfg: ModelConfig, p, x, policy):
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = policy(xz, "act_inner2")
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, _ = causal_conv(x_in, p["conv_w"], p["conv_b"])
    x_c = jax.nn.silu(x_c)
    y, _ = _mamba_core(cfg, p, x_c, policy)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return policy(out, "act")


def mamba_apply_decode(cfg: ModelConfig, p, x, cache, policy):
    """x: (B, 1, d); cache: {"conv": (B, cw-1, di), "ssm": (B, di, s)}."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = policy(xz, "act_inner2")
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, conv_state = causal_conv(x_in, p["conv_w"], p["conv_b"],
                                  state=cache["conv"])
    x_c = jax.nn.silu(x_c)
    y, h_last = _mamba_core(cfg, p, x_c, policy, h0=cache["ssm"],
                            return_state=True)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    new_cache = {"conv": policy(conv_state, "ssm_conv"),
                 "ssm": policy(h_last, "ssm_state")}
    return policy(out, "act"), new_cache


def init_mamba_cache(cfg: ModelConfig, B: int, dtype):
    return {"conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_inner), dtype),
            "ssm": jnp.zeros((B, cfg.d_inner, cfg.ssm_state), F32)}


# --------------------------------------------------------------------------
# RG-LRU block (Griffin / RecurrentGemma recurrent block)
# --------------------------------------------------------------------------
def init_rglru(cfg: ModelConfig, key):
    d, di, cw, nb = cfg.d_model, cfg.d_inner, cfg.conv_width, cfg.rglru_blocks
    bs = di // nb
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "w_x": (jax.random.normal(ks[0], (d, di)) * d ** -0.5).astype(dt),
        "w_gate": (jax.random.normal(ks[1], (d, di)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (cw, di)) * cw ** -0.5).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "rg_a": (jax.random.normal(ks[3], (nb, bs, bs)) * bs ** -0.5).astype(dt),
        "rg_a_b": jnp.zeros((di,), F32),
        "rg_x": (jax.random.normal(ks[4], (nb, bs, bs)) * bs ** -0.5).astype(dt),
        "rg_x_b": jnp.zeros((di,), F32),
        "lam": jnp.full((di,), 2.0, F32),   # sigmoid(lam)≈0.88 base decay
        "out_proj": (jax.random.normal(ks[5], (di, d)) * di ** -0.5).astype(dt),
    }


def _blockdiag(x, w, nb):
    B, S, di = x.shape
    xb = x.reshape(B, S, nb, di // nb)
    return jnp.einsum("bsnq,nqp->bsnp", xb, w).reshape(B, S, di)


_RG_C = 8.0


def _rglru_core(cfg, p, x_c, h0=None, return_state=False):
    nb = cfg.rglru_blocks
    r = jax.nn.sigmoid(_blockdiag(x_c, p["rg_a"], nb).astype(F32) + p["rg_a_b"])
    i = jax.nn.sigmoid(_blockdiag(x_c, p["rg_x"], nb).astype(F32) + p["rg_x_b"])
    log_a = -_RG_C * r * jax.nn.softplus(p["lam"])      # (B,S,di) <= 0
    a = jnp.exp(log_a)
    gated = i * x_c.astype(F32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * gated
    if cfg.use_pallas and h0 is None and not return_state:
        from repro.kernels.rg_lru.ops import rg_lru
        h_all = rg_lru(a, b, interpret=jax.default_backend() != "tpu")
        return h_all, None
    h_all, h_last = linear_scan(a, b, h0, chunk=cfg.scan_chunk,
                                unroll=cfg.unroll_inner)
    return h_all, (h_last if return_state else None)


def rglru_apply_train(cfg: ModelConfig, p, x, policy):
    xb = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    g = jax.nn.gelu(jnp.einsum("bsd,di->bsi", x, p["w_gate"]))
    xb = policy(xb, "act_inner")
    g = policy(g, "act_inner")
    x_c, _ = causal_conv(xb, p["conv_w"], p["conv_b"])
    h, _ = _rglru_core(cfg, p, x_c)
    y = (h * g.astype(F32)).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return policy(out, "act")


def rglru_apply_decode(cfg: ModelConfig, p, x, cache, policy):
    xb = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    g = jax.nn.gelu(jnp.einsum("bsd,di->bsi", x, p["w_gate"]))
    xb = policy(xb, "act_inner")
    x_c, conv_state = causal_conv(xb, p["conv_w"], p["conv_b"],
                                  state=cache["conv"])
    h, h_last = _rglru_core(cfg, p, x_c, h0=cache["h"], return_state=True)
    y = (h * g.astype(F32)).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    new_cache = {"conv": policy(conv_state, "ssm_conv"),
                 "h": policy(h_last, "ssm_state")}
    return policy(out, "act"), new_cache


def init_rglru_cache(cfg: ModelConfig, B: int, dtype):
    return {"conv": jnp.zeros((B, cfg.conv_width - 1, cfg.d_inner), dtype),
            "h": jnp.zeros((B, cfg.d_inner), F32)}
