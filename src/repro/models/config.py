"""Model configuration for the FlockJAX architecture zoo.

One unified decoder-stack description covers all 10 assigned architectures:
dense / GQA / sliding-window / local:global transformers, MoE (top-k with
shared experts), Mamba-1 SSM, RG-LRU hybrid (Griffin/RecurrentGemma), and the
Whisper encoder-decoder.  Modality frontends (audio conv stem, vision patch
encoder) are STUBS per the assignment: ``input_specs`` feeds precomputed
frame/patch embeddings.

Layer-kind strings used in ``pattern``:
  "attn"   full (global) causal self-attention
  "local"  sliding-window causal self-attention (window = ``window_size``)
  "swa"    alias of "local" (Mixtral-style sliding window)
  "rec"    RG-LRU gated linear recurrence block (Griffin recurrent block)
  "mamba"  Mamba-1 selective-SSM block (no separate FFN; d_ff == 0)

The stack is organised into *stages*: maximal runs of the repeating pattern,
executed with ``lax.scan`` over stacked per-layer parameters (compile-time
O(1) in depth).  A remainder prefix becomes a final 1-repeat stage.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Tuple

ATTN_KINDS = ("attn", "local", "swa", "global")
MIXER_KINDS = ATTN_KINDS + ("rec", "mamba")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    pattern: Tuple[str, ...] = ("attn",)
    window_size: int = 0             # for "local"/"swa" layers
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0    # 0 -> same as rope_theta (gemma3: locals 10k, global 1M)
    qkv_bias: bool = False
    qk_norm: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm | nonparam_ln
    glu: bool = True                 # gated (SwiGLU/GeGLU) FFN; False -> plain MLP
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d) embedding multiplier
    # ---- MoE ----
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-routed-expert hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_gathered_spec: str = "replicated"   # replicated | auto (let GSPMD
                                            #   place the dispatch tensor)
    # ---- SSM / recurrent ----
    d_inner: int = 0
    ssm_state: int = 0
    conv_width: int = 4
    dt_rank: int = 0
    rglru_blocks: int = 16           # block-diagonal gate blocks
    ssm_fuse: str = "none"           # none | chunk (fused chunked scan: the
                                     #   (B,S,di,state) discretised tensors
                                     #   exist only per-chunk, like the
                                     #   Pallas kernel)
    # ---- encoder-decoder / frontends ----
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 0             # whisper: 1500 frames
    frontend: str = ""               # "" | "audio" | "vision"
    num_prefix_tokens: int = 0       # vlm: image patch tokens prepended to text
    # ---- numerics / execution ----
    max_seq: int = 131_072
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_block_k: int = 512          # chunked-attention KV block
    attn_impl: str = "masked"        # masked | blocked (static block-pair
                                     #   list skips fully-masked tiles)
    scan_chunk: int = 256            # SSM/RG-LRU within-chunk assoc-scan length
    remat: bool = True               # activation checkpointing per layer
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    kv_quant: str = "none"           # none | int8 (quantized KV cache)
    train_accum_steps: int = 1       # gradient-accumulation microbatches
    unroll_inner: bool = False       # python-loop inner chunk loops (cost lowering)
    unroll_layers: bool = False      # python-loop over stage repeats (cost lowering)
    # cost-probe overrides: ((pattern, repeats), ...); () -> derive from depth.
    # XLA's cost model counts while-loop bodies once (verified), so the
    # dry-run lowers small unrolled probe configs and solves for per-stage
    # marginal cost — see launch/dryrun.py.
    stages_override: tuple = ()
    enc_stages_override: tuple = ()
    use_pallas: bool = False         # TPU kernels; False -> pure-jnp reference path
    # sharding-driven physical padding (see DESIGN.md §6); 1 disables
    shard_multiple: int = 1

    # ---------------- derived ----------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_num_heads(self) -> int:
        """Q heads padded up so head-sharding divides the model axis."""
        m = self.shard_multiple
        if m <= 1 or self.num_heads < m:
            return self.num_heads
        return _round_up(self.num_heads, m)

    @property
    def padded_num_kv_heads(self) -> int:
        """MHA (H == KV) pads both so the 1:1 grouping survives padding;
        GQA keeps its true KV head count (replicated if not divisible)."""
        if self.num_heads == self.num_kv_heads:
            return self.padded_num_heads
        return self.num_kv_heads

    @property
    def padded_vocab(self) -> int:
        m = self.shard_multiple
        return _round_up(self.vocab_size, m) if m > 1 else self.vocab_size

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def theta_local(self) -> float:
        return self.rope_theta_local or self.rope_theta

    def stages(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """(pattern, repeats) segments covering num_layers exactly."""
        if self.stages_override:
            return tuple((tuple(p), r) for p, r in self.stages_override)
        p = self.pattern
        reps, rem = divmod(self.num_layers, len(p))
        out = []
        if reps:
            out.append((p, reps))
        if rem:
            out.append((p[:rem], 1))
        return tuple(out)

    def encoder_stages(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        if not self.is_encoder_decoder:
            return ()
        if self.enc_stages_override:
            return tuple((tuple(p), r) for p, r in self.enc_stages_override)
        return ((("attn",), self.num_encoder_layers),)

    def moe_capacity(self, tokens_per_group: int) -> int:
        """Per-expert slot capacity for a dispatch group of given size."""
        ideal = tokens_per_group * self.top_k / self.num_experts
        c = int(math.ceil(ideal * self.capacity_factor))
        return max(1, min(_round_up(c, 4), tokens_per_group * self.top_k))

    def num_params(self) -> int:
        """Analytic parameter count (unpadded), for MODEL_FLOPS."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            n += d * self.vocab_size                  # lm head
        per_kind = {}
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        if self.qkv_bias:
            attn += (self.num_heads + 2 * self.num_kv_heads) * hd
        ffn_mult = 3 if self.glu else 2
        dense_ffn = ffn_mult * d * self.d_ff
        if self.num_experts:
            moe = d * self.num_experts \
                + self.num_experts * ffn_mult * d * self.moe_d_ff \
                + (self.num_shared_experts * ffn_mult * d * self.moe_d_ff
                   if self.num_shared_experts else 0)
            mix_plus_ffn = attn + moe
        else:
            mix_plus_ffn = attn + dense_ffn
        per_kind.update({k: mix_plus_ffn for k in ATTN_KINDS})
        if self.d_inner:
            di, s = self.d_inner, self.ssm_state
            per_kind["mamba"] = (d * 2 * di + self.conv_width * di
                                 + di * (self.dt_rank + 2 * s)
                                 + self.dt_rank * di + di * s + di + di * d)
            bs = di // self.rglru_blocks
            per_kind["rec"] = (2 * d * di + self.conv_width * di
                               + 2 * self.rglru_blocks * bs * bs + di
                               + di * d + dense_ffn)
        for pat, reps in self.stages():
            for k in pat:
                n += per_kind[k] * reps
        if self.is_encoder_decoder:
            enc_attn = 4 * d * d
            n += self.num_encoder_layers * (enc_attn + dense_ffn)
            n += self.num_layers * enc_attn          # decoder cross-attention
        return n

    def active_params(self) -> int:
        """Params touched per token (MoE: only routed top-k)."""
        if not self.num_experts:
            return self.num_params()
        d = self.d_model
        ffn_mult = 3 if self.glu else 2
        dead = (self.num_experts - self.top_k) * ffn_mult * d * self.moe_d_ff
        n_moe_layers = sum(
            reps * sum(1 for k in pat if k in ATTN_KINDS)
            for pat, reps in self.stages())
        return self.num_params() - dead * n_moe_layers

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str                         # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# archs for which long_500k is runnable (sub-quadratic / windowed); the
# rest SKIP that cell per DESIGN.md §4.
LONG_CONTEXT_OK = {
    "falcon-mamba-7b", "recurrentgemma-9b", "mixtral-8x7b", "gemma3-12b",
}


def cell_is_supported(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_OK
    return True
