"""Fault-tolerant checkpointing: atomic, keep-N, resumable, reshardable.

Production posture (DESIGN.md §6):
  * ATOMIC: write to ``step_XXXX.tmp`` then ``rename`` — a node failure
    mid-save never corrupts the latest checkpoint;
  * KEEP-N: bounded disk, oldest checkpoints garbage-collected;
  * RESUME: ``restore_latest`` scans the directory, so ``--resume auto``
    after a crash continues from the newest complete checkpoint
    (bitwise-identical continuation is asserted in the failure test);
  * ELASTIC: arrays are saved as host numpy with their pytree structure;
    on restore the trainer re-shards them for whatever mesh is active, so
    the same checkpoint restarts on a different pod/slice count.

Format: msgpack-free, dependency-light — one ``.npz`` per checkpoint with
flattened key paths + a JSON manifest (step, config name, tree structure).
"""

from __future__ import annotations

import json
import re
import threading
import time
from pathlib import Path
from typing import Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def normalize(node):
        if not isinstance(node, dict):
            return node
        keys = list(node)
        if keys and all(re.fullmatch(r"\d+", k) for k in keys):
            return [normalize(node[str(i)]) for i in range(len(keys))]
        return {k: normalize(v) for k, v in node.items()}

    return normalize(root)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    # ---- save ---------------------------------------------------------------
    def save(self, step: int, state: dict, metadata: Optional[dict] = None):
        """state: arbitrary pytree of arrays (params/opt/data-state)."""
        host = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), state)
        if self.async_save:
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(step, host, metadata or {}))
            self._pending.start()
        else:
            self._write(step, host, metadata or {})

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_state, metadata: dict):
        flat = _flatten(host_state)
        # numpy can't serialise bfloat16 — store a uint16 view + dtype tag
        dtypes = {}
        enc = {}
        for k, v in flat.items():
            v = np.asarray(v)
            if v.dtype.kind == "V" or str(v.dtype) == "bfloat16":
                dtypes[k] = "bfloat16"
                v = v.view(np.uint16)
            enc[k] = v
        tmp = self.dir / f"step_{step:010d}.tmp.npz"
        final = self.dir / f"step_{step:010d}.npz"
        np.savez(tmp, __dtypes__=np.frombuffer(
            json.dumps(dtypes).encode(), np.uint8), **enc)
        # wall-clock manifest timestamp  # flocklint: ignore[FLKL101]
        manifest = {"step": step, "time": time.time(), **metadata}
        (self.dir / f"step_{step:010d}.json").write_text(
            json.dumps(manifest))
        tmp.replace(final)                      # atomic publish
        self._gc()

    def _gc(self):
        ckpts = self.list_steps()
        for step in ckpts[:-self.keep] if self.keep else []:
            for suffix in (".npz", ".json"):
                p = self.dir / f"step_{step:010d}{suffix}"
                if p.exists():
                    p.unlink()

    # ---- restore -------------------------------------------------------------
    def list_steps(self):
        steps = []
        for p in self.dir.glob("step_*.npz"):
            m = re.fullmatch(r"step_(\d+)\.npz", p.name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def restore(self, step: int) -> dict:
        import ml_dtypes
        path = self.dir / f"step_{step:010d}.npz"
        with np.load(path, allow_pickle=False) as z:
            flat = {k: z[k] for k in z.files}
        dtypes = {}
        if "__dtypes__" in flat:
            dtypes = json.loads(flat.pop("__dtypes__").tobytes().decode())
        for k, dt in dtypes.items():
            flat[k] = flat[k].view(ml_dtypes.bfloat16)
        return _unflatten(flat)

    def restore_latest(self) -> Optional[dict]:
        steps = self.list_steps()
        return self.restore(steps[-1]) if steps else None

    def latest_step(self) -> int:
        steps = self.list_steps()
        return steps[-1] if steps else -1

    def metadata(self, step: int) -> dict:
        p = self.dir / f"step_{step:010d}.json"
        return json.loads(p.read_text()) if p.exists() else {}
