"""jit-able train / eval steps with optional gradient accumulation."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.layers import NULL_POLICY

from .optimizer import HParams, adamw_update

F32 = jnp.float32


def make_train_step(cfg: ModelConfig, hp: HParams, policy=NULL_POLICY):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With hp.accum_steps > 1 the global batch is split along the batch dim
    into microbatches scanned sequentially (grad accumulation) — the
    distributed-optimization lever for fitting large global batches.
    """

    def loss(params, batch):
        return M.loss_fn(cfg, params, batch, policy)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if hp.accum_steps > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, aux), g = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), aux

            micro_batches = jax.tree.map(
                lambda a: a.reshape(hp.accum_steps,
                                    a.shape[0] // hp.accum_steps,
                                    *a.shape[1:]),
                batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            if cfg.unroll_inner:
                # cost-probe lowering: python loop so XLA's cost model
                # (which counts while bodies once) sees every microbatch
                carry = (zeros, jnp.zeros((), F32))
                aux = None
                for i in range(hp.accum_steps):
                    mb = jax.tree.map(lambda a, i=i: a[i], micro_batches)
                    carry, aux = micro(carry, mb)
                (grads, l_sum) = carry
            else:
                (grads, l_sum), auxs = jax.lax.scan(
                    micro, (zeros, jnp.zeros((), F32)), micro_batches)
                aux = jax.tree.map(lambda a: a[-1], auxs)
            grads = jax.tree.map(lambda g: g / hp.accum_steps, grads)
            lval = l_sum / hp.accum_steps
        else:
            (lval, aux), grads = grad_fn(params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, hp)
        metrics = {"total_loss": lval, **aux, **opt_metrics}
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, policy=NULL_POLICY):
    def eval_step(params, batch):
        _, metrics = M.loss_fn(cfg, params, batch, policy)
        return metrics
    return eval_step
