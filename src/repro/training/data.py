"""Deterministic, resumable synthetic data pipeline.

Token streams are generated from a counter-based RNG keyed on
(seed, step, host), so:
  * RESUMABLE: after restart the pipeline regenerates exactly the batch for
    any step — no iterator state to checkpoint beyond the step counter;
  * ELASTIC: per-host shards are a pure function of (step, host_index,
    n_hosts); changing the host count re-partitions the same global stream;
  * STRAGGLER-AWARE: ``StragglerWatchdog`` tracks per-step wall time and
    flags hosts whose step time exceeds ``threshold``x the running median
    (on real fleets this feeds the scheduler's replacement logic; here it
    feeds metrics and the fault-tolerance test).

Documents are sampled from a mixture of Zipfian token draws and repeated
phrase templates so batches have realistic repetition for the dedup/cache
benchmarks (and non-trivial loss curves for the training example).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    pad_id: int = -1


class SyntheticTokenPipeline:
    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_index = host_index
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts

    def batch_at(self, step: int) -> dict:
        """Global-deterministic batch for ``step`` (this host's shard)."""
        cfg = self.cfg
        rows = []
        base = self.host_index * self.local_batch
        for r in range(self.local_batch):
            rng = np.random.default_rng(
                (cfg.seed * 1_000_003 + step) * 65_537 + base + r)
            # zipf-distributed ids clipped to vocab, plus a motif: repeat a
            # short random phrase so sequences are learnably compressible
            toks = rng.zipf(cfg.zipf_a, cfg.seq_len + 1)
            toks = np.minimum(toks - 1, cfg.vocab_size - 1)
            phrase = rng.integers(0, cfg.vocab_size,
                                  rng.integers(4, 12))
            pos = rng.integers(0, max(cfg.seq_len - len(phrase), 1),
                               max(cfg.seq_len // (4 * len(phrase)), 1))
            for p in pos:
                toks[p:p + len(phrase)] = phrase[:len(toks[p:p + len(phrase)])]
            rows.append(toks)
        arr = np.stack(rows).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class StragglerWatchdog:
    def __init__(self, threshold: float = 2.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: list[float] = []
        self.flagged_steps: list[int] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        """Record step time; returns True if this step straggled."""
        dt = time.monotonic() - self._t0
        self.times.append(dt)
        self.times = self.times[-self.window:]
        self._step += 1
        med = float(np.median(self.times))
        straggled = len(self.times) >= 8 and dt > self.threshold * med
        if straggled:
            self.flagged_steps.append(self._step)
        return straggled

    @property
    def median_s(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0
