"""AdamW with fp32 master weights and ZeRO-1-style sharded optimizer state.

Live params stay in ``param_dtype`` (bf16); the optimizer state carries a
fp32 master copy plus first/second moments.  State shardings add a "data"
axis on the first evenly-divisible replicated dim of each tensor, so the
12 bytes/param optimizer footprint is spread over the *whole* mesh rather
than just the model axis (ZeRO-1).  GSPMD materialises the implied
reduce-scatter (grads -> sharded moments) and all-gather (master -> bf16
params) from the in/out shardings alone.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

F32 = jnp.float32


@dataclass(frozen=True)
class HParams:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    accum_steps: int = 1             # gradient-accumulation microbatches


def lr_schedule(hp: HParams, step):
    step = step.astype(F32)
    warm = step / jnp.maximum(hp.warmup_steps, 1)
    prog = jnp.clip((step - hp.warmup_steps)
                    / jnp.maximum(hp.total_steps - hp.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return hp.lr * jnp.minimum(warm, 1.0) * jnp.maximum(cos, 0.1)


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(lambda p: p.astype(F32), params),
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, hp: HParams):
    step = state["step"] + 1
    lr = lr_schedule(hp, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.grad_clip / (gnorm + 1e-9))
    bc1 = 1 - hp.b1 ** step.astype(F32)
    bc2 = 1 - hp.b2 ** step.astype(F32)

    def upd(g, m, v, master):
        g = g.astype(F32) * scale
        m = hp.b1 * m + (1 - hp.b1) * g
        v = hp.b2 * v + (1 - hp.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + hp.eps)
        master = master - lr * (u + hp.weight_decay * master)
        return m, v, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, ma)
           for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), new_master, params)
    new_state = {"step": step, "master": new_master, "m": new_m,
                 "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def _zero1_spec(spec: P, shape, data_size: int) -> P:
    """Add 'data' on the first replicated dim that divides evenly."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (s, n) in enumerate(zip(entries, shape)):
        if s is None and n % data_size == 0 and n >= data_size:
            entries[i] = "data"
            break
    return P(*entries)


def opt_specs(param_spec_tree, param_shapes, mesh):
    """Optimizer-state PartitionSpecs (ZeRO-1 over the 'data' axis)."""
    data_size = mesh.shape["data"]

    def one(spec, shape_struct):
        return _zero1_spec(spec, shape_struct.shape, data_size)

    sharded = jax.tree.map(one, param_spec_tree, param_shapes,
                           is_leaf=lambda s: isinstance(s, P))
    return {"step": P(), "master": sharded, "m": sharded, "v": sharded}
