from .optimizer import adamw_init, adamw_update, opt_specs, HParams
from .train_step import make_train_step, make_eval_step
