"""First-class retrieval plan operators (paper Query 3, Table 1 FUSION).

FlockMTL's pitch is that RAG composes *relationally*: retrieval, score
fusion and LLM reasoning are operators in one plan, so the optimizer can
batch, cache and reorder them.  This module is the executor layer behind
the ``Pipeline`` retrieval nodes:

  * ``vector_topk``  — paper Query 3 step 2: embed the query column,
    scan the corpus embedding index, expand each query row into its
    top-k candidate rows (a LATERAL join).
  * ``bm25_topk``    — Query 3 step 3: the FTS retriever over the same
    corpus; no LLM calls at all.
  * ``hybrid_topk``  — Query 3 steps 2-4: both retrievers at a
    per-retriever candidate depth, fused with ``core.fusion`` (Table 1:
    ``fusion_rrf``/``combsum``/...), final top-k by fused score.

Canonical candidate semantics (what the equivalence suite pins): each
retriever scores the corpus, candidates are the top-``depth`` docs by
``(score desc, doc id asc)``; fusion sees full-length per-retriever
score arrays with NaN at non-candidate positions (exactly the
FULL-OUTER-JOIN idiom of ``examples/hybrid_search.py``), and the final
cut is top-k of the fused array with the same deterministic tie-break.

Corpus predicates (``corpus_filter=``) are part of the operator's
contract — "top-k among corpus docs satisfying the predicate".  The
unoptimized plan embeds the FULL corpus and masks non-matching docs out
of the ranking; the optimizer's ``prune_corpus`` rewrite moves the
predicate below the index build so only matching docs are embedded.
Both produce identical rows: per-doc scores are independent of the rest
of the corpus on the vector side, and BM25 statistics (idf, avgdl) are
ALWAYS computed over the full corpus so its scores cannot depend on the
rewrite.

Corpus embeddings are memoised through ``retrieval.ensure_index`` —
session registry first, then the persistent ``IndexStore`` sidecar —
keyed by (embedding model ref, corpus fingerprint), so plan nodes
sharing a corpus dedupe the embed work and repeated queries skip it
entirely.  When the context allows cross-job co-packing, the corpus and
query embed dispatches run concurrently and their part-filled tail
batches merge into one provider request (``embedding_pack_key``).
"""

from __future__ import annotations

import threading
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.cache import corpus_fingerprint
from repro.core.functions import (SemanticContext, embedding_pack_key,
                                  llm_embedding, llm_rerank)
from repro.core.fusion import fusion
from repro.core.scheduler import SpecTask, SpeculativeJoin
from repro.retrieval import BM25Index, ensure_index

from .table import Table

RETRIEVAL_OPS = ("vector_topk", "bm25_topk", "hybrid_topk")

# k-pushdown defaults: when ``hybrid_topk(candidate_k=None)`` leaves the
# per-retriever depth to the engine, the unoptimized plan fuses FULL
# candidate lists and the optimizer pushes the final k down to
# ``max(CANDIDATE_MIN, CANDIDATE_FACTOR * k)`` per retriever
CANDIDATE_FACTOR = 4
CANDIDATE_MIN = 32


def retrieval_outputs(info: dict) -> List[str]:
    """Columns a retrieval node may produce: the score and rank columns
    plus every corpus column (under both its own name and the ``_doc``
    collision suffix) — the conservative ban set for pushdown."""
    corpus_cols = list(info["corpus"].column_names)
    return ([info["out"], info["out"] + "_rank"]
            + corpus_cols + [c + "_doc" for c in corpus_cols])


def pushed_candidate_k(k: int) -> int:
    """The per-retriever candidate depth the optimizer's k-pushdown rule
    derives from a final fused top-``k``."""
    return max(CANDIDATE_MIN, CANDIDATE_FACTOR * k)


# ---------------------------------------------------------------------------
# candidate generation
# ---------------------------------------------------------------------------
def _corpus_selection(info: dict) -> List[int]:
    """Doc ids satisfying the node's corpus predicate (all ids without
    one) — identical whether or not the optimizer pruned, so the rewrite
    can only change WHERE the predicate is applied, never the result."""
    corpus = info["corpus"]
    pred = info.get("corpus_filter")
    if pred is None:
        return list(range(len(corpus)))
    return [i for i, r in enumerate(corpus.rows()) if pred(r)]


def _ranked(scores: np.ndarray, eligible: Sequence[int],
            depth: int) -> Tuple[List[int], List[float]]:
    """Top-``depth`` of ``eligible`` doc ids by ``(score desc, id asc)``
    — ``eligible`` arrives ascending, so the stable sort IS the
    canonical tie-break."""
    s = np.asarray(scores, np.float64)[list(eligible)]
    order = np.argsort(-s, kind="stable")[:depth]
    return ([int(eligible[j]) for j in order],
            [float(s[j]) for j in order])


def _embed_corpus_and_queries(ctx: SemanticContext, model_spec,
                              corpus_texts: List[str],
                              queries: List[str], fingerprint):
    """Corpus index (via ``ensure_index``) + query vectors.  When the
    corpus is not memoised and the context allows co-packing, the two
    embed dispatches run on concurrent threads under an activated
    embedding pack identity, so the corpus tail batch and the (small)
    query batch merge into one provider request."""
    model = ctx.resolve_model(model_spec)
    if fingerprint is None:
        fingerprint = corpus_fingerprint(corpus_texts)
    cached = ctx.index_cached(model.ref, fingerprint)
    if (cached or not queries or not ctx.copack
            or ctx.scheduler is None or not ctx.enable_batching):
        index, _ = ensure_index(ctx, model_spec, corpus_texts,
                                fingerprint=fingerprint)
        qv = llm_embedding(ctx, model_spec, queries)
        return index, qv

    ident = embedding_pack_key(ctx, model)
    slots: List = [None, None]
    errors: List[BaseException] = []

    def worker(slot: int, thunk):
        try:
            slots[slot] = thunk()
        # re-raised on the caller  # flocklint: ignore[FLKL105]
        except BaseException as exc:
            errors.append(exc)

    # two expected submitters under one embedding identity (corpus +
    # queries): the scheduler flushes the merged pack the moment the
    # second tail arrives instead of waiting out the linger deadline
    ctx.copack_begin({ident: 2})
    try:
        threads = [
            # exactly two bounded submitters under one activated pack
            # identity, joined below  # flocklint: ignore[FLKL106]
            threading.Thread(
                target=worker,
                args=(0, lambda: ensure_index(ctx, model_spec,
                                              corpus_texts,
                                              fingerprint=fingerprint)),
                name="flockjax-embed-corpus"),
            # flocklint: ignore[FLKL106]
            threading.Thread(
                target=worker,
                args=(1, lambda: llm_embedding(ctx, model_spec, queries)),
                name="flockjax-embed-query"),
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    finally:
        ctx.copack_end({ident: 2})
    if errors:
        raise errors[0]
    return slots[0][0], slots[1]


def _vector_candidates(ctx: SemanticContext, info: dict,
                       queries: List[str], sel: List[int],
                       depth: int) -> List[Tuple[List[int], List[float]]]:
    """Per-query vector candidates at ``depth``: (doc ids, cosine
    scores).  Three modes — no predicate (scan all), pruned (embed and
    scan only matching docs), unpruned predicate (scan all, mask the
    ranking) — produce identical candidates; only the embed volume
    differs."""
    corpus_texts = [str(x) for x in
                    info["corpus"].column(info["doc_col"])]
    n = len(corpus_texts)
    full = len(sel) == n
    pruned = bool(info.get("prune_corpus")) and not full
    texts = ([corpus_texts[i] for i in sel] if pruned else corpus_texts)
    if not texts:
        return [([], []) for _ in queries]
    fp = None if pruned else info.get("corpus_fp")
    index, qv = _embed_corpus_and_queries(ctx, info["model"], texts,
                                          queries, fp)
    # ANN routing: the optimizer's ann_select resolution wins; a forced
    # ann="ivf" is honoured even on an unoptimized plan; "auto" without
    # a resolution stays exact (result-preserving default).  The masked
    # unpruned-predicate branch always scans exactly — its full ranking
    # feeds the mask.
    ann = info.get("ann_resolved") or (
        "ivf" if info.get("ann") == "ivf" else "exact")
    out: List[Tuple[List[int], List[float]]] = []
    if full or pruned:
        if ann == "ivf":
            s, li = index.topk_ann(
                qv, min(depth, len(texts)),
                nprobe=info.get("ann_nprobe", info.get("nprobe")),
                nlist=info.get("ann_nlist", info.get("nlist")),
                recall_target=info.get("recall_target"))
        else:
            s, li = index.topk(qv, min(depth, len(texts)))
        for r in range(len(queries)):
            ids = ([sel[int(j)] for j in li[r]] if pruned
                   else [int(j) for j in li[r]])
            out.append((ids, [float(x) for x in s[r]]))
    else:
        s, li = index.topk(qv, n)          # full ranking, then mask
        selset = set(sel)
        for r in range(len(queries)):
            pairs = [(int(i), float(sc))
                     for i, sc in zip(li[r], s[r]) if int(i) in selset]
            pairs = pairs[:depth]
            out.append(([p[0] for p in pairs], [p[1] for p in pairs]))
    return out


def _bm25_candidates(info: dict, queries: List[str], sel: List[int],
                     depth: int) -> List[Tuple[List[int], List[float]]]:
    """Per-query BM25 candidates at ``depth``.  The index is ALWAYS
    built over the full corpus (idf/avgdl are corpus statistics; a
    pruned build would change scores), memoised on the node info."""
    bm = info.get("_bm25")
    if bm is None:
        bm = info["_bm25"] = BM25Index.build(
            [str(x) for x in info["corpus"].column(info["doc_col"])])
    # all pending queries score in ONE vectorized pass over the
    # postings (bit-identical rows to per-query score(), see bm25.py)
    scores = bm.score_many([str(q) for q in queries])
    return [_ranked(scores[i], sel, depth) for i in range(len(queries))]


def _candidates(ctx: SemanticContext, op: str, info: dict,
                queries: List[str]) -> List[Tuple[List[int], List[float]]]:
    sel = _corpus_selection(info)
    k_eff = min(info["k"], len(sel))
    if op == "bm25_topk":
        return _bm25_candidates(info, queries, sel, k_eff)
    if op == "vector_topk":
        return _vector_candidates(ctx, info, queries, sel, k_eff)

    # hybrid: per-retriever candidate lists at the (possibly pushed-
    # down) depth, fused over full-length NaN-holed score arrays
    n = len(info["corpus"])
    depth = info.get("candidate_k") or len(sel)
    depth = min(depth, len(sel))
    vec = _vector_candidates(ctx, info, queries, sel, depth)
    bm = _bm25_candidates(info, queries, sel, depth)
    out = []
    for (v_ids, v_s), (b_ids, b_s) in zip(vec, bm):
        col_b = np.full(n, np.nan)
        col_b[b_ids] = b_s
        col_v = np.full(n, np.nan)
        col_v[v_ids] = v_s
        fused = fusion(info["fusion"], col_b, col_v)
        out.append(_ranked(fused, sel, k_eff))
    return out


# ---------------------------------------------------------------------------
# node executor
# ---------------------------------------------------------------------------
def make_retrieval_fn(ctx: SemanticContext, op: str, info: dict):
    """Executor closure for one retrieval plan node.  Bound to the
    passed ``info`` dict, so the optimizer can rebuild a node with
    modified info (``prune_corpus``, ``candidate_k``) without mutating
    the shared logical plan."""
    if op not in RETRIEVAL_OPS:
        raise ValueError(f"unknown retrieval op {op!r}")

    def fn(t: Table) -> Table:
        corpus = info["corpus"]
        out_col, rank_col = info["out"], info["out"] + "_rank"
        names: Dict[str, str] = {
            c: (c + "_doc" if c in t.column_names else c)
            for c in corpus.column_names}
        if not len(t):
            cols = {nm: [] for nm in t.column_names}
            for c in corpus.column_names:
                cols[names[c]] = []
            cols[out_col] = []
            cols[rank_col] = []
            return Table(cols)
        queries = [str(v) for v in t.column(info["query_col"])]
        cand = _candidates(ctx, op, info, queries)

        def child(i, row):
            ids, scores = cand[i]
            cols = {names[c]: [corpus.columns[c][d] for d in ids]
                    for c in corpus.column_names}
            cols[out_col] = list(scores)
            cols[rank_col] = list(range(1, len(ids) + 1))
            return Table(cols)

        return t.lateral(child)

    return fn


# ---------------------------------------------------------------------------
# speculative retrieval->rerank executor
# ---------------------------------------------------------------------------
def make_spec_rerank_fn(ctx: SemanticContext, node):
    """Executor for one ``spec_rerank`` plan node: ``hybrid_topk``
    followed by a grouped ``llm_rerank``, with the rerank's window
    cache warmed over BM25-predicted candidates WHILE the dense
    retriever and fusion finish.

    The BM25 side of a hybrid node is provider-free (postings scan), so
    the final per-query top-k can be *predicted* before any embed
    request returns.  Warmup tasks rerank the predicted candidate
    tuples — their permutations are discarded, but every rerank window
    lands in the prediction cache keyed by its serialized tuple
    content.  The mandatory task runs the full retrieval; when it
    resolves, warmups for queries whose predicted list does not match
    the fused top-k (content and order both) are cancelled if not yet
    started, or counted as wasted rows if already dispatched.  The
    authoritative rerank then runs over the REAL expanded table —
    matched groups hit the cache window-for-window, mispredicted ones
    pay the provider exactly as the serial plan would — so the output
    is bit-identical to ``hybrid_topk`` -> ``llm_rerank`` by
    construction."""
    info = node.info
    retr_info = info["_retr"]
    rr = info["_rerank"]
    retr_fn = make_retrieval_fn(ctx, info["retr_op"], retr_info)

    def rerank_table(expanded: Table) -> Table:
        """The serial plan's grouped rerank, verbatim."""
        tuples = [{c: r[c] for c in rr["cols"]} for r in expanded.rows()]
        if rr.get("by") is None:
            perm = llm_rerank(ctx, rr["model"], rr["prompt"], tuples)
            return expanded.take(perm)
        groups: dict = {}
        for i, v in enumerate(expanded.column(rr["by"])):
            groups.setdefault(v, []).append(i)
        order: List[int] = []
        for idxs in groups.values():
            perm = llm_rerank(ctx, rr["model"], rr["prompt"],
                              [tuples[i] for i in idxs])
            order.extend(idxs[p] for p in perm)
        return expanded.take(order)

    def fn(t: Table) -> Table:
        if not len(t):
            return retr_fn(t)
        corpus = retr_info["corpus"]
        names = {c: (c + "_doc" if c in t.column_names else c)
                 for c in corpus.column_names}
        inv = {v: c for c, v in names.items()}
        parents = list(t.rows())
        queries = [str(v) for v in t.column(retr_info["query_col"])]
        sel = _corpus_selection(retr_info)
        k_eff = min(retr_info["k"], len(sel))
        pred = _bm25_candidates(retr_info, queries, sel, k_eff)
        rr_cols = list(rr["cols"])
        by = rr.get("by")

        def value(pi: int, d: int, c: str):
            if c in inv:
                return corpus.columns[inv[c]][d]
            return parents[pi][c]

        # predicted expanded rows (parent order x rank order), grouped
        # exactly as the serial rerank groups the real expansion
        pgroups: dict = {}
        for pi in range(len(parents)):
            for d in pred[pi][0]:
                key = value(pi, d, by) if by is not None else None
                pgroups.setdefault(key, []).append((pi, d))
        pkeys = list(pgroups)
        ptuples = {key: [{c: value(pi, d, c) for c in rr_cols}
                         for pi, d in pgroups[key]] for key in pkeys}

        join = SpeculativeJoin(ctx.scheduler)
        state: dict = {"mismatched": set()}

        def authoritative() -> Table:
            expanded = retr_fn(t)
            tuples = [{c: r[c] for c in rr_cols}
                      for r in expanded.rows()]
            if by is None:
                agroups = {None: list(range(len(tuples)))}
            else:
                agroups = {}
                for i, v in enumerate(expanded.column(by)):
                    agroups.setdefault(v, []).append(i)
            mismatched = set()
            for j, key in enumerate(pkeys):
                actual = ([tuples[i] for i in agroups[key]]
                          if key in agroups else None)
                if actual != ptuples[key]:
                    mismatched.add(key)
                    join.cancel(1 + j)      # warmup windows can't hit
            state["mismatched"] = mismatched
            return expanded

        def make_warmup(key):
            def thunk():
                llm_rerank(ctx, rr["model"], rr["prompt"], ptuples[key])
                return key
            return thunk

        tasks = ([SpecTask(authoritative, rows=len(t), label="retrieve",
                           mandatory=True)]
                 + [SpecTask(make_warmup(key), rows=len(ptuples[key]),
                             label=f"warmup-{j}")
                    for j, key in enumerate(pkeys)])
        results = join.run(tasks)
        expanded = results[0]
        cancelled = set(join.cancelled)
        wasted = sum(len(ptuples[key]) for j, key in enumerate(pkeys)
                     if key in state["mismatched"]
                     and (1 + j) not in cancelled)
        if wasted:
            join.note_wasted(wasted)
        return rerank_table(expanded)

    return fn
