from .table import Table
from .pipeline import Pipeline, PlanNode, ask, copack_identity
from .retrieval_ops import RETRIEVAL_OPS
from .optimizer import (OptimizedPlan, PlanCost, estimate_plan_cost,
                        optimize_plan)
