from .table import Table
from .analysis import (Diagnostic, Obligation, PlanValidationError,
                       Schema, analyze_plan, infer_schema,
                       verify_rewrites)
from .pipeline import Pipeline, PlanNode, ask, copack_identity
from .retrieval_ops import RETRIEVAL_OPS
from .optimizer import (OptimizedPlan, PlanCost, estimate_plan_cost,
                        optimize_plan)
