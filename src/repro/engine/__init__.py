from .table import Table
from .pipeline import Pipeline, ask
