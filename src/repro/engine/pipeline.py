"""Lazy CTE-style pipeline over Tables with semantic operators + explain().

Mirrors how FlockMTL queries chain CTEs (paper Query 2/3): each chained
call appends a plan node; ``collect()`` executes; ``explain()`` shows the
plan with the optimizer's execution reports (batch sizes, cache hits,
dedup factor, meta-prompt prefix) — the paper's plan-inspection interface
(Fig. 2b) as a library call.

**Plan optimization** (``optimizer.py``): by default ``collect()`` first
rewrites the chained node list with three cost-based rules —

  * *pushdown*: cheap relational ops (``filter``/``limit``/``select``/
    key-independent ``order_by``) bubble below semantic ops they commute
    with, so LLM calls see fewer tuples (a ``limit(10)`` chained after an
    ``llm_complete`` over 10k rows runs first, making the LLM pass 1000x
    cheaper);
  * *semantic fusion*: adjacent ``llm_filter``/``llm_complete``/
    ``llm_complete_json`` nodes sharing one model + input-column set merge
    into a single multi-output metaprompt pass (one request stream instead
    of N);
  * *cost-ordered filter chains*: consecutive ``llm_filter`` nodes run
    cheapest-and-most-selective first, ranked by estimated token cost x
    the pass rates recorded in ``SemanticContext.selectivity_stats``.

``collect(optimize=False)`` is the escape hatch that executes nodes
exactly as chained; ``explain()`` prints the logical and rewritten plans
side by side with estimated request/token counts, the critical-path
``waves`` latency estimate, and the fired rewrites.

**Concurrent dispatch** (``core/scheduler.py``): when the context holds
a ``RequestScheduler``, ``collect()`` additionally dispatches runs of
independent row-preserving map nodes concurrently (and every node's
batches overlap on the scheduler's worker pool), so wall-clock tracks
the model's ``max_concurrency`` instead of the batch count.  Dispatch
never changes which tuples a node sees — results and request/token
counts are identical to the serial path.

**Cross-node batch co-packing** (``SemanticContext(copack=...)``,
default on): map nodes of one concurrent dispatch group that share a
metaprompt-prefix identity (model + function kind + serialization +
prompt text — ``copack_identity``) register with the scheduler's
packing queue, and their part-filled TAIL batches merge into shared
provider requests before admission.  Per-row results are independent of
batch composition, so collected tables are bit-identical; only request
density changes (fewer, fuller batches — the TPU step stays dense when
concurrency is highest).  ``copack=False`` is the escape hatch.

**Speculative pipelining** (``collect(speculate=...)`` or the
context's ``speculate`` knob): serial plans stall wherever a node
waits on an upstream LLM round-trip.  The optimizer speculates across
three such edges.  *Filter chains*: a chain of k ``llm_filter`` nodes
normally costs k sequential round-trips; speculation fans a chosen
*prefix* of members out over the chain's input concurrently and ANDs
the masks, keeping the expensive tail serial on survivors (the split
minimizing estimated wall time under the waste cap).  *Map past
filter*: a map (``llm_complete``/``llm_complete_json``) downstream of
a filter dispatches completions for the filter's *input* rows while
the mask is still in flight — chunks whose rows all die are cancelled,
and results for masked-out rows are discarded (their cache entries
survive).  *Retrieval-aware rerank*: ``llm_rerank`` downstream of
``hybrid_topk`` starts reranking the first retriever's candidate set
while fusion finishes, warming the prediction cache; the final top-k
is reconciled against the authoritative retrieval.  Every decision is
driven by the calibrated cost model (observed latency percentiles,
retry rates and batch sizes from the ``CalibrationStore`` sidecar);
the expected waste — predicted from recorded selectivity — is capped
by ``ctx.speculate_waste_cap`` (widened 1.25x under
``objective="latency"``, narrowed 0.8x under ``"cost"``) and reported
per edge in ``explain()``'s "Speculation:" section.  Surviving streams
are bit-identical to the serial plan in all three shapes.

**First-class retrieval operators** (``retrieval_ops.py``): paper
Query 3 is a plan, not a script — ``vector_topk`` / ``bm25_topk`` /
``hybrid_topk`` expand each query row into its top-k candidate rows (a
LATERAL join over the corpus), ``hybrid_topk`` fuses both retrievers
with the paper's FUSION table methods (rrf/combsum/...), and
``llm_rerank(by=...)`` reranks each query's candidate list through the
existing map path.  Because retrieval is IN the plan, the optimizer
prunes filtered corpora before embedding, pushes query-side filters
below the expansion, pushes k into per-retriever candidate depth,
dedupes shared corpus embeddings (session registry + the persistent
``IndexStore`` sidecar), and ``explain()`` prices the embed requests,
their co-packed estimate, and the index-scan cost.

Relational ``filter`` predicates are opaque closures; pass
``filter(pred, cols=[...])`` to declare the columns the predicate reads
and unlock pushdown past column-producing semantic ops.

``ask()`` is the ASK functionality: NL -> pipeline.  Faithful NL->SQL needs
an instruction-tuned checkpoint; with research (random-weight) models it is
a deterministic template planner — DEMO-ONLY, as recorded in DESIGN.md §8.
"""

from __future__ import annotations

import re
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import functions as F
from repro.core.functions import SemanticContext
from repro.core.metaprompt import build_multi_task

from .table import Table

# row-preserving semantic map ops: safe to dispatch concurrently when no
# def-use dependency links them (each sees the group's input table either
# way, so results AND request/token counts match the serial execution)
_PARALLEL_MAP_OPS = ("llm_complete", "llm_complete_json", "llm_embedding")

# plan ops whose dispatches can co-pack: their metaprompt prefix is fully
# determined by (model, function kind, serialization, prompt text), so
# two nodes agreeing on that tuple produce byte-identical static prefixes
# and their rows can share one provider request.  Embedding dispatches
# have no prompt at all, so they co-pack on the model alone; fused
# multi-output nodes co-pack on the full rendered multi-task prompt
# (sub-task kinds AND texts, in order), so only structurally identical
# fusions merge and the positional demux stays exact per sub-output.
_COPACK_KINDS = {"llm_complete": "complete",
                 "llm_complete_json": "complete_json",
                 "llm_embedding": "embedding",
                 "llm_fused": "multi"}


def copack_identity(ctx: SemanticContext, node: "PlanNode"):
    """Metaprompt-prefix identity of a map node, or ``None`` when the
    node cannot co-pack.  Must mirror the ``pack_key`` computed by
    ``functions._map_core`` (``functions.llm_multi`` renders the same
    multi-task prompt for fused nodes, and
    ``functions.embedding_pack_key`` covers embedding dispatches) — the
    scheduler's packing queue merges tail batches exactly when these
    tuples compare equal."""
    kind = _COPACK_KINDS.get(node.op)
    if kind is None:
        return None
    try:
        model = ctx.resolve_model(node.info["model"])
        if kind == "embedding":
            return F.embedding_pack_key(ctx, model)
        if kind == "multi":
            text = build_multi_task(
                node.info["kinds"],
                [ctx.resolve_prompt(p)[0] for p in node.info["prompts"]])
        else:
            text, _ = ctx.resolve_prompt(node.info["prompt"])
    except KeyError:
        return None
    # the FULL resolved resource, not just name@version: inline specs
    # all land on version 0, and a merged request executes under one
    # job's model object — jobs whose caps (max_output_tokens,
    # context_window) differ must never merge
    return (id(ctx.provider), model, kind, ctx.serialization, text)


@dataclass
class PlanNode:
    op: str
    info: dict = field(default_factory=dict)
    fn: Optional[Callable] = None
    report_slot: Optional[int] = None


class Pipeline:
    def __init__(self, ctx: SemanticContext, source: Table,
                 name: str = "scan"):
        self.ctx = ctx
        self.source = source
        self.nodes: List[PlanNode] = [PlanNode("scan", {"rows": len(source),
                                                        "name": name})]

    def _add(self, op: str, fn, **info) -> "Pipeline":
        p = Pipeline.__new__(Pipeline)
        p.ctx, p.source = self.ctx, self.source
        p.nodes = self.nodes + [PlanNode(op, info, fn)]
        return p

    # ---- relational --------------------------------------------------------
    def select(self, *names):
        return self._add("select", lambda t: t.select(*names), cols=names)

    def filter(self, pred, cols: Optional[Sequence[str]] = None):
        """``cols`` declares which columns ``pred`` reads — optional, but
        required for the optimizer to push the filter past
        column-producing semantic ops."""
        info = {} if cols is None else {"cols": list(cols)}
        return self._add("filter", lambda t: t.filter(pred), **info)

    def order_by(self, key, desc=False):
        return self._add("order_by", lambda t: t.order_by(key, desc),
                         key=str(key), desc=desc,
                         key_is_callable=callable(key))

    def limit(self, n):
        return self._add("limit", lambda t: t.limit(n), n=n)

    def with_column(self, name, fn):
        return self._add(
            "project", lambda t: t.with_column(name, [fn(r)
                                                      for r in t.rows()]),
            out=name)

    # ---- semantic scalar ops -------------------------------------------------
    def llm_filter(self, model, prompt, cols: Sequence[str]):
        def fn(t: Table) -> Table:
            tuples = [{c: r[c] for c in cols} for r in t.rows()]
            mask = F.llm_filter(self.ctx, model, prompt, tuples)
            return t.filter_mask(mask)
        return self._add("llm_filter", fn, model=model, prompt=prompt,
                         cols=cols)

    def llm_complete(self, out: str, model, prompt, cols: Sequence[str]):
        def fn(t: Table) -> Table:
            tuples = [{c: r[c] for c in cols} for r in t.rows()]
            vals = F.llm_complete(self.ctx, model, prompt, tuples)
            return t.with_column(out, vals)
        return self._add("llm_complete", fn, model=model, prompt=prompt,
                         cols=cols, out=out)

    def llm_complete_json(self, out: str, model, prompt,
                          cols: Sequence[str]):
        def fn(t: Table) -> Table:
            tuples = [{c: r[c] for c in cols} for r in t.rows()]
            vals = F.llm_complete_json(self.ctx, model, prompt, tuples)
            return t.with_column(out, vals)
        return self._add("llm_complete_json", fn, model=model,
                         prompt=prompt, cols=cols, out=out)

    def llm_embedding(self, out: str, model, cols: Sequence[str]):
        def fn(t: Table) -> Table:
            tuples = [{c: r[c] for c in cols} for r in t.rows()]
            vecs = F.llm_embedding(self.ctx, model, tuples)
            return t.with_column(out, list(vecs))
        return self._add("llm_embedding", fn, model=model, cols=cols,
                         out=out)

    # ---- retrieval operators -------------------------------------------------
    def _add_retrieval(self, op: str, info: dict) -> "Pipeline":
        from .retrieval_ops import make_retrieval_fn, retrieval_outputs
        from repro.core.cache import corpus_fingerprint
        info["corpus_rows"] = len(info["corpus"])
        info["corpus_fp"] = corpus_fingerprint(
            [str(x) for x in info["corpus"].column(info["doc_col"])])
        info["outs"] = retrieval_outputs(info)
        return self._add(op, make_retrieval_fn(self.ctx, op, info), **info)

    @staticmethod
    def _ann_info(ann, recall_target, nprobe, nlist) -> dict:
        """Validated ``ann=`` plan options; {} when ANN is off (keys are
        only present when requested, so plans without the option render
        and estimate exactly as before)."""
        if ann is None:
            if any(v is not None for v in (recall_target, nprobe, nlist)):
                raise ValueError(
                    "recall_target/nprobe/nlist require ann= "
                    "('auto', 'ivf' or 'exact')")
            return {}
        if ann not in ("auto", "ivf", "exact"):
            raise ValueError(f"ann={ann!r}: expected 'auto', 'ivf', "
                             f"'exact' or None")
        out: dict = {"ann": ann}
        if recall_target is not None:
            if not 0.0 < float(recall_target) <= 1.0:
                raise ValueError("recall_target must be in (0, 1]")
            out["recall_target"] = float(recall_target)
        for name, v in (("nprobe", nprobe), ("nlist", nlist)):
            if v is not None:
                if int(v) < 1:
                    raise ValueError(f"{name} must be >= 1")
                out[name] = int(v)
        return out

    def vector_topk(self, out: str, model, query_col: str, corpus: Table,
                    k: int, doc_col: str = "text", corpus_filter=None,
                    corpus_filter_cols: Optional[Sequence[str]] = None,
                    ann: Optional[str] = None,
                    recall_target: Optional[float] = None,
                    nprobe: Optional[int] = None,
                    nlist: Optional[int] = None):
        """Paper Query 3 step 2 as a plan node: embed ``query_col``,
        scan the corpus embedding index, expand each query row into its
        top-``k`` candidate rows (corpus columns + cosine score ``out``
        + ``out_rank``).  ``corpus_filter`` restricts retrieval to
        matching corpus docs; the optimizer's ``prune_corpus`` rewrite
        then embeds only those (identical rows, fewer embed requests).

        ``ann`` opts the scan into IVF approximate search: ``"ivf"``
        forces it, ``"auto"`` lets the optimizer price the probed-list
        FLOPs against the exact scan and pick per node (choice and
        estimated recall render in ``explain()``), ``"exact"`` pins the
        exact scan while still rendering both frontiers.
        ``recall_target`` (default 0.95) sizes ``nprobe`` when it is not
        given explicitly; ``nlist`` overrides the ~sqrt(N) quantizer."""
        return self._add_retrieval("vector_topk", dict(
            out=out, model=model, query_col=query_col, corpus=corpus,
            k=k, doc_col=doc_col, corpus_filter=corpus_filter,
            corpus_filter_cols=(None if corpus_filter_cols is None
                                else list(corpus_filter_cols)),
            cols=[query_col],
            **self._ann_info(ann, recall_target, nprobe, nlist)))

    def bm25_topk(self, out: str, query_col: str, corpus: Table, k: int,
                  doc_col: str = "text", corpus_filter=None,
                  corpus_filter_cols: Optional[Sequence[str]] = None):
        """Paper Query 3 step 3 as a plan node: the BM25 FTS retriever —
        no LLM calls.  Index statistics always come from the full
        corpus, so results are independent of optimizer rewrites."""
        return self._add_retrieval("bm25_topk", dict(
            out=out, query_col=query_col, corpus=corpus, k=k,
            doc_col=doc_col, corpus_filter=corpus_filter,
            corpus_filter_cols=(None if corpus_filter_cols is None
                                else list(corpus_filter_cols)),
            cols=[query_col]))

    def hybrid_topk(self, out: str, model, query_col: str, corpus: Table,
                    k: int, fusion: str = "rrf", doc_col: str = "text",
                    candidate_k: Optional[int] = None, corpus_filter=None,
                    corpus_filter_cols: Optional[Sequence[str]] = None,
                    ann: Optional[str] = None,
                    recall_target: Optional[float] = None,
                    nprobe: Optional[int] = None,
                    nlist: Optional[int] = None):
        """Paper Query 3 steps 2-4 as one plan node: vector + BM25
        retrievers at per-retriever depth ``candidate_k``, fused with
        ``core.fusion`` (Table 1: rrf/combsum/...), final top-``k`` by
        fused score.  ``candidate_k=None`` lets the engine choose the
        depth: full candidate lists unoptimized, ``k`` pushed down to
        ``max(32, 4k)`` per retriever by the optimizer.  The ``ann``
        options (see ``vector_topk``) apply to the vector retriever;
        BM25 always scans its postings exactly."""
        return self._add_retrieval("hybrid_topk", dict(
            out=out, model=model, query_col=query_col, corpus=corpus,
            k=k, fusion=fusion, doc_col=doc_col, candidate_k=candidate_k,
            corpus_filter=corpus_filter,
            corpus_filter_cols=(None if corpus_filter_cols is None
                                else list(corpus_filter_cols)),
            cols=[query_col],
            **self._ann_info(ann, recall_target, nprobe, nlist)))

    # ---- semantic aggregates ---------------------------------------------------
    def llm_rerank(self, model, prompt, cols: Sequence[str],
                   by: Optional[str] = None):
        """Listwise LLM rerank.  Without ``by`` the whole table is one
        candidate list; with ``by`` rows rerank WITHIN each group of
        equal ``by`` values (paper Query 3 step 5 over a retrieval
        operator's expansion: one candidate list per query row), groups
        keeping their first-seen order."""
        def fn(t: Table) -> Table:
            tuples = [{c: r[c] for c in cols} for r in t.rows()]
            if by is None:
                perm = F.llm_rerank(self.ctx, model, prompt, tuples)
                return t.take(perm)
            groups: dict = {}
            for i, v in enumerate(t.column(by)):
                groups.setdefault(v, []).append(i)
            order: List[int] = []
            for idxs in groups.values():
                perm = F.llm_rerank(self.ctx, model, prompt,
                                    [tuples[i] for i in idxs])
                order.extend(idxs[p] for p in perm)
            return t.take(order)
        info = {"model": model, "prompt": prompt, "cols": cols}
        if by is not None:
            info["by"] = by
        return self._add("llm_rerank", fn, **info)

    # ---- static analysis ---------------------------------------------------
    def check(self, strict: bool = True):
        """Pre-flight static analysis of the plan *as written* — schema
        inference, catalog resolution of MODEL/PROMPT refs, prompt
        placeholder binding, and parameter validation — with **zero
        provider requests** (paper §2.1: resources are schema objects,
        so references are statically resolvable).

        Returns the list of ``analysis.Diagnostic`` findings.  With
        ``strict=True`` (default) any error-severity diagnostic raises
        ``analysis.PlanValidationError`` instead, carrying the full
        list on ``.diagnostics``."""
        from .analysis import analyze_plan
        res = analyze_plan(self.ctx, self.source, self.nodes)
        self._last_diagnostics = res.diagnostics
        if strict:
            res.raise_on_error()
        return res.diagnostics

    def _verify_preflight(self, verify: str):
        from .analysis import PlanValidationError, analyze_plan
        res = analyze_plan(self.ctx, self.source, self.nodes)
        self._last_diagnostics = list(res.diagnostics)
        if res.errors and verify == "strict":
            raise PlanValidationError(res.diagnostics)
        if verify == "warn":
            import warnings
            for d in res.diagnostics:
                warnings.warn(str(d), stacklevel=3)

    def _verify_rewrites(self, verify: str, opt):
        from .analysis import PlanValidationError, verify_rewrites
        diags = verify_rewrites(self.ctx, self.source, self.nodes, opt)
        self._last_diagnostics = (
            getattr(self, "_last_diagnostics", []) + diags)
        if diags and verify == "strict":
            raise PlanValidationError(diags)
        if verify == "warn":
            import warnings
            for d in diags:
                warnings.warn(str(d), stacklevel=3)

    # ---- execution -----------------------------------------------------------
    def _plan(self, speculate=None, objective=None):
        """Run (and memoise, per ``(speculate, objective)`` mode) the
        cost-based rewrite for the current nodes."""
        from .optimizer import optimize_plan
        if speculate is None:
            speculate = self.ctx.speculate
        if objective is None:
            objective = self.ctx.objective
        # True and "auto" produce identical plans — share one memo slot
        key = ("always" if speculate == "always"
               else "auto" if speculate else False, objective)
        plans = getattr(self, "_opt", None)
        if plans is None:
            plans = self._opt = {}
        if key not in plans:
            plans[key] = optimize_plan(self.ctx, self.source, self.nodes,
                                       speculate=speculate,
                                       objective=objective)
        return plans[key]

    # ---- concurrent node dispatch -----------------------------------------
    @staticmethod
    def _node_outs(node: PlanNode) -> List[str]:
        if node.info.get("out"):
            return [node.info["out"]]
        return list(node.info.get("outs", ()))

    @staticmethod
    def _dispatch_groups(nodes: List[PlanNode]) -> List[List[PlanNode]]:
        """Partition the plan into maximal runs of independent,
        row-preserving semantic map nodes (fused siblings included when
        they carry no filter sub-task).  Each multi-node group executes
        concurrently; everything else stays node-at-a-time."""
        def parallel_ok(node: PlanNode) -> bool:
            if node.op in _PARALLEL_MAP_OPS:
                return True
            return (node.op == "llm_fused"
                    and "filter" not in node.info.get("kinds", ()))

        groups: List[List[PlanNode]] = []
        i = 0
        while i < len(nodes):
            node = nodes[i]
            if not parallel_ok(node):
                groups.append([node])
                i += 1
                continue
            group = [node]
            produced = set(Pipeline._node_outs(node))
            j = i + 1
            while j < len(nodes):
                nxt = nodes[j]
                if not parallel_ok(nxt):
                    break
                if set(nxt.info.get("cols", ())) & produced:
                    break          # def-use dependency: must stay serial
                group.append(nxt)
                produced |= set(Pipeline._node_outs(nxt))
                j += 1
            groups.append(group)
            i = j
        return groups

    def _copack_group_ids(self, group: List[PlanNode]) -> Dict:
        """Prefix identities shared by >= 2 nodes of one dispatch
        group, mapped to how many member nodes will dispatch under each
        — the co-packable set AND rider-expectation counts this group
        activates on the context while it runs (a lone node never pays
        the packing-queue linger, and a pack whose last expected rider
        has arrived flushes immediately)."""
        counts: Dict = {}
        for node in group:
            ident = copack_identity(self.ctx, node)
            if ident is not None:
                counts[ident] = counts.get(ident, 0) + 1
        return {i: n for i, n in counts.items() if n >= 2}

    def _run_group(self, t_in: Table, group: List[PlanNode]) -> Table:
        """Execute a group of independent map nodes concurrently over one
        input table, then merge their output columns in plan order.
        Nodes sharing a metaprompt-prefix identity are registered as
        co-packable for the duration, so their part-filled tail batches
        can merge into shared provider requests."""
        results: List = [None] * len(group)
        errors: List[BaseException] = []

        def worker(k: int, node: PlanNode):
            try:
                tbl = node.fn(t_in)
                results[k] = (tbl, self.ctx.last_report_slot())
            # re-raised on the caller  # flocklint: ignore[FLKL105]
            except BaseException as exc:
                errors.append(exc)

        shared = (self._copack_group_ids(group)
                  if self.ctx.copack and self.ctx.scheduler is not None
                  else [])
        if shared:
            self.ctx.copack_begin(shared)
        try:
            # node-group fan-out, joined below; batches themselves ride
            # the scheduler pool  # flocklint: ignore[FLKL106]
            threads = [threading.Thread(target=worker, args=(k, n),
                                        name=f"flockjax-node-{n.op}")
                       for k, n in enumerate(group)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
        finally:
            if shared:
                self.ctx.copack_end(shared)
        if errors:
            raise errors[0]

        acc = t_in
        for node, (tbl, slot) in zip(group, results):
            for out in self._node_outs(node):
                acc = acc.with_column(out, tbl.column(out))
            if slot is not None:
                node.report_slot = slot
            node.info["rows_out"] = len(acc)
        return acc

    def collect(self, optimize: bool = True,
                parallel: Optional[bool] = None,
                speculate=None, objective: Optional[str] = None,
                verify: str = "off") -> Table:
        """Execute the plan.  ``optimize=False`` is the escape hatch that
        runs the nodes exactly as chained (no pushdown/fusion/reorder —
        and no speculation, which is an optimizer rewrite).

        ``parallel`` controls concurrent dispatch of independent plan
        nodes (fused siblings, adjacent map ops with no def-use edge):
        default on when the context has a ``RequestScheduler``, off
        otherwise.  Dispatch never changes which tuples a node sees, so
        results and request/token counts are identical either way.

        ``speculate`` opts ``llm_filter`` chains into concurrent
        mask-join dispatch (``False`` off, ``True``/``"auto"``
        cost-gated per chain, ``"always"`` forced); defaults to the
        context's ``speculate`` knob.  Speculation preserves the
        surviving tuple stream bit-for-bit but may issue extra requests
        over tuples a serial chain would have eliminated — the expected
        waste, predicted from recorded selectivity, is reported by
        ``explain()`` and bounded by ``ctx.speculate_waste_cap``.

        ``objective`` overrides the context's scheduling objective for
        this execution: ``"latency"`` bounds the co-pack linger by the
        calibrated expected-arrival window and ranks plan rewrites by
        estimated wall-clock, ``"cost"`` keeps the full configured
        linger (density dial) and ranks by token/request spend.

        ``verify`` runs the static analyzer (``engine/analysis.py``)
        around execution: ``"strict"`` rejects the plan with
        ``PlanValidationError`` BEFORE any provider request when
        pre-flight finds errors, and discharges every optimizer
        rewrite's soundness obligation on the optimized plan;
        ``"warn"`` emits the same findings as ``warnings`` and
        proceeds; ``"off"`` (default) skips analysis entirely."""
        if parallel is None:
            parallel = self.ctx.scheduler is not None
        if speculate is None:
            speculate = self.ctx.speculate
        if objective is not None and objective not in ("latency", "cost"):
            raise ValueError("objective must be 'latency' or 'cost', "
                             f"got {objective!r}")
        if verify not in ("off", "warn", "strict"):
            raise ValueError("verify must be 'off', 'warn' or "
                             f"'strict', got {verify!r}")
        if verify != "off":
            # pre-flight BEFORE planning/execution: an invalid plan is
            # rejected with zero provider requests
            self._verify_preflight(verify)
        if optimize:
            # remembered for explain(); an optimize=False run bypasses
            # the optimizer entirely, so recording its speculate mode
            # would make explain() describe a plan that never ran
            self._last_speculate = speculate
        # the override must reach runtime decisions (ctx.copack_linger)
        # taken on worker threads mid-execution, so it is installed on
        # the context for the duration and restored afterwards
        prev_objective = self.ctx.objective
        if objective is not None:
            self.ctx.objective = objective
        try:
            if optimize:
                opt = self._plan(speculate)
                if verify != "off":
                    # discharge the optimizer's soundness obligations
                    # on the rewritten plan before it executes
                    self._verify_rewrites(verify, opt)
                nodes = opt.nodes
            else:
                nodes = self.nodes
            self._executed_nodes = nodes
            self._executed_optimized = optimize
            t = self.source
            base = len(self.ctx.reports)
            groups = (self._dispatch_groups(nodes) if parallel
                      else [[n] for n in nodes])
            try:
                for group in groups:
                    if len(group) > 1:
                        t = self._run_group(t, group)
                        continue
                    node = group[0]
                    if node.fn is not None:
                        before = len(self.ctx.reports)
                        t = node.fn(t)
                        # spec-chain members append reports from their
                        # own threads and record the slots themselves;
                        # the main thread's thread-local slot would be
                        # stale here
                        if (len(self.ctx.reports) > before
                                and "member_report_slots"
                                not in node.info):
                            slot = self.ctx.last_report_slot()
                            node.report_slot = (before if slot is None
                                                else slot)
                        node.info["rows_out"] = len(t)
            finally:
                # bookkeeping + debounced sidecars survive node errors:
                # earlier filters' observations would otherwise be lost
                self._last_reports = self.ctx.reports[base:]
                self.ctx.flush_stats()
        finally:
            self.ctx.objective = prev_objective
        return t

    def reduce(self, model, prompt, cols: Sequence[str],
               optimize: bool = True):
        t = self.collect(optimize=optimize)
        tuples = [{c: r[c] for c in cols} for r in t.rows()]
        return F.llm_reduce(self.ctx, model, prompt, tuples)

    # ---- plan inspection -----------------------------------------------------
    def _render_report(self, lines, slot, indent="        "):
        r = self.ctx.reports[slot]
        sel = ("" if r.selectivity is None
               else f" selectivity={r.selectivity:.2f}")
        coal = ("" if not r.coalesced
                else f" coalesced={r.coalesced}")
        packed = ("" if not r.packed
                  else f" packed={r.packed}")
        lines.append(
            f"{indent}tuples={r.n_tuples} unique={r.n_unique} "
            f"cache_hits={r.cache_hits} requests={r.requests} "
            f"retries={r.retries} nulls={r.nulls} "
            f"batch_sizes={r.batch_sizes[:8]} "
            f"serialization={r.serialization}{sel}{coal}{packed}")

    def _render_nodes(self, lines, nodes, node_costs):
        for i, node in enumerate(nodes):
            info = {k: v for k, v in node.info.items()
                    if k not in ("model", "prompt", "prompts",
                                 "prompt_ids", "member_specs",
                                 "member_masks", "member_report_slots",
                                 "corpus", "corpus_filter", "outs")
                    and not k.startswith("_")}
            est = node_costs[i] if i < len(node_costs) else None
            est_s = ""
            if est and (est["requests"] or est.get("scan_flops")):
                est_s = (f"  est[rows->{est['rows']} "
                         f"req={est['requests']} tok={est['tokens']}")
                if est.get("scan_flops"):
                    est_s += f" scan_flops={est['scan_flops']:.2e}"
                est_s += "]"
            ann = est.get("ann") if est else None
            if ann:
                est_s += (f" ann[{ann['choice']} nlist={ann['nlist']} "
                          f"nprobe={ann['nprobe']} "
                          f"est_recall={ann['recall_est']:.2f} "
                          f"ivf_flops={ann['ivf_flops']:.2e} "
                          f"exact_flops={ann['exact_flops']:.2e}]")
            lines.append(f"  [{i}] {node.op:18s} {info}{est_s}")
            if node.report_slot is not None:
                self._render_report(lines, node.report_slot)
            for k, slot in enumerate(
                    node.info.get("member_report_slots", ())):
                if slot is not None:
                    lines.append(f"        member[{k}]:")
                    self._render_report(lines, slot, indent="          ")

    def explain(self, speculate=None) -> str:
        """Render the logical plan, the optimizer's rewritten plan, the
        fired rewrite rules, and both plans' estimated request/token
        totals (paper Fig. 2b, now with the optimizer's decisions).

        With speculation on (``speculate`` argument, the last
        ``collect()``'s mode, or the context knob — first set wins),
        a "Speculation:" section reports each ``llm_filter`` chain's
        serial-waves vs speculative-waves estimates, the calibrated
        wall-clock predictions when execution statistics exist, and the
        expected wasted-request budget."""
        if speculate is None:
            speculate = getattr(self, "_last_speculate", None)
        opt = self._plan(speculate)
        lines = ["Pipeline plan (as written):"]
        self._render_nodes(lines, self.nodes, opt.naive_node_costs)
        lines.append(f"  estimated: {opt.naive_cost}")
        lines.append("Optimized plan:")
        self._render_nodes(lines, opt.nodes, opt.optimized_node_costs)
        lines.append(f"  estimated: {opt.optimized_cost}")
        from .analysis import infer_schema
        lines.append("Inferred schema (optimized plan):")
        for i, (node, sch) in enumerate(
                zip(opt.nodes, infer_schema(self.source, opt.nodes))):
            lines.append(f"  [{i}] {node.op:18s} -> {sch.render()}")
        if opt.frontiers:
            # both scheduling frontiers of the optimized plan: the
            # co-packed request count is free under "latency" (last-
            # tail-out), while "cost" may spend up to the configured
            # linger per packed group waiting for denser merges
            lines.append("Objectives:")
            for name in ("latency", "cost"):
                fr = opt.frontiers.get(name)
                if fr is None:
                    continue
                wall = ("est_wall=uncalibrated"
                        if fr["est_wall"] is None
                        else f"est_wall={fr['est_wall']:.3f}s")
                star = "  <- active" if name == opt.objective else ""
                lines.append(f"  {name}: packed_req={fr['packed_req']} "
                             f"{wall}{star}")
        if opt.rewrites:
            lines.append("Rewrites applied:")
            for rw in opt.rewrites:
                lines.append(f"  - {rw}")
        else:
            lines.append("Rewrites applied: none")
        if opt.spec_decisions:
            lines.append("Speculation:")
            for d in opt.spec_decisions:
                lines.append(f"  - {d}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# ASK: natural language -> pipeline (deterministic template planner)
# ---------------------------------------------------------------------------
_SEVERITY = re.compile(r"\b(severity|score|rate|rating)\b", re.I)
_FILTER = re.compile(r"\b(mention\w*|about|related to|regarding)\s+(.+?)"
                     r"(?:\s+and\b|[.,]|$)", re.I)
_SUMMARIZE = re.compile(r"\b(summari[sz]e|overview)\b", re.I)


def ask(ctx: SemanticContext, table: Table, question: str,
        model={"model": "ask-default", "context_window": 8192},
        text_cols: Optional[Sequence[str]] = None):
    """NL question -> (generated pseudo-SQL, Pipeline).  DEMO-ONLY planner."""
    cols = list(text_cols or table.column_names)
    pipe = Pipeline(ctx, table, name="ask")
    sql = [f"SELECT * FROM t"]
    m = _FILTER.search(question)
    if m:
        topic = m.group(2).strip()
        pipe = pipe.llm_filter(model, {"prompt": f"is about {topic}"}, cols)
        sql.append(f"WHERE llm_filter(..., 'is about {topic}', "
                   f"{{{', '.join(cols)}}})")
    if _SEVERITY.search(question):
        pipe = pipe.llm_complete_json(
            "assessment", model,
            {"prompt": 'extract {"issue": <short>, "severity": <1-5>}'},
            cols)
        sql.append("SELECT *, llm_complete_json(..., 'severity json', ...)")
    if _SUMMARIZE.search(question):
        pipe = pipe.llm_complete("summary", model,
                                 {"prompt": "summarize in one sentence"},
                                 cols)
        sql.append("SELECT *, llm_complete(..., 'summarize', ...)")
    return "\n".join(sql), pipe
