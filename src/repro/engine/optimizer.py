"""Cost-based semantic plan optimizer (paper §2.3, "seamless" tier).

FlockMTL's pitch is that LLM-backed relational plans get optimized below
the query surface: the user chains operators in whatever order reads
naturally, and the engine re-orders and fuses them so the model sees as
few tuples — and as few requests — as possible.  This module implements
that rewrite layer for ``Pipeline`` plans.  Three rules run in sequence:

1. **Pushdown** — cheap relational ops (``filter``, ``limit``, ``select``,
   key-independent ``order_by``) bubble *toward the scan*, past semantic
   ops they commute with, so LLM calls see fewer tuples:

   * ``limit`` commutes with per-row map ops (``llm_complete``,
     ``llm_complete_json``, ``llm_embedding``, ``project``) — they preserve
     row count and order.  It never crosses ``llm_filter`` / ``order_by`` /
     ``llm_rerank``.
   * relational ``filter`` commutes with ``llm_filter`` (conjunctive
     predicates) and — when its column dependencies are declared via
     ``Pipeline.filter(pred, cols=...)`` — with map ops whose output
     column it does not read.
   * ``select`` crosses ``llm_filter``/``llm_rerank`` when it retains
     their input columns.
   * ``order_by`` with a string key crosses map ops that don't produce
     that key, and ``llm_filter`` (stable sort of a subset == subset of
     the stable-sorted whole).

2. **Semantic fusion** — adjacent ``llm_filter``/``llm_complete``/
   ``llm_complete_json`` nodes sharing one model and one input-column set
   (and with no def-use dependency between them) merge into a single
   ``llm_fused`` node that answers all sub-tasks in one metaprompt pass
   (``core.functions.llm_multi``, kind ``multi``).

3. **Cost-ordered filter chains** — runs of consecutive ``llm_filter``
   nodes are re-ordered by estimated per-tuple token cost x expected
   selectivity (cheap, selective filters first), using
   ``provider.estimate_tokens`` and the per-prompt pass rates recorded in
   ``SemanticContext.selectivity_stats``.

4. **Speculative pipelining** (opt-in via the context/``collect()``
   ``speculate`` knob) — dependent plan edges overlap instead of
   queueing, in three shapes:

   * **filter chains** — a cost-ordered ``llm_filter`` chain normally
     pays one provider round-trip PER member, because member k+1 waits
     for member k's survivors.  The optimizer may replace the chain
     with one ``llm_spec_chain`` node that fans a chosen *prefix* of
     members out over the chain's input stream concurrently
     (``core.scheduler.SpeculativeJoin``) and ANDs the masks, keeping
     the expensive tail serial over the prefix's survivors — the split
     point is the one minimizing the wall estimate under the waste
     cap (``split == len(chain)`` reproduces PR 3's all-or-nothing
     fan-out).
   * **map past filter** — an ``llm_complete``/``llm_complete_json``
     node downstream of an ``llm_filter`` (or spec chain) dispatches
     completions for the filter's INPUT rows concurrently with the
     mask (``llm_spec_map``).  Chunks whose rows the resolved mask
     proves dead are cancelled before dispatch; completed values for
     masked-out rows are discarded from the output but still land in
     the prediction cache.
   * **retrieval-aware rerank** — ``llm_rerank`` downstream of
     ``hybrid_topk`` starts reranking the BM25-predicted per-query
     candidate lists while the dense retriever and fusion finish
     (``spec_rerank``), warming the rerank window cache; the
     authoritative pass over the final top-k reconciles via cache
     hits, so outputs are bit-identical by construction.

   Every decision is per edge: expected wasted requests are predicted
   from recorded selectivity and must stay within
   ``speculate_waste_cap`` x the serial request count (widened 1.25x
   under the ``latency`` objective, narrowed 0.8x under ``cost``), and
   the speculative plan must win on the **calibrated** wall-clock
   estimate (observed per-request latency percentiles and retry rates
   from the ``CalibrationStore``; plain ``waves`` comparison when
   uncalibrated).  ``speculate="always"`` forces eligible edges
   regardless (equivalence tests, benchmarks).

The cost model is *calibrated* when execution statistics exist:
per-request latency percentiles turn ``waves`` into an ``est_wall``
seconds estimate, observed overflow-retry rates inflate request counts,
and observed mean batch sizes replace the flat default width for
columns produced mid-plan that cannot be sampled from the source.

``optimize_plan`` is pure planning: it returns new ``PlanNode`` lists
(fused/speculative nodes carry fresh closures) plus a cost estimate of
both plans — nothing executes until ``Pipeline.collect()`` runs the
rewritten plan.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence, Tuple

from repro.core import functions as F
from repro.core.batching import plan_batches
from repro.core.functions import SemanticContext
from repro.core.metaprompt import build_multi_task, build_prefix, \
    serialize_tuple
from repro.core.provider import estimate_tokens

from repro.retrieval.ivf import (IVF_MIN_DOCS, default_nlist,
                                 ivf_scan_flops, planned_nprobe,
                                 planned_recall)
from repro.retrieval.vector import DEFAULT_RECALL_TARGET

from .analysis import Obligation, semantic_key
from .retrieval_ops import RETRIEVAL_OPS, pushed_candidate_k
from .table import Table

# node taxonomy --------------------------------------------------------------
SEMANTIC_MAP_OPS = ("llm_complete", "llm_complete_json", "llm_embedding")
SEMANTIC_OPS = SEMANTIC_MAP_OPS + ("llm_filter", "llm_rerank", "llm_fused")
RELATIONAL_OPS = ("filter", "limit", "select", "order_by")
FUSABLE = {"llm_filter": "filter", "llm_complete": "complete",
           "llm_complete_json": "complete_json"}

# default pass rate assumed for predicates with no recorded statistics
DEFAULT_SELECTIVITY = 0.5
# token estimate for a column whose width we cannot sample (produced
# mid-plan by an earlier semantic op)
DEFAULT_COL_TOKENS = 16
_SAMPLE_ROWS = 32


@dataclass
class PlanCost:
    """Estimated provider-side cost of one plan.

    ``waves`` is the critical-path latency estimate for the concurrent
    scheduler: per node, ``ceil(requests / model.max_concurrency)``
    request round-trips must run back-to-back (the scheduler overlaps
    everything else), summed over the sequential node chain.  With the
    serial executor (``scheduler=None``) the critical path is simply
    ``requests``.

    ``wall_s`` is the calibrated wall-clock estimate: waves multiplied
    by each model's observed per-request latency percentile (p50 from
    the ``CalibrationStore``).  It is 0.0 when any contributing model
    has no recorded statistics — uncalibrated, not "instant".

    ``wasted_requests`` is the expected speculative-dispatch waste: the
    requests a chosen ``llm_spec_chain`` issues over tuples a serial
    chain would have eliminated, predicted from recorded selectivity
    (0 for plans without chosen speculation).

    ``packed_requests`` is the request estimate WITH cross-node batch
    co-packing: same-prefix map nodes of one dispatch group merge their
    part-filled tail batches, so the packed estimate plans their tuples
    as one stream (0 when no dispatch group co-packs — the plain
    ``requests`` estimate stands).

    ``tokens`` counts estimated PROMPT tokens (tuple payloads + one
    prefix per request); expected output tokens shape the batch plans
    but are not part of the token totals.

    ``scan_flops`` is the retrieval operators' index-scan cost estimate
    (vector scan ~ 2*N*D per query, BM25 postings scan ~ N per query,
    fusion ~ N per query) — provider-free work, reported separately so
    ``explain()`` shows a RAG plan's full retrieval cost next to its
    embed requests.

    ``pack_wait_s`` is the worst-case co-pack linger spend: one full
    configured linger window per dispatch group with packed savings.
    Under the latency objective the scheduler's last-tail-out flush
    makes this ~0 on the critical path (riders arrive together); under
    the cost objective the plan may actually pay it — the two
    ``est_wall`` frontiers ``explain()`` reports differ by exactly this
    term."""
    requests: int = 0
    tokens: int = 0
    rows_into_llm: int = 0      # tuples fed to semantic ops, post-dedup-free
    waves: int = 0              # critical-path request waves (concurrent)
    wall_s: float = 0.0         # calibrated latency estimate (0 = no data)
    wasted_requests: int = 0    # expected speculative-request overshoot
    packed_requests: int = 0    # request estimate with tail co-packing
    scan_flops: float = 0.0     # retrieval index-scan cost (non-provider)
    pack_wait_s: float = 0.0    # worst-case co-pack linger (cost frontier)
    # exact-vs-ANN pricing of a retrieval scan: both frontiers plus the
    # choice, set only on nodes with an ``ann=`` plan option (explain()
    # renders it; totals aggregate only the chosen frontier's flops)
    ann: Optional[dict] = None

    def __str__(self):
        s = (f"requests={self.requests} tokens={self.tokens} "
             f"llm_rows={self.rows_into_llm} waves={self.waves}")
        if self.wall_s:
            s += f" est_wall={self.wall_s:.3f}s"
        if self.wasted_requests:
            s += f" wasted_requests={self.wasted_requests}"
        if self.packed_requests and self.packed_requests != self.requests:
            s += f" packed_req={self.packed_requests}"
        if self.scan_flops:
            s += f" scan_flops={self.scan_flops:.2e}"
        return s


@dataclass
class SpeculationDecision:
    """Record of one per-edge speculative-dispatch decision: the serial
    vs speculative waves/wall estimates, the expected wasted-request
    budget, and whether the planner chose speculation.  ``kind`` names
    the speculation shape (``chain`` / ``map`` / ``rerank``); for
    chains ``split`` is the number of prefix members speculated (0 or
    ``len(members)`` = the whole chain)."""
    members: List[str]                  # member prompt identities
    rows_in: int = 0
    serial_requests: int = 0
    spec_requests: int = 0
    serial_waves: int = 0
    spec_waves: int = 0
    wasted_requests: int = 0            # expected extra requests (budget)
    serial_wall_s: float = 0.0          # calibrated; 0.0 = uncalibrated
    spec_wall_s: float = 0.0
    chosen: bool = False
    reason: str = ""
    kind: str = "chain"                 # chain | map | rerank
    split: int = 0                      # chain: speculated prefix length

    def __str__(self):
        if self.kind == "map":
            head = f"map past filter over {self.rows_in} rows"
        elif self.kind == "rerank":
            head = (f"rerank over retrieval "
                    f"({self.rows_in} candidate rows)")
        else:
            head = f"chain of {len(self.members)} over {self.rows_in} rows"
            if 0 < self.split < len(self.members):
                head += f" (spec prefix {self.split})"
        walls = ""
        if self.serial_wall_s or self.spec_wall_s:
            walls = (f" serial_wall={self.serial_wall_s:.3f}s "
                     f"spec_wall={self.spec_wall_s:.3f}s")
        return (f"{head}: "
                f"serial_waves={self.serial_waves} "
                f"spec_waves={self.spec_waves}{walls} "
                f"wasted<={self.wasted_requests} "
                f"-> {'SPECULATE' if self.chosen else 'serial'} "
                f"({self.reason})")


@dataclass
class OptimizedPlan:
    nodes: List[Any]                    # rewritten PlanNode list
    rewrites: List[str] = field(default_factory=list)
    naive_cost: PlanCost = field(default_factory=PlanCost)
    optimized_cost: PlanCost = field(default_factory=PlanCost)
    # per-node {rows, requests, tokens} estimates, aligned with the
    # original and rewritten node lists (PlanNodes are shared between the
    # two plans, so estimates live here, not on node.info)
    naive_node_costs: List[dict] = field(default_factory=list)
    optimized_node_costs: List[dict] = field(default_factory=list)
    # one entry per llm_filter chain considered for speculation
    spec_decisions: List[SpeculationDecision] = field(default_factory=list)
    # the objective the rewrite gates ranked under, and both scheduling
    # frontiers of the optimized plan: {"latency"|"cost": {"packed_req",
    # "est_wall"}} with est_wall None when uncalibrated
    objective: str = "latency"
    frontiers: dict = field(default_factory=dict)
    # machine-checkable soundness claims, one or more per applied
    # rewrite, discharged by ``analysis.verify_rewrites`` on the
    # optimized plan (``collect(verify="strict")`` runs it)
    obligations: List[Obligation] = field(default_factory=list)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
def _avg_tuple_tokens(source: Table, cols: Sequence[str],
                      serialization: str) -> int:
    """Mean serialized-tuple token cost, sampled from the source table.

    Columns produced mid-plan (not present at the scan) are charged a
    flat default width."""
    known = [c for c in cols if c in source.columns]
    missing = len(cols) - len(known)
    if not known:
        return max(1, missing * DEFAULT_COL_TOKENS)
    n = min(len(source), _SAMPLE_ROWS)
    if n == 0:
        return max(1, missing * DEFAULT_COL_TOKENS)
    total = 0
    for i in range(n):
        tup = {c: source.columns[c][i] for c in known}
        total += estimate_tokens(serialize_tuple(tup, serialization))
    return max(1, total // n + missing * DEFAULT_COL_TOKENS)


def _node_prompt_text(ctx: SemanticContext, node) -> Tuple[str, str]:
    """(prompt_text, prompt_id) for a semantic node, '' for non-LLM ops."""
    spec = node.info.get("prompt")
    if spec is None:
        return "", ""
    return ctx.resolve_prompt(spec)


def _fused_prompt_text(ctx: SemanticContext, node) -> str:
    kinds = node.info["kinds"]
    texts = [ctx.resolve_prompt(p)[0] for p in node.info["prompts"]]
    return build_multi_task(kinds, texts)


def _calibrated_requests(ctx: SemanticContext, model, n_rows: int,
                         plan_requests: int, sampled: bool) -> int:
    """Correct a batch-plan request estimate with recorded execution
    statistics: when the tuple width could not be sampled from the
    source (columns produced mid-plan), fall back to the model's
    observed mean batch size; always inflate by the observed
    overflow-retry rate (a model that routinely overflows pays more
    requests than the plan alone predicts)."""
    req = plan_requests
    rec = ctx.calibration_stats.get(model.ref)
    if not sampled and rec and rec["requests"]:
        mean_bs = max(1.0, rec["tuples"] / rec["requests"])
        req = max(req, math.ceil(n_rows / mean_bs))
    retry_rate = ctx.calibrated_retry_rate(model.ref)
    if retry_rate:
        req = math.ceil(req * (1.0 + retry_rate))
    return req


def _per_model_waves(entries) -> Tuple[int, Optional[float]]:
    """Reduce per-model ``(requests, limit, latency|None)`` entries to
    the concurrent critical path: models fan out on independent gates,
    so waves = max over models of ``ceil(requests / limit)``, and the
    calibrated wall is the slowest model's ``waves x latency`` — or
    ``None`` when any contributing model has no recorded latency."""
    waves = 0
    wall: Optional[float] = 0.0
    for req, limit, lat in entries:
        if not req:
            continue
        w = -(-req // limit)
        waves = max(waves, w)
        if lat is None:
            wall = None
        elif wall is not None:
            wall = max(wall, w * lat)
    return waves, wall


def _filter_estimate(ctx: SemanticContext, member: dict, n: int,
                     source: Table, kind: str = "filter") -> Tuple[int, int]:
    """(requests, tokens) estimate for one per-row semantic evaluation —
    ``member`` carries ``model``/``prompt``/``cols`` specs, ``kind`` the
    metaprompt flavour (``filter``/``complete``/``complete_json``) —
    over ``n`` tuples, with the calibrated request correction applied."""
    if n <= 0:
        return 0, 0
    model = ctx.resolve_model(member["model"])
    per_tuple = _avg_tuple_tokens(source, member.get("cols", ()),
                                  ctx.serialization)
    prompt_text, _ = ctx.resolve_prompt(member["prompt"])
    prefix_tokens = estimate_tokens(
        build_prefix(kind, prompt_text, ctx.serialization))
    plan = plan_batches([per_tuple] * n, prefix_tokens,
                        model.context_window, model.max_output_tokens,
                        ctx.max_batch if ctx.enable_batching else 1,
                        headroom=ctx.batch_headroom(model.ref))
    sampled = any(c in source.columns for c in member.get("cols", ()))
    requests = _calibrated_requests(ctx, model, n, len(plan.batches),
                                    sampled)
    tokens = sum(plan.est_tokens) + len(plan.batches) * prefix_tokens
    if len(plan.batches):
        tokens = int(tokens * requests / len(plan.batches))
    return requests, tokens


def _avg_text_tokens(values) -> int:
    """Mean token estimate of raw text values (corpus docs, query
    strings), sampled like ``_avg_tuple_tokens``."""
    vals = list(values)[:_SAMPLE_ROWS]
    if not vals:
        return 1
    return max(1, sum(estimate_tokens(str(v)) for v in vals) // len(vals))


# ANN auto-select: IVF must undercut the exact scan by at least this
# factor before the optimizer switches a node off the exact path — the
# quantizer build and the recall risk are not worth a marginal win
ANN_FLOPS_ADVANTAGE = 0.5


def _ann_decision(ctx: SemanticContext, info: dict, model_ref: str,
                  docs: int) -> dict:
    """Resolve a node's ``ann=`` plan option over a ``docs``-row scan:
    {choice, nlist, nprobe, recall_target, recall_est, calibrated}.

    ``nlist``/``nprobe`` honour explicit plan options, defaulting to
    ~sqrt(N) lists and the smallest probe count whose recall estimate
    meets the target.  The estimate uses a session-built index's
    calibrated recall curve when one exists, else the planning prior.
    ``ann="ivf"`` forces IVF and ``"exact"`` the exact scan; ``"auto"``
    picks IVF iff the corpus is big enough, the recall estimate meets
    the target, and the probed FLOPs undercut the exact scan by
    ``ANN_FLOPS_ADVANTAGE`` — a per-query ratio, so the choice is
    independent of how many queries flow in."""
    mode = info.get("ann")
    target = float(info.get("recall_target") or DEFAULT_RECALL_TARGET)
    nlist = int(info.get("nlist") or default_nlist(docs))
    nlist = max(1, min(nlist, max(docs, 1)))
    ivf = None
    if not info.get("prune_corpus") and info.get("corpus_fp"):
        idx = ctx.lookup_index((model_ref, info["corpus_fp"]))
        built = getattr(idx, "_ivf", None)
        if built is not None and (info.get("nlist") is None
                                  or built.nlist == nlist):
            ivf, nlist = built, built.nlist
    nprobe = info.get("nprobe")
    if nprobe is None:
        nprobe = (ivf.nprobe_for(target) if ivf is not None
                  else planned_nprobe(nlist, target))
    nprobe = max(1, min(int(nprobe), nlist))
    recall = (ivf.estimated_recall(nprobe) if ivf is not None
              else planned_recall(nprobe, nlist))
    if mode == "ivf":
        choice = "ivf"
    elif mode == "exact":
        choice = "exact"
    else:                                   # auto
        ratio = (nlist + docs * nprobe / nlist) / max(docs, 1) / 2.0
        choice = ("ivf" if docs >= IVF_MIN_DOCS and recall >= target
                  and ratio <= ANN_FLOPS_ADVANTAGE else "exact")
    return {"choice": choice, "nlist": nlist, "nprobe": nprobe,
            "recall_target": target, "recall_est": float(recall),
            "calibrated": ivf is not None}


def _ann_frontiers(ctx: SemanticContext, info: dict, model_ref: str,
                   nq: int, docs: int, dim: int) -> Optional[dict]:
    """Both priced scan frontiers for a node with an ``ann=`` option
    (None otherwise): the resolved choice plus exact and IVF FLOPs."""
    if not info.get("ann"):
        return None
    if info.get("ann_resolved"):
        dec = {"choice": info["ann_resolved"],
               "nlist": info["ann_nlist"], "nprobe": info["ann_nprobe"],
               "recall_target": float(info.get("recall_target")
                                      or DEFAULT_RECALL_TARGET),
               "recall_est": info["ann_recall_est"],
               "calibrated": bool(info.get("ann_calibrated"))}
    else:
        dec = _ann_decision(ctx, info, model_ref, docs)
        if info["ann"] == "auto":
            # an unresolved auto executes the exact scan — the naive
            # plan prices that, so explain() shows the optimized plan
            # dropping the scan FLOPs when ann_select picks IVF
            dec["choice"] = "exact"
    dec = dict(dec)
    dec["exact_flops"] = 2.0 * nq * docs * dim
    dec["ivf_flops"] = ivf_scan_flops(nq, docs, dim, dec["nlist"],
                                      dec["nprobe"])
    return dec


def _retrieval_estimate(ctx: SemanticContext, node, rows_in: float,
                        source: Table,
                        seen_corpus: set) -> Tuple[float, PlanCost]:
    """(rows_out, cost) for a retrieval operator.

    Embed requests come from ``plan_batches`` over the corpus + query
    text streams (no output tokens, calibrated per-model headroom);
    a corpus whose index is memoised — by an earlier node of this plan
    (``seen_corpus``), the session registry, or the ``IndexStore``
    sidecar — charges the query embeds only.  ``scan_flops`` covers the
    provider-free index-scan work, and ``packed_requests`` the embed
    estimate with corpus/query tail co-packing."""
    op, info = node.op, node.info
    cost = PlanCost()
    nq = max(int(round(rows_in)), 0)
    corpus_rows = info.get("corpus_rows", len(info["corpus"]))
    sel_rows = corpus_rows
    if info.get("corpus_filter") is not None:
        sel_rows = max(1, int(round(corpus_rows * DEFAULT_SELECTIVITY)))
    rows_out = float(nq * min(info["k"], sel_rows))
    if nq == 0 or corpus_rows == 0:
        return rows_out, cost

    if op != "vector_topk":         # bm25 or hybrid: postings scan
        cost.scan_flops += float(nq * corpus_rows)
    if op == "hybrid_topk":         # fusion over full-length arrays
        cost.scan_flops += float(nq * corpus_rows)
    if op == "bm25_topk":
        return rows_out, cost

    model = ctx.resolve_model(info["model"])
    dim = model.embedding_dim or 64
    scan_docs = sel_rows if info.get("prune_corpus") else corpus_rows
    exact_flops = 2.0 * nq * scan_docs * dim
    ann = _ann_frontiers(ctx, info, model.ref, nq, scan_docs, dim)
    if ann is not None:
        cost.ann = ann
        cost.scan_flops += (ann["ivf_flops"] if ann["choice"] == "ivf"
                            else ann["exact_flops"])
    else:
        cost.scan_flops += exact_flops

    per_doc = _avg_text_tokens(info["corpus"].column(info["doc_col"]))
    qcol = info.get("query_col")
    per_q = (_avg_text_tokens(source.columns[qcol])
             if qcol in source.columns else DEFAULT_COL_TOKENS)
    key = (model.ref, info.get("corpus_fp"), bool(info.get(
        "prune_corpus")) and info.get("corpus_filter") is not None)
    cached = key in seen_corpus
    if not cached and not key[2] and info.get("corpus_fp"):
        cached = ctx.index_cached(model.ref, info["corpus_fp"])
    embed_docs = 0 if cached else (
        sel_rows if info.get("prune_corpus") else corpus_rows)
    seen_corpus.add(key)

    mb = ctx.max_batch if ctx.enable_batching else 1
    headroom = ctx.batch_headroom(model.ref)
    window = model.context_window
    corpus_costs = [per_doc] * embed_docs
    query_costs = [per_q] * nq
    requests, tokens = 0, 0
    for costs in (corpus_costs, query_costs):
        if not costs:
            continue
        plan = plan_batches(costs, 0, window, 0, mb, headroom=headroom)
        requests += len(plan.batches)
        tokens += sum(plan.est_tokens)
    cost.requests = requests
    cost.tokens = tokens
    cost.rows_into_llm = embed_docs + nq
    limit = max(1, getattr(model, "max_concurrency", 1) or 1)
    cost.waves = -(-requests // limit) if requests else 0
    copack_on = (getattr(ctx, "copack", False)
                 and ctx.scheduler is not None and ctx.enable_batching)
    if copack_on and corpus_costs and query_costs:
        joint = plan_batches(corpus_costs + query_costs, 0, window, 0,
                             mb, headroom=headroom)
        if len(joint.batches) < requests:
            cost.packed_requests = len(joint.batches)
    return rows_out, cost


def estimate_node_cost(ctx: SemanticContext, node, rows_in: float,
                       source: Table,
                       seen_corpus: Optional[set] = None
                       ) -> Tuple[float, PlanCost]:
    """(rows_out, provider cost) for one node under the cost model.

    Cardinalities flow through: relational filters halve, llm_filters use
    recorded selectivity, limit truncates, maps preserve, retrieval
    operators expand to k rows per query.  ``seen_corpus`` threads the
    shared-corpus embed dedupe across the nodes of one plan."""
    op, info = node.op, node.info
    cost = PlanCost()
    rows = rows_in

    if op == "filter":
        return rows * DEFAULT_SELECTIVITY, cost
    if op == "limit":
        return min(rows, info.get("n", rows)), cost
    if op in ("select", "order_by", "project", "scan"):
        return rows, cost

    if op in RETRIEVAL_OPS:
        return _retrieval_estimate(ctx, node, rows, source,
                                   set() if seen_corpus is None
                                   else seen_corpus)

    if op == "llm_spec_chain":
        # speculative mask-join: the speculated prefix runs over the
        # full chain input with per-model waves (members of different
        # models fan out on independent gates, same-model members share
        # one); serial tail members queue behind it over the prefix's
        # survivors
        n = int(round(rows))
        if n <= 0:
            return 0.0, cost
        members = info["member_specs"]
        split = info.get("split") or len(members)
        per_model: dict = {}        # ref -> [requests, limit, latency]
        tail_waves, tail_wall = 0, 0.0
        tail_calibrated = True
        for k, member in enumerate(members):
            model = ctx.resolve_model(member["model"])
            limit = max(1, getattr(model, "max_concurrency", 1) or 1)
            lat = ctx.calibrated_latency(model.ref)
            if k < split:
                req, tok = _filter_estimate(ctx, member, n, source)
                cost.rows_into_llm += n
                entry = per_model.setdefault(model.ref, [0, limit, lat])
                entry[0] += req
                entry[1] = min(entry[1], limit)
            else:
                m = int(round(rows))
                req, tok = _filter_estimate(ctx, member, m, source)
                cost.rows_into_llm += m
                w = -(-req // limit) if req else 0
                tail_waves += w
                if lat is None:
                    tail_calibrated = False
                else:
                    tail_wall += w * lat
            cost.requests += req
            cost.tokens += tok
            _, pid = ctx.resolve_prompt(member["prompt"])
            rows = rows * ctx.expected_selectivity(pid,
                                                   DEFAULT_SELECTIVITY)
        waves, wall = _per_model_waves(per_model.values())
        cost.waves = waves + tail_waves
        cost.wall_s = (wall + tail_wall
                       if wall is not None and tail_calibrated else 0.0)
        return rows, cost

    if op == "llm_spec_map":
        # map-past-filter: filter members and the downstream map all
        # run over the node's full input concurrently; the critical
        # path is the slowest model's wave count
        n = int(round(rows))
        if n <= 0:
            return 0.0, cost
        per_model = {}
        for member in info["member_specs"]:
            model = ctx.resolve_model(member["model"])
            limit = max(1, getattr(model, "max_concurrency", 1) or 1)
            req, tok = _filter_estimate(ctx, member, n, source)
            cost.requests += req
            cost.tokens += tok
            cost.rows_into_llm += n
            entry = per_model.setdefault(
                model.ref, [0, limit, ctx.calibrated_latency(model.ref)])
            entry[0] += req
            entry[1] = min(entry[1], limit)
            _, pid = ctx.resolve_prompt(member["prompt"])
            rows = rows * ctx.expected_selectivity(pid,
                                                   DEFAULT_SELECTIVITY)
        map_spec = {"model": info["model"], "prompt": info["prompt"],
                    "cols": info.get("cols", ())}
        mkind = ("complete_json" if info.get("map_op") ==
                 "llm_complete_json" else "complete")
        req, tok = _filter_estimate(ctx, map_spec, n, source, kind=mkind)
        model = ctx.resolve_model(info["model"])
        limit = max(1, getattr(model, "max_concurrency", 1) or 1)
        cost.requests += req
        cost.tokens += tok
        cost.rows_into_llm += n
        entry = per_model.setdefault(
            model.ref, [0, limit, ctx.calibrated_latency(model.ref)])
        entry[0] += req
        entry[1] = min(entry[1], limit)
        cost.waves, wall = _per_model_waves(per_model.values())
        cost.wall_s = wall or 0.0
        return rows, cost

    if op == "spec_rerank":
        # retrieval + rerank warmup overlap: the retrieval's embeds and
        # the BM25-predicted rerank windows run concurrently; the
        # authoritative pass reconciles through the window cache
        shim = node.__class__(info["retr_op"], info["_retr"])
        rows_out, cost = _retrieval_estimate(
            ctx, shim, rows, source,
            set() if seen_corpus is None else seen_corpus)
        n = int(round(rows_out))
        if n > 0:
            window, stride = 10, 5
            windows = 1 if n <= window else 1 + -(-(n - window) // stride)
            rr = info["_rerank"]
            per_tuple = _avg_tuple_tokens(source, rr.get("cols", ()),
                                          ctx.serialization)
            prompt_text, _ = ctx.resolve_prompt(rr["prompt"])
            prefix_tokens = estimate_tokens(
                build_prefix("rerank", prompt_text, ctx.serialization))
            cost.requests += windows
            cost.tokens += windows * (prefix_tokens + window * per_tuple)
            cost.rows_into_llm += n
            cost.waves = max(cost.waves, windows)
        return rows_out, cost

    if op not in SEMANTIC_OPS:
        return rows, cost

    model = ctx.resolve_model(info["model"])
    n = int(round(rows))
    if n <= 0:
        return 0.0, cost
    per_tuple = _avg_tuple_tokens(source, info.get("cols", ()),
                                  ctx.serialization)

    def waves(requests: int) -> int:
        limit = max(1, getattr(model, "max_concurrency", 1) or 1)
        return -(-requests // limit)

    if op == "llm_embedding":
        cost.requests = 1
        cost.tokens = n * per_tuple
        cost.rows_into_llm = n
        cost.waves = waves(cost.requests)
        return rows, cost

    if op == "llm_rerank":
        window, stride = 10, 5
        windows = 1 if n <= window else 1 + -(-(n - window) // stride)
        prompt_text, _ = _node_prompt_text(ctx, node)
        prefix_tokens = estimate_tokens(
            build_prefix("rerank", prompt_text, ctx.serialization))
        cost.requests = windows
        cost.tokens = windows * (prefix_tokens + window * per_tuple)
        cost.rows_into_llm = n
        # rerank windows chain (each consumes the last window's output):
        # no overlap available, every request is its own wave
        cost.waves = cost.requests
        return rows, cost

    if op == "llm_fused":
        kind = "multi"
        prompt_text = _fused_prompt_text(ctx, node)
    else:
        kind = {"llm_filter": "filter", "llm_complete": "complete",
                "llm_complete_json": "complete_json"}[op]
        prompt_text, _ = _node_prompt_text(ctx, node)
    prefix_tokens = estimate_tokens(
        build_prefix(kind, prompt_text, ctx.serialization))
    plan = plan_batches([per_tuple] * n, prefix_tokens,
                        model.context_window, model.max_output_tokens,
                        ctx.max_batch if ctx.enable_batching else 1,
                        headroom=ctx.batch_headroom(model.ref))
    sampled = any(c in source.columns for c in info.get("cols", ()))
    cost.requests = _calibrated_requests(ctx, model, n, len(plan.batches),
                                         sampled)
    cost.tokens = sum(plan.est_tokens) + len(plan.batches) * prefix_tokens
    if len(plan.batches):
        cost.tokens = int(cost.tokens * cost.requests / len(plan.batches))
    cost.rows_into_llm = n
    cost.waves = waves(cost.requests)

    if op == "llm_filter":
        _, pid = _node_prompt_text(ctx, node)
        rows = rows * ctx.expected_selectivity(pid, DEFAULT_SELECTIVITY)
    elif op == "llm_fused":
        for k, pid in zip(node.info["kinds"], node.info["prompt_ids"]):
            if k == "filter":
                rows = rows * ctx.expected_selectivity(
                    pid, DEFAULT_SELECTIVITY)
    return rows, cost


def _packed_savings(ctx: SemanticContext, source: Table, group,
                    n: int) -> int:
    """Requests saved by co-packing one dispatch group: members sharing
    a metaprompt-prefix identity plan their tuples as ONE stream, so the
    part-filled tails that would ship per node merge (mirrors the
    scheduler's packing queue)."""
    from .pipeline import copack_identity   # local import: avoid cycle

    if n <= 0:
        return 0
    by_ident: dict = {}
    for node in group:
        ident = copack_identity(ctx, node)
        if ident is not None:
            by_ident.setdefault(ident, []).append(node)
    saved = 0
    mb = ctx.max_batch if ctx.enable_batching else 1
    for ident, members in by_ident.items():
        if len(members) < 2:
            continue
        model = ctx.resolve_model(members[0].info["model"])
        kind = ident[2]         # (provider, model, kind, ser, text)
        if members[0].op == "llm_fused":
            # fused nodes carry sub-task prompt specs, not a single
            # prompt: the shared prefix is the rendered multi-task text
            prompt_text = _fused_prompt_text(ctx, members[0])
        else:
            prompt_text, _ = _node_prompt_text(ctx, members[0])
        prefix_tokens = estimate_tokens(
            build_prefix(kind, prompt_text, ctx.serialization))
        headroom = ctx.batch_headroom(model.ref)
        costs: List[int] = []
        solo = 0
        for node in members:
            per_tuple = _avg_tuple_tokens(source, node.info.get("cols",
                                                                ()),
                                          ctx.serialization)
            member_costs = [per_tuple] * n
            solo += len(plan_batches(
                member_costs, prefix_tokens, model.context_window,
                model.max_output_tokens, mb, headroom=headroom).batches)
            costs.extend(member_costs)
        joint = len(plan_batches(
            costs, prefix_tokens, model.context_window,
            model.max_output_tokens, mb, headroom=headroom).batches)
        saved += max(0, solo - joint)
    return saved


def estimate_plan_cost(ctx: SemanticContext, source: Table,
                       nodes: Sequence) -> Tuple[PlanCost, List[dict]]:
    from .pipeline import Pipeline      # local import: avoid cycle

    total = PlanCost()
    per_node: List[dict] = []
    node_info: dict = {}      # id(node) -> (model_ref, limit, requests,
    #                            standalone waves, standalone wall)
    entry_rows: dict = {}     # id(node) -> rows flowing INTO the node
    rows = float(len(source))
    seen_corpus: set = set()      # shared-corpus embed dedupe across nodes
    node_packed_saved = 0
    # worst-case linger a co-packing site may spend waiting for denser
    # merges (the cost objective's density dial; ~0 under latency-first
    # last-tail-out scheduling): one window per site with packed savings
    linger_s = (ctx.scheduler.pack_linger_s
                if getattr(ctx, "scheduler", None) is not None else 0.0)
    for node in nodes:
        entry_rows[id(node)] = rows
        rows, c = estimate_node_cost(ctx, node, rows, source, seen_corpus)
        nd = {"rows": int(round(rows)),
              "requests": c.requests, "tokens": c.tokens}
        if c.scan_flops:
            nd["scan_flops"] = c.scan_flops
        if c.ann is not None:
            nd["ann"] = c.ann
        per_node.append(nd)
        total.requests += c.requests
        total.tokens += c.tokens
        total.rows_into_llm += c.rows_into_llm
        total.scan_flops += c.scan_flops
        if c.packed_requests and c.packed_requests < c.requests:
            node_packed_saved += c.requests - c.packed_requests
            total.pack_wait_s += linger_s
        ref, limit = "", 1
        if (c.requests and "model" in node.info
                and (node.op in SEMANTIC_OPS or node.op in RETRIEVAL_OPS)):
            m = ctx.resolve_model(node.info["model"])
            ref = m.ref
            limit = max(1, getattr(m, "max_concurrency", 1) or 1)
        node_info[id(node)] = (ref, limit, c.requests, c.waves, c.wall_s)
    # critical path: nodes in one dispatch group overlap, but same-model
    # members contend for one gate — their requests share the model's
    # concurrency budget, so per group it is the slowest MODEL (summed
    # requests / limit), and groups run back-to-back.  The calibrated
    # wall estimate multiplies each wave count by the model's observed
    # p50 request latency; a plan touching any uncalibrated model
    # reports wall_s = 0.0 (unknown) rather than an undercount.
    uncalibrated = False
    copack_on = (getattr(ctx, "copack", False)
                 and ctx.scheduler is not None and ctx.enable_batching)
    packed_saved = 0
    for group in Pipeline._dispatch_groups(list(nodes)):
        if copack_on and len(group) > 1:
            saved = _packed_savings(
                ctx, source, group,
                int(round(entry_rows.get(id(group[0]), 0.0))))
            if saved:
                packed_saved += saved
                total.pack_wait_s += linger_s
        if len(group) == 1:
            ref, limit, reqs, w, nwall = node_info.get(
                id(group[0]), ("", 1, 0, 0, 0.0))
            total.waves += w
            if not reqs:
                continue
            if nwall:               # node computed its own (spec chain)
                total.wall_s += nwall
                continue
            lat = ctx.calibrated_latency(ref) if ref else None
            if lat is None:
                uncalibrated = True
            else:
                total.wall_s += w * lat
            continue
        per_model: dict = {}
        for n in group:
            ref, limit, reqs, _, _ = node_info[id(n)]
            if not reqs:
                continue
            r0, l0 = per_model.get(ref, (0, limit))
            per_model[ref] = (r0 + reqs, min(l0, limit))
        group_waves, group_wall = _per_model_waves(
            (r, l, ctx.calibrated_latency(ref) if ref else None)
            for ref, (r, l) in per_model.items())
        total.waves += group_waves
        if group_wall is None:
            uncalibrated = True
        else:
            total.wall_s += group_wall
    if uncalibrated:
        total.wall_s = 0.0
    packed_saved += node_packed_saved
    if packed_saved:
        total.packed_requests = max(0, total.requests - packed_saved)
    return total, per_node


# ---------------------------------------------------------------------------
# rule 1: relational pushdown
# ---------------------------------------------------------------------------
def _commutes_before(rel, sem) -> bool:
    """May relational node ``rel`` move to run before node ``sem``?"""
    r, s = rel.op, sem.op
    produced = sem.info.get("out")
    fused_outs = sem.info.get("outs", ())

    if r == "limit":
        return s in ("llm_complete", "llm_complete_json", "llm_embedding",
                     "project")
    if r == "filter":
        if s == "llm_filter":
            return True
        if s in RETRIEVAL_OPS:
            # a filter over query-side columns commutes with the LATERAL
            # expansion (candidate rows replicate the query columns);
            # one reading the node's outputs (scores, ranks, corpus
            # columns) must stay above it
            deps = rel.info.get("cols")
            if deps is None:
                return False               # opaque predicate: stay put
            return not (set(deps) & set(sem.info.get("outs", ())))
        if s in ("llm_complete", "llm_complete_json", "llm_embedding",
                 "project"):
            deps = rel.info.get("cols")
            if deps is None:
                return False               # opaque predicate: stay put
            banned = set(fused_outs) | ({produced} if produced else set())
            return not (set(deps) & banned)
        return False
    if r == "select":
        if s in ("llm_filter", "llm_rerank"):
            needed = set(sem.info.get("cols", ()))
            if sem.info.get("by") is not None:
                needed.add(sem.info["by"])     # grouped rerank key
            return needed <= set(rel.info.get("cols", ()))
        return False
    if r == "order_by":
        key = rel.info.get("key")
        if rel.info.get("key_is_callable"):
            return False
        if s == "llm_filter":
            return True
        if s in ("llm_complete", "llm_complete_json", "llm_embedding",
                 "project"):
            banned = set(fused_outs) | ({produced} if produced else set())
            return key not in banned
        return False
    return False


def _pushdown(nodes: List, rewrites: List[str],
              obligations: List[Obligation]) -> List:
    nodes = list(nodes)
    changed = True
    while changed:
        changed = False
        for i in range(len(nodes) - 1):
            a, b = nodes[i], nodes[i + 1]
            if (a.op in SEMANTIC_OPS + RETRIEVAL_OPS + ("project",)
                    and b.op in RELATIONAL_OPS
                    and _commutes_before(b, a)):
                nodes[i], nodes[i + 1] = b, a
                rule = f"pushdown({b.op} before {a.op})"
                rewrites.append(rule)
                # claim: b may legally run before a, and b's read-set
                # is satisfied at its new position (the verifier
                # re-checks both with its own legality table)
                obligations.append(Obligation(
                    rule=rule, kind="commute",
                    payload={"rel_id": id(b), "rel_op": b.op,
                             "sem_key": semantic_key(a),
                             "sem_node": a}))
                changed = True
    return nodes


# ---------------------------------------------------------------------------
# rule 1b: retrieval rewrites (corpus pruning, k-pushdown, embed dedupe)
# ---------------------------------------------------------------------------
def _retrieval_rewrites(ctx: SemanticContext, nodes: List,
                        rewrites: List[str],
                        obligations: List[Obligation]) -> List:
    """Monotone retrieval-operator rewrites (never cost-gated — each one
    only ever removes work):

    * ``prune_corpus`` — a node carrying a ``corpus_filter`` embeds only
      the matching docs instead of embedding everything and masking the
      ranking.  Result-preserving by construction: per-doc vector scores
      are independent of the rest of the corpus, the selection and the
      tie-break are identical either way, and BM25 statistics always
      come from the full corpus.
    * ``k_pushdown`` — ``hybrid_topk(candidate_k=None)`` fuses FULL
      per-retriever candidate lists unoptimized; the rewrite pushes the
      final k into a per-retriever depth of ``max(32, 4k)`` (the
      engine-chosen physical depth, like a batch size).
    * ``dedupe_corpus_embed`` — notes nodes sharing (model, corpus
      fingerprint) with an earlier node; at runtime the session index
      registry / ``IndexStore`` serves them without re-embedding, and
      the cost model charges the corpus embed once.

    Rewritten nodes are REBUILT (fresh info dict + executor closure) so
    the shared logical plan is never mutated."""
    from .pipeline import PlanNode              # local import: avoid cycle
    from .retrieval_ops import make_retrieval_fn

    out: List = []
    seen: set = set()
    for node in nodes:
        if node.op not in RETRIEVAL_OPS:
            out.append(node)
            continue
        info = node.info
        changes: dict = {}
        if (info.get("corpus_filter") is not None
                and not info.get("prune_corpus")
                and node.op != "bm25_topk"):
            changes["prune_corpus"] = True
            rule = (f"prune_corpus({node.op}: corpus filter "
                    f"below the index build)")
            rewrites.append(rule)
            obligations.append(Obligation(
                rule=rule, kind="selection_invariance",
                payload={"key": semantic_key(node)}))
        if node.op == "hybrid_topk" and not info.get("candidate_k"):
            c = pushed_candidate_k(info["k"])
            if c < info.get("corpus_rows", 0):
                changes["candidate_k"] = c
                rule = (f"k_pushdown(hybrid_topk: k={info['k']} -> "
                        f"per-retriever candidate_k={c})")
                rewrites.append(rule)
                obligations.append(Obligation(
                    rule=rule, kind="recall_contract",
                    payload={"key": semantic_key(node),
                             "k": info["k"], "candidate_k": c}))
        if (node.op != "bm25_topk" and info.get("ann")
                and not info.get("ann_resolved")):
            # ann_select: resolve auto/forced ANN into a concrete scan
            # choice the executor follows and the cost model prices
            try:
                ref = ctx.resolve_model(info["model"]).ref
            except KeyError:
                ref = None
            if ref is not None:
                docs = info.get("corpus_rows", len(info["corpus"]))
                if (info.get("corpus_filter") is not None
                        and changes.get("prune_corpus")):
                    docs = max(1, int(round(docs * DEFAULT_SELECTIVITY)))
                probe = dict(info)
                probe.update(changes)
                dec = _ann_decision(ctx, probe, ref, docs)
                changes.update(
                    ann_resolved=dec["choice"], ann_nlist=dec["nlist"],
                    ann_nprobe=dec["nprobe"],
                    ann_recall_est=dec["recall_est"],
                    ann_calibrated=dec["calibrated"])
                rule = (
                    f"ann_select({node.op}: ann={info['ann']} -> "
                    f"{dec['choice']} nlist={dec['nlist']} "
                    f"nprobe={dec['nprobe']} "
                    f"est_recall={dec['recall_est']:.2f}"
                    f"{' calibrated' if dec['calibrated'] else ''})")
                rewrites.append(rule)
                obligations.append(Obligation(
                    rule=rule, kind="recall_contract",
                    payload={"key": semantic_key(node),
                             "mode": info["ann"],
                             "choice": dec["choice"],
                             "nlist": dec["nlist"],
                             "nprobe": dec["nprobe"],
                             "recall_est": dec["recall_est"],
                             "recall_target": dec["recall_target"]}))
        if "model" in info and info.get("corpus_fp"):
            try:
                ref = ctx.resolve_model(info["model"]).ref
            except KeyError:
                ref = None
            if ref is not None:
                key = (ref, info["corpus_fp"])
                if key in seen:
                    rule = (f"dedupe_corpus_embed({node.op}: corpus "
                            f"index shared with an earlier node)")
                    rewrites.append(rule)
                    obligations.append(Obligation(
                        rule=rule, kind="index_shared",
                        payload={"ref": ref, "fp": info["corpus_fp"]}))
                seen.add(key)
        if changes:
            new_info = dict(info)
            new_info.pop("_bm25", None)
            new_info.update(changes)
            out.append(PlanNode(node.op, new_info,
                                make_retrieval_fn(ctx, node.op,
                                                  new_info)))
        else:
            out.append(node)
    return out


# ---------------------------------------------------------------------------
# rule 2: semantic fusion
# ---------------------------------------------------------------------------
def _model_identity(ctx: SemanticContext, spec):
    # the full resolved resource, not just name@version: inline specs all
    # land on version 0, and fusing ops whose context_window /
    # max_output_tokens differ would run one sub-task under the other's
    # limits
    try:
        return ctx.resolve_model(spec)
    except KeyError:
        return repr(sorted(spec.items()))


def _can_join_group(ctx, group: List, node) -> bool:
    if node.op not in FUSABLE:
        return False
    head = group[0]
    if tuple(node.info["cols"]) != tuple(head.info["cols"]):
        return False
    if _model_identity(ctx, node.info["model"]) != _model_identity(
            ctx, head.info["model"]):
        return False
    # def-use: a later op reading an earlier op's output cannot fuse —
    # guaranteed here because cols are identical and outputs are new
    # columns, but guard against out-name collisions with input cols
    produced = {g.info.get("out") for g in group if g.info.get("out")}
    return not (set(node.info["cols"]) & produced)


def _make_fused_node(ctx: SemanticContext, group: List):
    from .pipeline import PlanNode      # local import: avoid cycle

    cols = list(group[0].info["cols"])
    model_spec = group[0].info["model"]
    subtasks = [{"kind": FUSABLE[g.op], "prompt": g.info["prompt"],
                 "out": g.info.get("out")} for g in group]
    prompt_ids = [ctx.resolve_prompt(g.info["prompt"])[1] for g in group]

    def fn(t: Table) -> Table:
        tuples = [{c: r[c] for c in cols} for r in t.rows()]
        per_task = F.llm_multi(ctx, model_spec,
                               [{"kind": s["kind"], "prompt": s["prompt"]}
                                for s in subtasks], tuples)
        mask = [True] * len(tuples)
        res = t
        for sub, vals in zip(subtasks, per_task):
            if sub["kind"] == "filter":
                mask = [m and bool(v) for m, v in zip(mask, vals)]
            else:
                res = res.with_column(sub["out"], vals)
        return res.filter_mask(mask)

    return PlanNode("llm_fused", {
        "model": model_spec, "cols": cols,
        "kinds": [s["kind"] for s in subtasks],
        "outs": [s["out"] for s in subtasks if s["out"]],
        "prompts": [g.info["prompt"] for g in group],
        "prompt_ids": prompt_ids,
        "fused": [g.op for g in group]}, fn)


def _fuse(ctx: SemanticContext, nodes: List, rewrites: List[str],
          obligations: List[Obligation]) -> List:
    out: List = []
    i = 0
    while i < len(nodes):
        node = nodes[i]
        if node.op in FUSABLE:
            group = [node]
            j = i + 1
            while j < len(nodes) and _can_join_group(ctx, group, nodes[j]):
                group.append(nodes[j])
                j += 1
            if len(group) > 1:
                fused = _make_fused_node(ctx, group)
                out.append(fused)
                rule = "fusion(" + "+".join(g.op for g in group) + ")"
                rewrites.append(rule)
                # claim: one llm_fused node carries exactly the merged
                # sub-tasks (kinds, outs, cols, prompts) under one model
                obligations.append(Obligation(
                    rule=rule, kind="fusion_exact",
                    payload={"kinds": list(fused.info["kinds"]),
                             "cols": list(fused.info["cols"]),
                             "outs": list(fused.info["outs"]),
                             "prompts": list(fused.info["prompts"]),
                             "models": [g.info["model"]
                                        for g in group]}))
                if "filter" in fused.info["kinds"]:
                    obligations.append(Obligation(
                        rule=rule, kind="mask_equivalence",
                        payload={}))
                i = j
                continue
        out.append(node)
        i += 1
    return out


# ---------------------------------------------------------------------------
# rule 3: cost-ordered filter chains
# ---------------------------------------------------------------------------
def _filter_rank(ctx: SemanticContext, node, source: Table) -> float:
    """Predicate-ordering rank: token cost per unit of elimination,
    cost / (1 - selectivity), ascending — cheap, selective predicates run
    first.  (Plain cost x selectivity mis-orders chains where an
    expensive filter is also very selective; the final plan is
    cost-gated either way.)"""
    prompt_text, pid = _node_prompt_text(ctx, node)
    per_tuple = _avg_tuple_tokens(source, node.info.get("cols", ()),
                                  ctx.serialization)
    prefix = estimate_tokens(
        build_prefix("filter", prompt_text, ctx.serialization))
    sel = ctx.expected_selectivity(pid, DEFAULT_SELECTIVITY)
    return (prefix + per_tuple) / max(1.0 - sel, 1e-6)


def _reorder_filters(ctx: SemanticContext, nodes: List, source: Table,
                     rewrites: List[str],
                     obligations: List[Obligation]) -> List:
    out: List = []
    i = 0
    while i < len(nodes):
        if nodes[i].op != "llm_filter":
            out.append(nodes[i])
            i += 1
            continue
        j = i
        while j < len(nodes) and nodes[j].op == "llm_filter":
            j += 1
        chain = nodes[i:j]
        ranked = sorted(chain, key=lambda n: _filter_rank(ctx, n, source))
        if ranked != chain:
            rule = (f"reorder_filters(chain of {len(chain)} by cost "
                    f"per eliminated tuple)")
            rewrites.append(rule)
            # claim: conjunctions commute — the plan's filter-predicate
            # multiset is unchanged by the reorder
            obligations.append(Obligation(
                rule=rule, kind="mask_equivalence", payload={}))
        out.extend(ranked)
        i = j
    return out


# ---------------------------------------------------------------------------
# rule 4: speculative pipelining (opt-in)
# ---------------------------------------------------------------------------
# objective-aware widening of the waste budget: a latency-first session
# tolerates extra speculative requests (they buy wall-clock), a
# cost-first one narrows the budget below the configured cap
SPEC_CAP_OBJECTIVE_MULT = {"latency": 1.25, "cost": 0.8}

# prior probability that a BM25-predicted per-query candidate list does
# NOT match the final fused top-k (no per-corpus calibration yet): the
# expected fraction of rerank warmup requests charged as waste
SPEC_RERANK_MISMATCH_PRIOR = 0.5


def _waste_cap(ctx: SemanticContext, serial_requests: int,
               objective: str) -> float:
    """Wasted-request budget for one speculation decision."""
    mult = SPEC_CAP_OBJECTIVE_MULT.get(objective, 1.0)
    return ctx.speculate_waste_cap * mult * max(serial_requests, 1)


def _make_spec_chain_node(ctx: SemanticContext, chain: List,
                          split: Optional[int] = None):
    """Build one ``llm_spec_chain`` node executing the first ``split``
    chain members as a concurrent mask-join over the chain's input
    tuple stream, then the remaining members serially over the prefix's
    survivors (``split`` omitted or == ``len(chain)``: the whole chain
    fans out, PR 3's behaviour).

    Each speculated member runs the full ``llm_filter`` staged path
    (dedup, cache, batch-plan, scheduler dispatch) on one of the join's
    bounded runner threads, so identical cache keys across members
    coalesce through the scheduler's single-flight registry and every
    member honours its model's concurrency gate.  Masks are ANDed; a
    tuple NULLed by overflow decodes to False — exactly the serial
    path's disposition — so the surviving stream is bit-identical to
    serial chain execution.  Tail members' masks are expanded back to
    the chain-input frame (False at already-dead positions) so
    ``member_masks`` stays one full-length mask per member.

    Note on statistics: speculative members observe *marginal* pass
    rates (over the chain input) where serial execution records
    *conditional* ones (over the predecessors' survivors); both are
    valid estimators for the cost model, and the waste budget is
    computed from the same recorded values either way."""
    from .pipeline import PlanNode      # local import: avoid cycle

    members = [{"model": g.info["model"], "prompt": g.info["prompt"],
                "cols": list(g.info["cols"])} for g in chain]
    prompt_ids = [ctx.resolve_prompt(g.info["prompt"])[1] for g in chain]
    k = len(members)
    if split is None or split <= 0 or split > k:
        split = k
    all_cols: List[str] = []
    for m in members:
        for c in m["cols"]:
            if c not in all_cols:
                all_cols.append(c)

    node = PlanNode("llm_spec_chain", {
        "member_specs": members, "cols": all_cols,
        "members": prompt_ids, "chain": k, "split": split})

    def fn(t: Table) -> Table:
        from repro.core.scheduler import SpecTask, SpeculativeJoin

        slots: List[Any] = [None] * k
        masks_out: List[Any] = [None] * k

        def make_thunk(kk: int, member: dict):
            def thunk() -> List[bool]:
                tuples = [{c: row[c] for c in member["cols"]}
                          for row in t.rows()]
                mask = F.llm_filter(ctx, member["model"],
                                    member["prompt"], tuples)
                slots[kk] = ctx.last_report_slot()
                return mask
            return thunk

        join = SpeculativeJoin(ctx.scheduler)
        masks = join.run(
            [SpecTask(make_thunk(kk, m), rows=len(t), label=f"member-{kk}")
             for kk, m in enumerate(members[:split])])
        lengths = {len(m) for m in masks}
        if len(lengths) > 1:
            raise ValueError(
                f"speculative members returned masks of differing "
                f"lengths {sorted(lengths)}")
        combined = [all(col) for col in zip(*masks)]
        for kk in range(split):
            masks_out[kk] = list(masks[kk])
        cur = t.filter_mask(combined)
        alive = [i for i, keep in enumerate(combined) if keep]
        for kk in range(split, k):
            member = members[kk]
            tuples = [{c: row[c] for c in member["cols"]}
                      for row in cur.rows()]
            mask = F.llm_filter(ctx, member["model"], member["prompt"],
                                tuples)
            slots[kk] = ctx.last_report_slot()
            full = [False] * len(t)
            for pos, keep in zip(alive, mask):
                full[pos] = bool(keep)
            masks_out[kk] = full
            cur = cur.filter_mask(mask)
            alive = [pos for pos, keep in zip(alive, mask) if keep]
        node.info["member_masks"] = masks_out
        node.info["member_report_slots"] = slots
        return cur

    node.fn = fn
    return node


def _decide_speculation(ctx: SemanticContext, source: Table, chain: List,
                        rows_in: float, mode: str,
                        objective: str = "latency"
                        ) -> Tuple[SpeculationDecision, float]:
    """Estimate serial vs speculative execution of one filter chain,
    over every candidate prefix split.

    Serial: member k sees the survivors of members < k (cardinalities
    from recorded selectivity) and its waves queue behind k-1 finished
    round-trips.  Speculative with split s: the first s members all see
    the full chain input; same-model members share one concurrency
    gate, different models fan out independently, so the prefix's
    critical path is the slowest model's wave count — ~1 round-trip
    when the fan-out fits the concurrency limits — and the remaining
    members queue serially over the prefix's survivors (their
    cardinalities are the serial ones: the ANDed prefix admits exactly
    the rows serial prefix execution would).  Expected waste is the
    prefix's request count over the full input minus its serial one;
    the chosen split minimizes the wall estimate (waves when
    uncalibrated) among splits within the waste cap."""
    n = int(round(rows_in))
    k = len(chain)
    decision = SpeculationDecision(
        members=[ctx.resolve_prompt(g.info["prompt"])[1] for g in chain],
        rows_in=n)
    per_member: List[dict] = []
    calibrated = True
    rows = rows_in
    for g in chain:
        member = {"model": g.info["model"], "prompt": g.info["prompt"],
                  "cols": g.info.get("cols", ())}
        model = ctx.resolve_model(member["model"])
        limit = max(1, getattr(model, "max_concurrency", 1) or 1)
        lat = ctx.calibrated_latency(model.ref)
        if lat is None:
            calibrated = False
        m = int(round(rows))
        req_serial, _ = _filter_estimate(ctx, member, m, source)
        if m == n:                      # first member: same estimate
            req_spec = req_serial
        else:
            req_spec, _ = _filter_estimate(ctx, member, n, source)
        w = -(-req_serial // limit) if req_serial else 0
        per_member.append({"ref": model.ref, "limit": limit, "lat": lat,
                           "req_serial": req_serial, "req_spec": req_spec,
                           "w_serial": w})
        decision.serial_requests += req_serial
        decision.serial_waves += w
        if lat is not None:
            decision.serial_wall_s += w * lat
        _, pid = ctx.resolve_prompt(member["prompt"])
        rows = rows * ctx.expected_selectivity(pid, DEFAULT_SELECTIVITY)

    def candidate(s: int) -> dict:
        per_model: dict = {}    # ref -> [spec requests, limit, latency]
        for pm in per_member[:s]:
            entry = per_model.setdefault(pm["ref"],
                                         [0, pm["limit"], pm["lat"]])
            entry[0] += pm["req_spec"]
            entry[1] = min(entry[1], pm["limit"])
        waves, wall = _per_model_waves(per_model.values())
        for pm in per_member[s:]:
            waves += pm["w_serial"]
            if wall is not None:
                if pm["lat"] is None and pm["req_serial"]:
                    wall = None
                elif pm["lat"] is not None:
                    wall += pm["w_serial"] * pm["lat"]
        wasted = max(0, sum(pm["req_spec"] - pm["req_serial"]
                            for pm in per_member[:s]))
        requests = (sum(pm["req_spec"] for pm in per_member[:s])
                    + sum(pm["req_serial"] for pm in per_member[s:]))
        return {"split": s, "waves": waves, "wall": wall,
                "wasted": wasted, "requests": requests}

    def adopt(c: dict):
        decision.split = c["split"]
        decision.spec_requests = c["requests"]
        decision.spec_waves = c["waves"]
        decision.wasted_requests = c["wasted"]
        if c["wall"] is not None:
            decision.spec_wall_s = c["wall"]
        else:
            decision.serial_wall_s = 0.0

    cands = [candidate(s) for s in range(2, k + 1)]
    if mode == "always":
        adopt(cands[-1])                # force the whole chain
        decision.chosen = True
        decision.reason = "forced by speculate='always'"
        return decision, rows
    cap = _waste_cap(ctx, decision.serial_requests, objective)
    feasible = [c for c in cands if c["wasted"] <= cap]
    if not feasible:
        adopt(min(cands, key=lambda c: c["wasted"]))
        decision.reason = (f"expected waste {decision.wasted_requests} "
                           f"requests exceeds cap {cap:.0f}")
    elif calibrated and decision.serial_wall_s:
        adopt(min(feasible,
                  key=lambda c: (c["wall"] if c["wall"] is not None
                                 else float("inf"), c["wasted"])))
        decision.chosen = bool(
            decision.spec_wall_s
            and decision.spec_wall_s < decision.serial_wall_s)
        decision.reason = (
            f"calibrated wall {decision.spec_wall_s:.3f}s "
            f"{'<' if decision.chosen else '>='} "
            f"{decision.serial_wall_s:.3f}s")
    else:
        adopt(min(feasible, key=lambda c: (c["waves"], c["wasted"])))
        decision.chosen = decision.spec_waves < decision.serial_waves
        decision.reason = (
            f"uncalibrated waves {decision.spec_waves} "
            f"{'<' if decision.chosen else '>='} {decision.serial_waves}")
    return decision, rows


def _speculate_chains(ctx: SemanticContext, source: Table, nodes: List,
                      rewrites: List[str],
                      obligations: List[Obligation], mode: str,
                      objective: str = "latency"
                      ) -> Tuple[List, List[SpeculationDecision]]:
    """Replace each eligible ``llm_filter`` chain (length >= 2) with a
    speculative mask-join node when the decision model says it pays."""
    out: List = []
    decisions: List[SpeculationDecision] = []
    rows = float(len(source))
    i = 0
    while i < len(nodes):
        node = nodes[i]
        if node.op != "llm_filter":
            rows, _ = estimate_node_cost(ctx, node, rows, source)
            out.append(node)
            i += 1
            continue
        j = i
        while j < len(nodes) and nodes[j].op == "llm_filter":
            j += 1
        chain = nodes[i:j]
        if len(chain) < 2:
            rows, _ = estimate_node_cost(ctx, node, rows, source)
            out.append(node)
            i = j
            continue
        decision, rows = _decide_speculation(ctx, source, chain, rows,
                                             mode, objective)
        decisions.append(decision)
        if decision.chosen:
            out.append(_make_spec_chain_node(ctx, chain, decision.split))
            prefix = ""
            if 0 < decision.split < len(chain):
                prefix = f", prefix={decision.split}"
            rule = (f"speculate(chain of {len(chain)}: "
                    f"spec_waves={decision.spec_waves} vs "
                    f"serial_waves={decision.serial_waves}, "
                    f"wasted<={decision.wasted_requests}{prefix})")
            rewrites.append(rule)
            # claim: the mask-join ANDs exactly the chain's predicates
            # (surviving stream bit-identical to serial execution)
            obligations.append(Obligation(
                rule=rule, kind="mask_equivalence",
                payload={"spec_chain": True,
                         "prompts": [g.info["prompt"] for g in chain]}))
        else:
            out.extend(chain)
            rewrites.append(
                f"rejected(speculate chain of {len(chain)}: "
                f"{decision.reason})")
        i = j
    return out, decisions


# ---------------------------------------------------------------------------
# rule 4b: map-past-filter speculation
# ---------------------------------------------------------------------------
def _filter_members(node) -> List[dict]:
    """Member specs of an upstream mask producer: one spec for a plain
    ``llm_filter``, the member list for an ``llm_spec_chain``."""
    if node.op == "llm_spec_chain":
        return [dict(m) for m in node.info["member_specs"]]
    return [{"model": node.info["model"], "prompt": node.info["prompt"],
             "cols": list(node.info["cols"])}]


def _decide_spec_map(ctx: SemanticContext, source: Table, filt, mp,
                     rows_in: float, mode: str, objective: str
                     ) -> Tuple[SpeculationDecision, float]:
    """Estimate serial vs speculative execution of one filter->map edge.

    Serial: the map queues behind the mask and sees only the survivors.
    Speculative: the map dispatches over the filter's full input
    concurrently with the mask — the edge's critical path is
    ``max(filter waves, map waves over the full input)`` — and the
    expected waste is the map requests over rows the mask kills."""
    n = int(round(rows_in))
    rows_out, fcost = estimate_node_cost(ctx, filt, rows_in, source)
    members = _filter_members(filt)
    decision = SpeculationDecision(
        kind="map",
        members=([ctx.resolve_prompt(m["prompt"])[1] for m in members]
                 + [ctx.resolve_prompt(mp.info["prompt"])[1]]),
        rows_in=n)
    if n <= 0:
        decision.reason = "no input rows"
        return decision, rows_out
    survivors = int(round(rows_out))
    map_spec = {"model": mp.info["model"], "prompt": mp.info["prompt"],
                "cols": mp.info.get("cols", ())}
    mkind = ("complete_json" if mp.op == "llm_complete_json"
             else "complete")
    req_surv, _ = _filter_estimate(ctx, map_spec, survivors, source,
                                   kind=mkind)
    req_full, _ = _filter_estimate(ctx, map_spec, n, source, kind=mkind)
    model = ctx.resolve_model(mp.info["model"])
    limit = max(1, getattr(model, "max_concurrency", 1) or 1)
    lat = ctx.calibrated_latency(model.ref)
    w_surv = -(-req_surv // limit) if req_surv else 0
    w_full = -(-req_full // limit) if req_full else 0
    decision.serial_requests = fcost.requests + req_surv
    decision.spec_requests = fcost.requests + req_full
    decision.serial_waves = fcost.waves + w_surv
    decision.spec_waves = max(fcost.waves, w_full)
    decision.wasted_requests = max(0, req_full - req_surv)

    # the filter side's calibrated wall: spec chains self-wall, plain
    # filters wall via their model's recorded latency
    if filt.op == "llm_spec_chain":
        wall_f = fcost.wall_s if fcost.wall_s else None
    else:
        lat_f = ctx.calibrated_latency(
            ctx.resolve_model(filt.info["model"]).ref)
        wall_f = fcost.waves * lat_f if lat_f is not None else None
    if wall_f is not None and lat is not None:
        decision.serial_wall_s = wall_f + w_surv * lat
        decision.spec_wall_s = max(wall_f, w_full * lat)

    if mode == "always":
        decision.chosen = True
        decision.reason = "forced by speculate='always'"
        return decision, rows_out
    cap = _waste_cap(ctx, decision.serial_requests, objective)
    if decision.wasted_requests > cap:
        decision.reason = (f"expected waste {decision.wasted_requests} "
                           f"requests exceeds cap {cap:.0f}")
    elif decision.spec_wall_s and decision.serial_wall_s:
        decision.chosen = decision.spec_wall_s < decision.serial_wall_s
        decision.reason = (
            f"calibrated wall {decision.spec_wall_s:.3f}s "
            f"{'<' if decision.chosen else '>='} "
            f"{decision.serial_wall_s:.3f}s")
    else:
        decision.chosen = decision.spec_waves < decision.serial_waves
        decision.reason = (
            f"uncalibrated waves {decision.spec_waves} "
            f"{'<' if decision.chosen else '>='} {decision.serial_waves}")
    return decision, rows_out


def _make_spec_map_node(ctx: SemanticContext, filt, mp):
    """Build one ``llm_spec_map`` node running the upstream mask members
    and the downstream map concurrently over the edge's input rows.

    The mask members are mandatory tasks (the serial plan needs them);
    the map dispatches in row chunks so the resolved mask can cancel
    not-yet-started chunks whose rows are all dead.  Values computed
    for rows the mask kills are dropped from the output (and counted
    via ``SchedulerStats.spec_wasted_rows``) but remain in the
    prediction cache — a later plan over the same rows gets them free.
    Surviving rows keep their serial values: per-tuple completions are
    independent of batch composition, so the output is bit-identical
    to filter-then-map."""
    from .pipeline import PlanNode      # local import: avoid cycle

    members = _filter_members(filt)
    prompt_ids = [ctx.resolve_prompt(m["prompt"])[1] for m in members]
    nm = len(members)
    node = PlanNode("llm_spec_map", {
        "member_specs": members, "members": prompt_ids,
        "model": mp.info["model"], "prompt": mp.info["prompt"],
        "cols": list(mp.info["cols"]), "out": mp.info["out"],
        "map_op": mp.op, "chain": nm})

    def fn(t: Table) -> Table:
        from repro.core.scheduler import SpecTask, SpeculativeJoin

        n = len(t)
        out_col = node.info["out"]
        if n == 0:
            return t.filter_mask([]).with_column(out_col, [])
        rows_all = list(t.rows())
        chunk = (ctx.max_batch
                 if ctx.enable_batching and ctx.max_batch else 32)
        spans = [(s, min(s + chunk, n)) for s in range(0, n, chunk)]
        join = SpeculativeJoin(ctx.scheduler)
        slots: List[Any] = [None] * (nm + 1)
        masks: List[Any] = [None] * nm
        lock = threading.Lock()
        state = {"left": nm}

        def make_member(k: int, member: dict):
            def thunk() -> List[bool]:
                tuples = [{c: row[c] for c in member["cols"]}
                          for row in rows_all]
                mask = F.llm_filter(ctx, member["model"],
                                    member["prompt"], tuples)
                slots[k] = ctx.last_report_slot()
                masks[k] = mask
                with lock:
                    state["left"] -= 1
                    done = state["left"] == 0
                if done:
                    combined = [all(col) for col in zip(*masks)]
                    state["combined"] = combined
                    # the mask resolved: speculative chunks whose rows
                    # are all dead never need to run
                    for j, (s, e) in enumerate(spans):
                        if not any(combined[s:e]):
                            join.cancel(nm + j)
                return mask
            return thunk

        map_cols = node.info["cols"]
        map_fn = (F.llm_complete_json
                  if node.info["map_op"] == "llm_complete_json"
                  else F.llm_complete)

        def make_chunk(j: int, s: int, e: int):
            def thunk() -> list:
                tuples = [{c: rows_all[i][c] for c in map_cols}
                          for i in range(s, e)]
                vals = map_fn(ctx, node.info["model"],
                              node.info["prompt"], tuples)
                slots[nm] = ctx.last_report_slot()
                return vals
            return thunk

        tasks = ([SpecTask(make_member(k, m), rows=n,
                           label=f"member-{k}", mandatory=True)
                  for k, m in enumerate(members)]
                 + [SpecTask(make_chunk(j, s, e), rows=e - s,
                             label=f"map-{j}")
                    for j, (s, e) in enumerate(spans)])
        results = join.run(tasks)
        combined = state["combined"]
        cancelled = set(join.cancelled)
        out_vals: List[Any] = [None] * n
        wasted = 0
        for j, (s, e) in enumerate(spans):
            vals = results[nm + j]
            if nm + j in cancelled or vals is None:
                continue
            for i in range(s, e):
                if combined[i]:
                    out_vals[i] = vals[i - s]
                else:
                    wasted += 1
        if wasted:
            join.note_wasted(wasted)
        node.info["member_masks"] = [list(m) for m in masks]
        node.info["member_report_slots"] = slots
        surv = [v for v, keep in zip(out_vals, combined) if keep]
        return t.filter_mask(combined).with_column(out_col, surv)

    node.fn = fn
    return node


def _speculate_maps(ctx: SemanticContext, source: Table, nodes: List,
                    rewrites: List[str],
                    obligations: List[Obligation], mode: str,
                    objective: str
                    ) -> Tuple[List, List[SpeculationDecision]]:
    """Fuse each eligible filter->map edge (an ``llm_filter`` or chosen
    ``llm_spec_chain`` directly feeding an ``llm_complete`` /
    ``llm_complete_json``) into one ``llm_spec_map`` node when the
    decision model says the overlap pays."""
    out: List = []
    decisions: List[SpeculationDecision] = []
    rows = float(len(source))
    i = 0
    while i < len(nodes):
        node = nodes[i]
        nxt = nodes[i + 1] if i + 1 < len(nodes) else None
        if (node.op in ("llm_filter", "llm_spec_chain")
                and nxt is not None
                and nxt.op in ("llm_complete", "llm_complete_json")):
            decision, rows_out = _decide_spec_map(ctx, source, node, nxt,
                                                  rows, mode, objective)
            decisions.append(decision)
            if decision.chosen:
                out.append(_make_spec_map_node(ctx, node, nxt))
                rule = (f"speculate(map past filter: "
                        f"spec_waves={decision.spec_waves} vs "
                        f"serial_waves={decision.serial_waves}, "
                        f"wasted<={decision.wasted_requests})")
                rewrites.append(rule)
                # claim: the node ANDs exactly the upstream predicates
                # and maps exactly the downstream prompt over survivors
                obligations.append(Obligation(
                    rule=rule, kind="mask_equivalence",
                    payload={"spec_map": True,
                             "prompts": [m["prompt"] for m in
                                         _filter_members(node)]}))
                rows = rows_out
                i += 2
                continue
            rewrites.append(
                f"rejected(speculate map past filter: {decision.reason})")
        rows, _ = estimate_node_cost(ctx, node, rows, source)
        out.append(node)
        i += 1
    return out, decisions


# ---------------------------------------------------------------------------
# rule 4c: retrieval-aware rerank speculation
# ---------------------------------------------------------------------------
def _decide_spec_rerank(ctx: SemanticContext, source: Table, retr, rr,
                        rows_in: float, mode: str, objective: str
                        ) -> Tuple[SpeculationDecision, float]:
    """Estimate serial vs speculative execution of one retrieval->rerank
    edge.  Serial: the rerank's chained windows queue behind the
    retrieval's embed waves.  Speculative: warmup windows over the
    BM25-predicted candidates overlap the dense embeds and fusion; the
    authoritative pass reconciles through the window cache, so only
    mispredicted queries pay again (``SPEC_RERANK_MISMATCH_PRIOR``)."""
    rows_out, rcost = _retrieval_estimate(ctx, retr, rows_in, source,
                                          set())
    n = int(round(rows_out))
    decision = SpeculationDecision(
        kind="rerank",
        members=[ctx.resolve_prompt(rr.info["prompt"])[1]],
        rows_in=n)
    if n <= 0:
        decision.reason = "no candidate rows"
        return decision, rows_out
    window, stride = 10, 5
    windows = 1 if n <= window else 1 + -(-(n - window) // stride)
    decision.serial_requests = rcost.requests + windows
    decision.wasted_requests = int(
        math.ceil(windows * SPEC_RERANK_MISMATCH_PRIOR))
    decision.spec_requests = (decision.serial_requests
                              + decision.wasted_requests)
    decision.serial_waves = rcost.waves + windows
    decision.spec_waves = max(rcost.waves, windows)

    if mode == "always":
        decision.chosen = True
        decision.reason = "forced by speculate='always'"
        return decision, rows_out
    cap = _waste_cap(ctx, decision.serial_requests, objective)
    if decision.wasted_requests > cap:
        decision.reason = (f"expected waste {decision.wasted_requests} "
                           f"requests exceeds cap {cap:.0f}")
    else:
        decision.chosen = decision.spec_waves < decision.serial_waves
        decision.reason = (
            f"uncalibrated waves {decision.spec_waves} "
            f"{'<' if decision.chosen else '>='} {decision.serial_waves}")
    return decision, rows_out


def _speculate_rerank(ctx: SemanticContext, source: Table, nodes: List,
                      rewrites: List[str],
                      obligations: List[Obligation], mode: str,
                      objective: str
                      ) -> Tuple[List, List[SpeculationDecision]]:
    """Fuse each eligible ``hybrid_topk`` -> ``llm_rerank`` edge into a
    ``spec_rerank`` node that warms the rerank window cache over the
    BM25-predicted candidates while the dense side finishes.

    Structural guards: the prediction cache must be enabled (it IS the
    reconciliation mechanism — without it warmup results cannot carry
    over to the authoritative pass), and the rerank must not read the
    retrieval's *computed* columns — the fused score and its rank are
    unknowable before fusion, so predicted tuples would never
    byte-match.  Joined corpus columns are fine: the BM25 side predicts
    which documents expand, and their content is known up front."""
    from .retrieval_ops import make_spec_rerank_fn
    from .pipeline import PlanNode      # local import: avoid cycle

    out: List = []
    decisions: List[SpeculationDecision] = []
    rows = float(len(source))
    i = 0
    while i < len(nodes):
        node = nodes[i]
        nxt = nodes[i + 1] if i + 1 < len(nodes) else None
        if (node.op == "hybrid_topk" and nxt is not None
                and nxt.op == "llm_rerank"):
            if not ctx.enable_cache:
                rewrites.append("rejected(speculate rerank: prediction "
                                "cache disabled)")
            elif (set(nxt.info.get("cols", ()))
                  | {nxt.info.get("by")}) & {
                      node.info.get("out"),
                      str(node.info.get("out")) + "_rank"}:
                rewrites.append("rejected(speculate rerank: rerank reads "
                                "the fused score/rank columns)")
            else:
                decision, rows_out = _decide_spec_rerank(
                    ctx, source, node, nxt, rows, mode, objective)
                decisions.append(decision)
                if decision.chosen:
                    info = {"k": node.info["k"],
                            "by": nxt.info.get("by"),
                            "outs": list(node.info.get("outs", ())),
                            "retr_op": node.op,
                            "members": list(decision.members),
                            "_retr": node.info,
                            "_rerank": {
                                "model": nxt.info["model"],
                                "prompt": nxt.info["prompt"],
                                "cols": list(nxt.info["cols"]),
                                "by": nxt.info.get("by")}}
                    spec = PlanNode("spec_rerank", info)
                    spec.fn = make_spec_rerank_fn(ctx, spec)
                    out.append(spec)
                    rule = (f"speculate(rerank over retrieval: "
                            f"spec_waves={decision.spec_waves} vs "
                            f"serial_waves={decision.serial_waves}, "
                            f"wasted<={decision.wasted_requests})")
                    rewrites.append(rule)
                    # claim: the authoritative rerank runs over the
                    # full fused top-k — warmup only pre-fills the
                    # window cache, never changes the candidate set
                    obligations.append(Obligation(
                        rule=rule, kind="recall_contract",
                        payload={"spec_rerank": True,
                                 "key": semantic_key(node),
                                 "k": node.info["k"]}))
                    rows = rows_out
                    i += 2
                    continue
                rewrites.append(
                    f"rejected(speculate rerank: {decision.reason})")
        rows, _ = estimate_node_cost(ctx, node, rows, source)
        out.append(node)
        i += 1
    return out, decisions


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
# latency-equivalent token cost charged per provider request when ranking
# plans: a chat-API round trip costs ~30 ms of overhead, the price of a
# few hundred tokens of service time (benchmarks/run.py batching bench)
REQUEST_OVERHEAD_TOKENS = 200

# nominal per-request round-trip seconds for ranking plans on waves when
# no calibrated latency exists (the same ~30 ms ballpark that motivates
# REQUEST_OVERHEAD_TOKENS)
NOMINAL_REQUEST_S = 0.03


def _cost_rank(c: PlanCost, objective: str = "cost") -> tuple:
    """Comparable plan rank under a scheduling objective.  ``cost``
    ranks by token spend plus a flat per-request overhead (the provider
    bill).  ``latency`` ranks by the calibrated wall estimate — waves x
    a nominal round-trip when uncalibrated — with the token rank as the
    tie-break, so among equally fast plans the cheaper one wins."""
    base = float(c.tokens + REQUEST_OVERHEAD_TOKENS * c.requests)
    if objective == "latency":
        wall = c.wall_s if c.wall_s else c.waves * NOMINAL_REQUEST_S
        return (wall, base)
    return (base, 0.0)


def _objective_frontiers(cost: PlanCost) -> dict:
    """Both scheduling frontiers of one plan estimate.  The co-packed
    request count is identical (last-tail-out makes packing free under
    the latency objective, so neither frontier gives it up); the wall
    estimates differ by the linger the cost objective may spend waiting
    for denser merges.  ``est_wall`` is None when uncalibrated."""
    packed = cost.packed_requests or cost.requests
    wall = cost.wall_s if cost.wall_s else None
    return {
        "latency": {"packed_req": packed, "est_wall": wall},
        "cost": {"packed_req": packed,
                 "est_wall": (None if wall is None
                              else wall + cost.pack_wait_s)},
    }


def optimize_plan(ctx: SemanticContext, source: Table, nodes: Sequence,
                  speculate=None, objective: Optional[str] = None
                  ) -> OptimizedPlan:
    """Rewrite a Pipeline node list; returns both plans' cost estimates.

    Pushdown always applies (it only ever shrinks the tuple stream LLM
    ops see); the filter re-ordering and semantic-fusion rewrites are
    cost-gated — each is kept only if the cost model says the plan got
    cheaper (e.g. fusing a highly selective filter with a completion
    would run the completion over the whole input, so it is rejected).

    ``speculate`` (``None``/``False`` off, ``True``/``"auto"``
    cost-gated, ``"always"`` forced) runs the speculative-pipelining
    rules last, over the cost-ordered plan: ``llm_filter`` chains of
    length >= 2 may become concurrent mask-join nodes (whole chain or
    a prefix), filter->map edges may become ``llm_spec_map`` nodes,
    and ``hybrid_topk``->``llm_rerank`` edges may become
    ``spec_rerank`` nodes — each per the calibrated decision recorded
    in ``OptimizedPlan.spec_decisions`` (the waste cap widens 1.25x
    under the latency objective and narrows 0.8x under cost).

    ``objective`` (``"latency"``/``"cost"``, default the context's) sets
    the rank the cost gates compare under: ``latency`` accepts a rewrite
    that lowers the wall estimate even when it spends more tokens (e.g.
    fusion collapsing two waves into one), ``cost`` keeps the token-first
    gate.  Pure planning: no provider calls, no table materialisation."""
    if objective is None:
        objective = getattr(ctx, "objective", "latency")
    if objective not in ("latency", "cost"):
        raise ValueError(
            f"objective must be 'latency' or 'cost', got {objective!r}")
    naive = [n for n in nodes]
    rewrites: List[str] = []
    obligations: List[Obligation] = []
    new = _pushdown(list(nodes), rewrites, obligations)
    new = _retrieval_rewrites(ctx, new, rewrites, obligations)

    cost, _ = estimate_plan_cost(ctx, source, new)
    for rule in (_reorder_filters, _fuse):
        trial_rw: List[str] = []
        trial_ob: List[Obligation] = []
        if rule is _reorder_filters:
            trial = rule(ctx, new, source, trial_rw, trial_ob)
        else:
            trial = rule(ctx, new, trial_rw, trial_ob)
        if not trial_rw:
            continue
        trial_cost, _ = estimate_plan_cost(ctx, source, trial)
        if _cost_rank(trial_cost, objective) <= _cost_rank(cost, objective):
            new, cost = trial, trial_cost
            rewrites.extend(trial_rw)
            obligations.extend(trial_ob)
        else:
            rewrites.extend(f"rejected({rw}: estimated cost higher)"
                            for rw in trial_rw)

    spec_decisions: List[SpeculationDecision] = []
    if speculate:
        mode = "always" if speculate == "always" else "auto"
        new, spec_decisions = _speculate_chains(ctx, source, new,
                                                rewrites, obligations,
                                                mode, objective)
        for rule_fn in (_speculate_maps, _speculate_rerank):
            new, more = rule_fn(ctx, source, new, rewrites, obligations,
                                mode, objective)
            spec_decisions.extend(more)

    if rewrites:
        # the one claim every rewrite shares: the plan's final output
        # schema (names + dtypes) is unchanged
        obligations.append(Obligation(
            rule="plan", kind="schema_preserved", payload={}))
    plan = OptimizedPlan(nodes=new, rewrites=rewrites,
                         spec_decisions=spec_decisions,
                         objective=objective, obligations=obligations)
    plan.naive_cost, plan.naive_node_costs = estimate_plan_cost(
        ctx, source, list(naive))
    plan.optimized_cost, plan.optimized_node_costs = estimate_plan_cost(
        ctx, source, new)
    plan.optimized_cost.wasted_requests = sum(
        d.wasted_requests for d in spec_decisions if d.chosen)
    plan.frontiers = _objective_frontiers(plan.optimized_cost)
    return plan
