"""Cost-based semantic plan optimizer (paper §2.3, "seamless" tier).

FlockMTL's pitch is that LLM-backed relational plans get optimized below
the query surface: the user chains operators in whatever order reads
naturally, and the engine re-orders and fuses them so the model sees as
few tuples — and as few requests — as possible.  This module implements
that rewrite layer for ``Pipeline`` plans.  Three rules run in sequence:

1. **Pushdown** — cheap relational ops (``filter``, ``limit``, ``select``,
   key-independent ``order_by``) bubble *toward the scan*, past semantic
   ops they commute with, so LLM calls see fewer tuples:

   * ``limit`` commutes with per-row map ops (``llm_complete``,
     ``llm_complete_json``, ``llm_embedding``, ``project``) — they preserve
     row count and order.  It never crosses ``llm_filter`` / ``order_by`` /
     ``llm_rerank``.
   * relational ``filter`` commutes with ``llm_filter`` (conjunctive
     predicates) and — when its column dependencies are declared via
     ``Pipeline.filter(pred, cols=...)`` — with map ops whose output
     column it does not read.
   * ``select`` crosses ``llm_filter``/``llm_rerank`` when it retains
     their input columns.
   * ``order_by`` with a string key crosses map ops that don't produce
     that key, and ``llm_filter`` (stable sort of a subset == subset of
     the stable-sorted whole).

2. **Semantic fusion** — adjacent ``llm_filter``/``llm_complete``/
   ``llm_complete_json`` nodes sharing one model and one input-column set
   (and with no def-use dependency between them) merge into a single
   ``llm_fused`` node that answers all sub-tasks in one metaprompt pass
   (``core.functions.llm_multi``, kind ``multi``).

3. **Cost-ordered filter chains** — runs of consecutive ``llm_filter``
   nodes are re-ordered by estimated per-tuple token cost x expected
   selectivity (cheap, selective filters first), using
   ``provider.estimate_tokens`` and the per-prompt pass rates recorded in
   ``SemanticContext.selectivity_stats``.

``optimize_plan`` is pure planning: it returns new ``PlanNode`` lists
(fused nodes carry fresh closures) plus a cost estimate of both plans —
nothing executes until ``Pipeline.collect()`` runs the rewritten plan.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Sequence, Tuple

from repro.core import functions as F
from repro.core.batching import plan_batches
from repro.core.functions import SemanticContext
from repro.core.metaprompt import build_multi_task, build_prefix, \
    serialize_tuple
from repro.core.provider import estimate_tokens

from .table import Table

# node taxonomy --------------------------------------------------------------
SEMANTIC_MAP_OPS = ("llm_complete", "llm_complete_json", "llm_embedding")
SEMANTIC_OPS = SEMANTIC_MAP_OPS + ("llm_filter", "llm_rerank", "llm_fused")
RELATIONAL_OPS = ("filter", "limit", "select", "order_by")
FUSABLE = {"llm_filter": "filter", "llm_complete": "complete",
           "llm_complete_json": "complete_json"}

# default pass rate assumed for predicates with no recorded statistics
DEFAULT_SELECTIVITY = 0.5
# token estimate for a column whose width we cannot sample (produced
# mid-plan by an earlier semantic op)
DEFAULT_COL_TOKENS = 16
_SAMPLE_ROWS = 32


@dataclass
class PlanCost:
    """Estimated provider-side cost of one plan.

    ``waves`` is the critical-path latency estimate for the concurrent
    scheduler: per node, ``ceil(requests / model.max_concurrency)``
    request round-trips must run back-to-back (the scheduler overlaps
    everything else), summed over the sequential node chain.  With the
    serial executor (``scheduler=None``) the critical path is simply
    ``requests``."""
    requests: int = 0
    tokens: int = 0
    rows_into_llm: int = 0      # tuples fed to semantic ops, post-dedup-free
    waves: int = 0              # critical-path request waves (concurrent)

    def __str__(self):
        return (f"requests={self.requests} tokens={self.tokens} "
                f"llm_rows={self.rows_into_llm} waves={self.waves}")


@dataclass
class OptimizedPlan:
    nodes: List[Any]                    # rewritten PlanNode list
    rewrites: List[str] = field(default_factory=list)
    naive_cost: PlanCost = field(default_factory=PlanCost)
    optimized_cost: PlanCost = field(default_factory=PlanCost)
    # per-node {rows, requests, tokens} estimates, aligned with the
    # original and rewritten node lists (PlanNodes are shared between the
    # two plans, so estimates live here, not on node.info)
    naive_node_costs: List[dict] = field(default_factory=list)
    optimized_node_costs: List[dict] = field(default_factory=list)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
def _avg_tuple_tokens(source: Table, cols: Sequence[str],
                      serialization: str) -> int:
    """Mean serialized-tuple token cost, sampled from the source table.

    Columns produced mid-plan (not present at the scan) are charged a
    flat default width."""
    known = [c for c in cols if c in source.columns]
    missing = len(cols) - len(known)
    if not known:
        return max(1, missing * DEFAULT_COL_TOKENS)
    n = min(len(source), _SAMPLE_ROWS)
    if n == 0:
        return max(1, missing * DEFAULT_COL_TOKENS)
    total = 0
    for i in range(n):
        tup = {c: source.columns[c][i] for c in known}
        total += estimate_tokens(serialize_tuple(tup, serialization))
    return max(1, total // n + missing * DEFAULT_COL_TOKENS)


def _node_prompt_text(ctx: SemanticContext, node) -> Tuple[str, str]:
    """(prompt_text, prompt_id) for a semantic node, '' for non-LLM ops."""
    spec = node.info.get("prompt")
    if spec is None:
        return "", ""
    return ctx.resolve_prompt(spec)


def _fused_prompt_text(ctx: SemanticContext, node) -> str:
    kinds = node.info["kinds"]
    texts = [ctx.resolve_prompt(p)[0] for p in node.info["prompts"]]
    return build_multi_task(kinds, texts)


def estimate_node_cost(ctx: SemanticContext, node, rows_in: float,
                       source: Table) -> Tuple[float, PlanCost]:
    """(rows_out, provider cost) for one node under the cost model.

    Cardinalities flow through: relational filters halve, llm_filters use
    recorded selectivity, limit truncates, maps preserve."""
    op, info = node.op, node.info
    cost = PlanCost()
    rows = rows_in

    if op == "filter":
        return rows * DEFAULT_SELECTIVITY, cost
    if op == "limit":
        return min(rows, info.get("n", rows)), cost
    if op in ("select", "order_by", "project", "scan"):
        return rows, cost
    if op not in SEMANTIC_OPS:
        return rows, cost

    model = ctx.resolve_model(info["model"])
    n = int(round(rows))
    if n <= 0:
        return 0.0, cost
    per_tuple = _avg_tuple_tokens(source, info.get("cols", ()),
                                  ctx.serialization)

    def waves(requests: int) -> int:
        limit = max(1, getattr(model, "max_concurrency", 1) or 1)
        return -(-requests // limit)

    if op == "llm_embedding":
        cost.requests = 1
        cost.tokens = n * per_tuple
        cost.rows_into_llm = n
        cost.waves = waves(cost.requests)
        return rows, cost

    if op == "llm_rerank":
        window, stride = 10, 5
        windows = 1 if n <= window else 1 + -(-(n - window) // stride)
        prompt_text, _ = _node_prompt_text(ctx, node)
        prefix_tokens = estimate_tokens(
            build_prefix("rerank", prompt_text, ctx.serialization))
        cost.requests = windows
        cost.tokens = windows * (prefix_tokens + window * per_tuple)
        cost.rows_into_llm = n
        # rerank windows chain (each consumes the last window's output):
        # no overlap available, every request is its own wave
        cost.waves = cost.requests
        return rows, cost

    if op == "llm_fused":
        kind = "multi"
        prompt_text = _fused_prompt_text(ctx, node)
    else:
        kind = {"llm_filter": "filter", "llm_complete": "complete",
                "llm_complete_json": "complete_json"}[op]
        prompt_text, _ = _node_prompt_text(ctx, node)
    prefix_tokens = estimate_tokens(
        build_prefix(kind, prompt_text, ctx.serialization))
    plan = plan_batches([per_tuple] * n, prefix_tokens,
                        model.context_window, model.max_output_tokens,
                        ctx.max_batch if ctx.enable_batching else 1)
    cost.requests = len(plan.batches)
    cost.tokens = sum(plan.est_tokens) + cost.requests * prefix_tokens
    cost.rows_into_llm = n
    cost.waves = waves(cost.requests)

    if op == "llm_filter":
        _, pid = _node_prompt_text(ctx, node)
        rows = rows * ctx.expected_selectivity(pid, DEFAULT_SELECTIVITY)
    elif op == "llm_fused":
        for k, pid in zip(node.info["kinds"], node.info["prompt_ids"]):
            if k == "filter":
                rows = rows * ctx.expected_selectivity(
                    pid, DEFAULT_SELECTIVITY)
    return rows, cost


def estimate_plan_cost(ctx: SemanticContext, source: Table,
                       nodes: Sequence) -> Tuple[PlanCost, List[dict]]:
    from .pipeline import Pipeline      # local import: avoid cycle

    total = PlanCost()
    per_node: List[dict] = []
    node_info: dict = {}      # id(node) -> (model_ref, limit, requests,
    #                            standalone waves)
    rows = float(len(source))
    for node in nodes:
        rows, c = estimate_node_cost(ctx, node, rows, source)
        per_node.append({"rows": int(round(rows)),
                         "requests": c.requests, "tokens": c.tokens})
        total.requests += c.requests
        total.tokens += c.tokens
        total.rows_into_llm += c.rows_into_llm
        ref, limit = "", 1
        if node.op in SEMANTIC_OPS and c.requests:
            m = ctx.resolve_model(node.info["model"])
            ref = m.ref
            limit = max(1, getattr(m, "max_concurrency", 1) or 1)
        node_info[id(node)] = (ref, limit, c.requests, c.waves)
    # critical path: nodes in one dispatch group overlap, but same-model
    # members contend for one gate — their requests share the model's
    # concurrency budget, so per group it is the slowest MODEL (summed
    # requests / limit), and groups run back-to-back
    for group in Pipeline._dispatch_groups(list(nodes)):
        if len(group) == 1:
            total.waves += node_info.get(id(group[0]), ("", 1, 0, 0))[3]
            continue
        per_model: dict = {}
        for n in group:
            ref, limit, reqs, _ = node_info[id(n)]
            if not reqs:
                continue
            r0, l0 = per_model.get(ref, (0, limit))
            per_model[ref] = (r0 + reqs, min(l0, limit))
        total.waves += max((-(-r // l) for r, l in per_model.values()),
                           default=0)
    return total, per_node


# ---------------------------------------------------------------------------
# rule 1: relational pushdown
# ---------------------------------------------------------------------------
def _commutes_before(rel, sem) -> bool:
    """May relational node ``rel`` move to run before node ``sem``?"""
    r, s = rel.op, sem.op
    produced = sem.info.get("out")
    fused_outs = sem.info.get("outs", ())

    if r == "limit":
        return s in ("llm_complete", "llm_complete_json", "llm_embedding",
                     "project")
    if r == "filter":
        if s == "llm_filter":
            return True
        if s in ("llm_complete", "llm_complete_json", "llm_embedding",
                 "project"):
            deps = rel.info.get("cols")
            if deps is None:
                return False               # opaque predicate: stay put
            banned = set(fused_outs) | ({produced} if produced else set())
            return not (set(deps) & banned)
        return False
    if r == "select":
        if s in ("llm_filter", "llm_rerank"):
            return set(sem.info.get("cols", ())) <= set(
                rel.info.get("cols", ()))
        return False
    if r == "order_by":
        key = rel.info.get("key")
        if rel.info.get("key_is_callable"):
            return False
        if s == "llm_filter":
            return True
        if s in ("llm_complete", "llm_complete_json", "llm_embedding",
                 "project"):
            banned = set(fused_outs) | ({produced} if produced else set())
            return key not in banned
        return False
    return False


def _pushdown(nodes: List, rewrites: List[str]) -> List:
    nodes = list(nodes)
    changed = True
    while changed:
        changed = False
        for i in range(len(nodes) - 1):
            a, b = nodes[i], nodes[i + 1]
            if (a.op in SEMANTIC_OPS + ("project",)
                    and b.op in RELATIONAL_OPS
                    and _commutes_before(b, a)):
                nodes[i], nodes[i + 1] = b, a
                rewrites.append(f"pushdown({b.op} before {a.op})")
                changed = True
    return nodes


# ---------------------------------------------------------------------------
# rule 2: semantic fusion
# ---------------------------------------------------------------------------
def _model_identity(ctx: SemanticContext, spec):
    # the full resolved resource, not just name@version: inline specs all
    # land on version 0, and fusing ops whose context_window /
    # max_output_tokens differ would run one sub-task under the other's
    # limits
    try:
        return ctx.resolve_model(spec)
    except KeyError:
        return repr(sorted(spec.items()))


def _can_join_group(ctx, group: List, node) -> bool:
    if node.op not in FUSABLE:
        return False
    head = group[0]
    if tuple(node.info["cols"]) != tuple(head.info["cols"]):
        return False
    if _model_identity(ctx, node.info["model"]) != _model_identity(
            ctx, head.info["model"]):
        return False
    # def-use: a later op reading an earlier op's output cannot fuse —
    # guaranteed here because cols are identical and outputs are new
    # columns, but guard against out-name collisions with input cols
    produced = {g.info.get("out") for g in group if g.info.get("out")}
    return not (set(node.info["cols"]) & produced)


def _make_fused_node(ctx: SemanticContext, group: List):
    from .pipeline import PlanNode      # local import: avoid cycle

    cols = list(group[0].info["cols"])
    model_spec = group[0].info["model"]
    subtasks = [{"kind": FUSABLE[g.op], "prompt": g.info["prompt"],
                 "out": g.info.get("out")} for g in group]
    prompt_ids = [ctx.resolve_prompt(g.info["prompt"])[1] for g in group]

    def fn(t: Table) -> Table:
        tuples = [{c: r[c] for c in cols} for r in t.rows()]
        per_task = F.llm_multi(ctx, model_spec,
                               [{"kind": s["kind"], "prompt": s["prompt"]}
                                for s in subtasks], tuples)
        mask = [True] * len(tuples)
        res = t
        for sub, vals in zip(subtasks, per_task):
            if sub["kind"] == "filter":
                mask = [m and bool(v) for m, v in zip(mask, vals)]
            else:
                res = res.with_column(sub["out"], vals)
        return res.filter_mask(mask)

    return PlanNode("llm_fused", {
        "model": model_spec, "cols": cols,
        "kinds": [s["kind"] for s in subtasks],
        "outs": [s["out"] for s in subtasks if s["out"]],
        "prompts": [g.info["prompt"] for g in group],
        "prompt_ids": prompt_ids,
        "fused": [g.op for g in group]}, fn)


def _fuse(ctx: SemanticContext, nodes: List, rewrites: List[str]) -> List:
    out: List = []
    i = 0
    while i < len(nodes):
        node = nodes[i]
        if node.op in FUSABLE:
            group = [node]
            j = i + 1
            while j < len(nodes) and _can_join_group(ctx, group, nodes[j]):
                group.append(nodes[j])
                j += 1
            if len(group) > 1:
                out.append(_make_fused_node(ctx, group))
                rewrites.append(
                    "fusion(" + "+".join(g.op for g in group) + ")")
                i = j
                continue
        out.append(node)
        i += 1
    return out


# ---------------------------------------------------------------------------
# rule 3: cost-ordered filter chains
# ---------------------------------------------------------------------------
def _filter_rank(ctx: SemanticContext, node, source: Table) -> float:
    """Predicate-ordering rank: token cost per unit of elimination,
    cost / (1 - selectivity), ascending — cheap, selective predicates run
    first.  (Plain cost x selectivity mis-orders chains where an
    expensive filter is also very selective; the final plan is
    cost-gated either way.)"""
    prompt_text, pid = _node_prompt_text(ctx, node)
    per_tuple = _avg_tuple_tokens(source, node.info.get("cols", ()),
                                  ctx.serialization)
    prefix = estimate_tokens(
        build_prefix("filter", prompt_text, ctx.serialization))
    sel = ctx.expected_selectivity(pid, DEFAULT_SELECTIVITY)
    return (prefix + per_tuple) / max(1.0 - sel, 1e-6)


def _reorder_filters(ctx: SemanticContext, nodes: List, source: Table,
                     rewrites: List[str]) -> List:
    out: List = []
    i = 0
    while i < len(nodes):
        if nodes[i].op != "llm_filter":
            out.append(nodes[i])
            i += 1
            continue
        j = i
        while j < len(nodes) and nodes[j].op == "llm_filter":
            j += 1
        chain = nodes[i:j]
        ranked = sorted(chain, key=lambda n: _filter_rank(ctx, n, source))
        if ranked != chain:
            rewrites.append(
                f"reorder_filters(chain of {len(chain)} by cost per "
                f"eliminated tuple)")
        out.extend(ranked)
        i = j
    return out


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
# latency-equivalent token cost charged per provider request when ranking
# plans: a chat-API round trip costs ~30 ms of overhead, the price of a
# few hundred tokens of service time (benchmarks/run.py batching bench)
REQUEST_OVERHEAD_TOKENS = 200


def _cost_rank(c: PlanCost) -> float:
    return c.tokens + REQUEST_OVERHEAD_TOKENS * c.requests


def optimize_plan(ctx: SemanticContext, source: Table,
                  nodes: Sequence) -> OptimizedPlan:
    """Rewrite a Pipeline node list; returns both plans' cost estimates.

    Pushdown always applies (it only ever shrinks the tuple stream LLM
    ops see); the filter re-ordering and semantic-fusion rewrites are
    cost-gated — each is kept only if the cost model says the plan got
    cheaper (e.g. fusing a highly selective filter with a completion
    would run the completion over the whole input, so it is rejected).
    Pure planning: no provider calls, no table materialisation."""
    naive = [n for n in nodes]
    rewrites: List[str] = []
    new = _pushdown(list(nodes), rewrites)

    cost, _ = estimate_plan_cost(ctx, source, new)
    for rule in (_reorder_filters, _fuse):
        trial_rw: List[str] = []
        if rule is _reorder_filters:
            trial = rule(ctx, new, source, trial_rw)
        else:
            trial = rule(ctx, new, trial_rw)
        if not trial_rw:
            continue
        trial_cost, _ = estimate_plan_cost(ctx, source, trial)
        if _cost_rank(trial_cost) <= _cost_rank(cost):
            new, cost = trial, trial_cost
            rewrites.extend(trial_rw)
        else:
            rewrites.extend(f"rejected({rw}: estimated cost higher)"
                            for rw in trial_rw)

    plan = OptimizedPlan(nodes=new, rewrites=rewrites)
    plan.naive_cost, plan.naive_node_costs = estimate_plan_cost(
        ctx, source, list(naive))
    plan.optimized_cost, plan.optimized_node_costs = estimate_plan_cost(
        ctx, source, new)
    return plan
