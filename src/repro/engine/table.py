"""Minimal columnar Table (the DuckDB stand-in for paper Queries 1-3).

Columns are python lists / numpy arrays of equal length.  Operations are
vectorised where possible and always return new Tables (immutability keeps
plan re-execution deterministic for the cache/dedup benchmarks).
"""

from __future__ import annotations

from typing import Callable, Dict, List, Sequence

import numpy as np


class Table:
    def __init__(self, columns: Dict[str, Sequence]):
        lens = {len(v) for v in columns.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged columns: { {k: len(v) for k, v in columns.items()} }")
        self.columns = {k: list(v) for k, v in columns.items()}

    # ---- basics ------------------------------------------------------------
    def __len__(self):
        return len(next(iter(self.columns.values()), []))

    @property
    def column_names(self) -> List[str]:
        return list(self.columns)

    def column(self, name: str) -> list:
        return self.columns[name]

    def rows(self) -> List[dict]:
        names = self.column_names
        return [dict(zip(names, vals))
                for vals in zip(*[self.columns[n] for n in names])]

    def head(self, n: int = 5) -> "Table":
        return Table({k: v[:n] for k, v in self.columns.items()})

    # ---- relational ops ------------------------------------------------------
    def select(self, *names: str) -> "Table":
        return Table({n: self.columns[n] for n in names})

    def with_column(self, name: str, values: Sequence) -> "Table":
        cols = dict(self.columns)
        cols[name] = list(values)
        return Table(cols)

    def filter_mask(self, mask: Sequence[bool]) -> "Table":
        return Table({k: [x for x, m in zip(v, mask) if m]
                      for k, v in self.columns.items()})

    def filter(self, pred: Callable[[dict], bool]) -> "Table":
        return self.filter_mask([pred(r) for r in self.rows()])

    def order_by(self, key, desc: bool = False) -> "Table":
        if isinstance(key, str):
            vals = self.columns[key]
        else:
            vals = [key(r) for r in self.rows()]
        idx = np.argsort(np.asarray(vals), kind="stable")
        if desc:
            idx = idx[::-1]
        return self.take(idx)

    def take(self, indices) -> "Table":
        return Table({k: [v[i] for i in indices]
                      for k, v in self.columns.items()})

    def limit(self, n: int) -> "Table":
        return self.head(n)

    def lateral(self, fn) -> "Table":
        """LATERAL join: ``fn(i, row) -> Table`` of matches per row; the
        parent row's columns replicate once per match (paper Query 3:
        a retrieval operator expands each query row into its top-k
        candidate rows).  Match tables must share one schema; a row with
        an empty match table contributes no output rows."""
        parents = self.rows()
        matches = [fn(i, r) for i, r in enumerate(parents)]
        child_names: List[str] = []
        for m in matches:
            if m.column_names:
                child_names = m.column_names
                break
        out: Dict[str, list] = {n: [] for n in self.column_names}
        for n in child_names:
            if n in out:
                raise ValueError(
                    f"lateral match column {n!r} collides with a parent "
                    f"column")
            out[n] = []
        for row, m in zip(parents, matches):
            k = len(m)
            for n in self.column_names:
                out[n].extend([row[n]] * k)
            for n in child_names:
                out[n].extend(m.columns[n])
        return Table(out)

    def full_outer_join(self, other: "Table", on: str,
                        suffixes=("_l", "_r")) -> "Table":
        """FULL OUTER JOIN on one key column (paper Query 3 fusion step);
        missing side contributes None."""
        left_idx = {v: i for i, v in enumerate(self.columns[on])}
        right_idx = {v: i for i, v in enumerate(other.columns[on])}
        keys = list(dict.fromkeys(list(left_idx) + list(right_idx)))
        out: Dict[str, list] = {on: keys}
        for name in self.column_names:
            if name == on:
                continue
            n2 = name + (suffixes[0] if name in other.column_names else "")
            out[n2] = [self.columns[name][left_idx[k]]
                       if k in left_idx else None for k in keys]
        for name in other.column_names:
            if name == on:
                continue
            n2 = name + (suffixes[1] if name in self.column_names else "")
            out[n2] = [other.columns[name][right_idx[k]]
                       if k in right_idx else None for k in keys]
        return Table(out)

    def group_rows(self, key: str) -> Dict:
        groups: Dict = {}
        for r in self.rows():
            groups.setdefault(r[key], []).append(r)
        return groups

    def __repr__(self):
        n = len(self)
        cols = ", ".join(f"{k}" for k in self.column_names)
        lines = [f"Table[{n} rows: {cols}]"]
        for r in self.rows()[:8]:
            lines.append("  " + " | ".join(f"{k}={str(v)[:32]}"
                                           for k, v in r.items()))
        if n > 8:
            lines.append(f"  ... {n - 8} more")
        return "\n".join(lines)
