"""Static plan analysis: schema inference, pre-flight diagnostics, and
rewrite-soundness verification (paper §2.1's "resources are schema
objects" carried to its conclusion).

FlockMTL makes ``MODEL`` and ``PROMPT`` first-class, versioned schema
objects precisely so that references are *statically resolvable* — yet
a typo'd model name, a prompt placeholder naming a column the node
never sees, or an optimizer rewrite that silently changed a node's
output columns would all surface mid-``collect()``, after paid provider
requests have shipped.  This module closes that gap with three layers:

1. **Schema/provenance inference** — ``infer_schema(source, nodes)``
   assigns every plan node an inferred output schema: column names,
   best-effort dtypes sampled from the source/corpus tables, and a
   provenance label (``scan``, ``node[i]:llm_complete``,
   ``corpus[content]``).  Inference understands the full operator
   vocabulary: ``Table.lateral`` expansion with the ``_doc`` collision
   suffix exactly as ``retrieval_ops.make_retrieval_fn`` computes it,
   fused ``llm_fused`` multi-outputs, speculative chains, the
   map-past-filter (``llm_spec_map``) and retrieval-aware rerank
   (``spec_rerank``) speculation nodes, and grouped ``llm_rerank``.

2. **Pre-flight diagnostics** — ``analyze_plan(ctx, source, nodes)``
   resolves MODEL/PROMPT references against the context's
   ``core.resources.Catalog``, checks ``{placeholder}`` tokens in
   prompt templates against the node's visible input columns, and
   centralizes parameter validation (ann knobs, ``k > 0``, fusion
   method names).  Every finding is a ``Diagnostic`` with a stable
   ``FLK``-prefixed code, a severity, and the node span — and the whole
   pass is pure planning: **zero provider requests**.

3. **Rewrite-soundness obligations** — every rule in
   ``engine/optimizer.py`` emits a machine-checkable ``Obligation``
   (commute legality against the node's ``outs`` ban set, schema
   preservation, mask-equivalence for filter reorders/speculation,
   candidate-set recall contracts for ``ann_select``/``k_pushdown``).
   ``verify_rewrites`` discharges them on the optimized plan with an
   *independent* encoding of the legality rules, so a bug in either the
   optimizer or the verifier is caught by the other.

Diagnostic codes (stable; see docs/diagnostics.md):

=======  ========  ====================================================
code     severity  meaning
=======  ========  ====================================================
FLK001   error     MODEL reference not found in the catalog
FLK002   error     PROMPT reference not found in the catalog
FLK003   error     prompt placeholder not bound to a visible column
FLK004   error     column not present in the node's input schema
FLK005   error     invalid operator parameter (k, ann knobs, fusion)
FLK006   error/    output column collides with an existing column
         warning   (error when ``Table.lateral`` would raise)
FLK010   error     rewrite-soundness obligation failed
=======  ========  ====================================================

Entry points: ``Pipeline.check()`` and ``Pipeline.collect(verify=)``
wrap this module; ``explain()`` renders the inferred schemas.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.fusion import FUSION_METHODS

from .table import Table

# ops whose executors feed tuples to a provider-backed LLM call
LLM_OPS = ("llm_filter", "llm_complete", "llm_complete_json",
           "llm_embedding", "llm_rerank", "llm_fused", "llm_spec_chain",
           "llm_spec_map", "spec_rerank")
# retrieval operators (mirrors retrieval_ops.RETRIEVAL_OPS without the
# import: analysis must stay importable from the optimizer without
# cycles)
RETRIEVAL_OPS = ("vector_topk", "bm25_topk", "hybrid_topk")
# fusable op -> metaprompt kind (mirrors optimizer.FUSABLE)
_FUSABLE_KINDS = {"llm_filter": "filter", "llm_complete": "complete",
                  "llm_complete_json": "complete_json"}
# output dtype a semantic map op produces
_OUT_DTYPE = {"llm_complete": "str", "llm_complete_json": "json",
              "llm_embedding": "vector", "project": "any",
              "complete": "str", "complete_json": "json"}

# ``{placeholder}`` tokens in prompt templates: an identifier directly
# after the brace (so JSON-shaped prompt text like ``{"issue": ...}``
# never matches); ``{{`` escapes
_PLACEHOLDER_RE = re.compile(r"(?<!\{)\{([A-Za-z_][A-Za-z0-9_]*)\}")


# ---------------------------------------------------------------------------
# diagnostics
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Diagnostic:
    """One analyzer finding: a stable ``FLK`` code, a severity
    (``error`` | ``warning``), the offending node's index and op, and a
    human message."""
    code: str
    severity: str
    message: str
    node: Optional[int] = None
    op: Optional[str] = None

    def __str__(self):
        span = ("" if self.node is None
                else f" @node[{self.node}]"
                     + (f" {self.op}" if self.op else ""))
        return f"{self.code} {self.severity}{span}: {self.message}"


class PlanValidationError(ValueError):
    """Raised by ``Pipeline.check()`` / ``collect(verify="strict")``
    when the analyzer finds error-severity diagnostics.  Carries the
    full list on ``.diagnostics``."""

    def __init__(self, diagnostics: Sequence[Diagnostic]):
        self.diagnostics = list(diagnostics)
        errors = [d for d in self.diagnostics if d.severity == "error"]
        lines = [f"plan failed static analysis "
                 f"({len(errors)} error(s)):"]
        lines += [f"  {d}" for d in self.diagnostics]
        super().__init__("\n".join(lines))


# ---------------------------------------------------------------------------
# schema model
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Column:
    """One inferred column: name, best-effort dtype, and provenance
    (which node or source table produced it)."""
    name: str
    dtype: str = "any"
    origin: str = "scan"


class Schema:
    """Ordered column set flowing between plan nodes."""

    def __init__(self, columns: Sequence[Column] = ()):
        self._cols: Dict[str, Column] = {c.name: c for c in columns}

    # ---- access ----------------------------------------------------------
    @property
    def names(self) -> List[str]:
        return list(self._cols)

    def __contains__(self, name: str) -> bool:
        return name in self._cols

    def __len__(self):
        return len(self._cols)

    def get(self, name: str) -> Optional[Column]:
        return self._cols.get(name)

    def columns(self) -> List[Column]:
        return list(self._cols.values())

    # ---- derivation (immutable) -----------------------------------------
    def add(self, col: Column) -> "Schema":
        s = Schema(self.columns())
        s._cols[col.name] = col
        return s

    def restrict(self, names: Sequence[str]) -> "Schema":
        return Schema([self._cols[n] for n in names if n in self._cols])

    def render(self, max_cols: int = 8) -> str:
        cols = self.columns()
        body = ", ".join(f"{c.name}:{c.dtype}" for c in cols[:max_cols])
        if len(cols) > max_cols:
            body += f", ... ({len(cols)} cols)"
        return body


def _dtype_of(value: Any) -> str:
    if isinstance(value, bool):
        return "bool"
    if isinstance(value, int):
        return "int"
    if isinstance(value, float):
        return "float"
    if isinstance(value, str):
        return "str"
    if isinstance(value, dict):
        return "json"
    if isinstance(value, (list, tuple)) or hasattr(value, "shape"):
        return "vector"
    return "any"


def table_schema(table: Table, origin: str = "scan") -> Schema:
    """Schema sampled from a materialized table: dtype of the first
    non-None value per column."""
    cols = []
    for name in table.column_names:
        dtype = "any"
        for v in table.columns[name]:
            if v is not None:
                dtype = _dtype_of(v)
                break
        cols.append(Column(name, dtype, origin))
    return Schema(cols)


def _dtype_compatible(a: str, b: str) -> bool:
    if a == b or "any" in (a, b):
        return True
    return {a, b} <= {"int", "float", "bool"}     # numeric widening


# ---------------------------------------------------------------------------
# per-node schema inference + pre-flight checks
# ---------------------------------------------------------------------------
@dataclass
class PlanAnalysis:
    """Result of one static pass: per-node OUTPUT schemas (aligned with
    the node list) and the collected diagnostics."""
    schemas: List[Schema] = field(default_factory=list)
    diagnostics: List[Diagnostic] = field(default_factory=list)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def output_schema(self) -> Schema:
        return self.schemas[-1] if self.schemas else Schema()

    def raise_on_error(self):
        if self.errors:
            raise PlanValidationError(self.diagnostics)


def _check_model(ctx, spec, idx, op, diags: List[Diagnostic]):
    """Catalog resolution of a MODEL spec (FLK001) + inline-spec
    parameter sanity (FLK005)."""
    if not isinstance(spec, dict):
        diags.append(Diagnostic(
            "FLK005", "error",
            f"model spec must be a dict, got {type(spec).__name__}",
            idx, op))
        return
    if "model_name" in spec:
        if ctx is not None and ctx.catalog.get_model(
                spec["model_name"]) is None:
            diags.append(Diagnostic(
                "FLK001", "error",
                f"MODEL {spec['model_name']!r} not found in the catalog",
                idx, op))
        return
    for key, floor in (("context_window", 1), ("max_output_tokens", 0),
                       ("embedding_dim", 0), ("max_concurrency", 1)):
        if key in spec:
            try:
                ok = int(spec[key]) >= floor
            except (TypeError, ValueError):
                ok = False
            if not ok:
                diags.append(Diagnostic(
                    "FLK005", "error",
                    f"model spec {key}={spec[key]!r} must be an int "
                    f">= {floor}", idx, op))


def _check_prompt(ctx, spec, visible: Sequence[str], schema: Schema,
                  idx, op, diags: List[Diagnostic]):
    """Catalog resolution of a PROMPT spec (FLK002) + placeholder
    binding against the node's visible tuple columns (FLK003)."""
    if not isinstance(spec, dict):
        diags.append(Diagnostic(
            "FLK005", "error",
            f"prompt spec must be a dict, got {type(spec).__name__}",
            idx, op))
        return
    text = None
    if "prompt_name" in spec:
        if ctx is None:
            return
        p = ctx.catalog.get_prompt(spec["prompt_name"])
        if p is None:
            diags.append(Diagnostic(
                "FLK002", "error",
                f"PROMPT {spec['prompt_name']!r} not found in the "
                f"catalog", idx, op))
            return
        text = p.text
    else:
        text = spec.get("prompt", "")
    for name in dict.fromkeys(_PLACEHOLDER_RE.findall(text or "")):
        if name in visible:
            continue
        if name in schema:
            diags.append(Diagnostic(
                "FLK003", "error",
                f"prompt placeholder {{{name}}} names column {name!r}, "
                f"which exists but is not passed in cols={list(visible)}",
                idx, op))
        else:
            diags.append(Diagnostic(
                "FLK003", "error",
                f"prompt placeholder {{{name}}} does not match any "
                f"input column (have: {schema.names})", idx, op))


def _check_cols(cols, schema: Schema, idx, op,
                diags: List[Diagnostic], what: str = "cols"):
    for c in cols:
        if c not in schema:
            diags.append(Diagnostic(
                "FLK004", "error",
                f"{what} references column {c!r} not present in the "
                f"input schema (have: {schema.names})", idx, op))


def _check_ann(info: dict, idx, op, diags: List[Diagnostic]):
    ann = info.get("ann")
    if ann is not None and ann not in ("auto", "ivf", "exact"):
        diags.append(Diagnostic(
            "FLK005", "error",
            f"ann={ann!r}: expected 'auto', 'ivf', 'exact' or None",
            idx, op))
    rt = info.get("recall_target")
    if rt is not None and not (0.0 < float(rt) <= 1.0):
        diags.append(Diagnostic(
            "FLK005", "error",
            f"recall_target={rt!r} must be in (0, 1]", idx, op))
    for knob in ("nprobe", "nlist"):
        v = info.get(knob)
        if v is not None and int(v) < 1:
            diags.append(Diagnostic(
                "FLK005", "error", f"{knob}={v!r} must be >= 1",
                idx, op))
    np_, nl = info.get("nprobe"), info.get("nlist")
    if np_ is not None and nl is not None and int(np_) > int(nl):
        diags.append(Diagnostic(
            "FLK005", "warning",
            f"nprobe={np_} > nlist={nl}: clamped to nlist at scan time "
            f"(bit-identical to exact)", idx, op))
    if any(info.get(k) is not None
           for k in ("recall_target", "nprobe", "nlist")) and ann is None:
        diags.append(Diagnostic(
            "FLK005", "error",
            "recall_target/nprobe/nlist require ann= "
            "('auto', 'ivf' or 'exact')", idx, op))


def _add_out(schema: Schema, name: str, dtype: str, idx: int, op: str,
             diags: List[Diagnostic]) -> Schema:
    if name in schema:
        prev = schema.get(name)
        diags.append(Diagnostic(
            "FLK006", "warning",
            f"output column {name!r} overwrites an existing column "
            f"(from {prev.origin})", idx, op))
    return schema.add(Column(name, dtype, f"node[{idx}]:{op}"))


class _NodeShim:
    """Minimal stand-in for a ``PlanNode`` (op + info) so analysis can
    recurse into the retrieval node a ``spec_rerank`` wraps without
    importing ``engine.pipeline`` (which imports this module)."""
    __slots__ = ("op", "info")

    def __init__(self, op: str, info: dict):
        self.op, self.info = op, info


def _infer_retrieval(node, schema: Schema, idx: int,
                     diags: List[Diagnostic]) -> Schema:
    """Retrieval expansion: parent columns replicate, corpus columns
    join under the ``_doc`` collision suffix (exactly the rename
    ``make_retrieval_fn`` applies), plus the score and rank columns.
    A name that collides even after the suffix is an error — the
    runtime ``Table.lateral`` raises on it."""
    op, info = node.op, node.info
    corpus = info.get("corpus")
    corpus_sch = (table_schema(corpus, origin="corpus")
                  if corpus is not None else Schema())
    out = schema
    for col in corpus_sch.columns():
        name = col.name + "_doc" if col.name in schema else col.name
        if name in out:
            diags.append(Diagnostic(
                "FLK006", "error",
                f"corpus column {col.name!r} collides with parent "
                f"column {name!r} even after the _doc suffix — "
                f"Table.lateral will reject this plan", idx, op))
            continue
        out = out.add(Column(name, col.dtype,
                             f"corpus[{col.name}]"))
    for name, dtype in ((info.get("out"), "float"),
                        (str(info.get("out")) + "_rank", "int")):
        if name in out:
            diags.append(Diagnostic(
                "FLK006", "error",
                f"retrieval output column {name!r} collides with an "
                f"existing column — Table.lateral will reject this "
                f"plan", idx, op))
            continue
        out = out.add(Column(name, dtype, f"node[{idx}]:{op}"))
    return out


def _analyze_node(ctx, node, schema: Schema, idx: int,
                  diags: List[Diagnostic]) -> Schema:
    """One inference + pre-flight step: returns the node's OUTPUT
    schema, appending diagnostics along the way."""
    op, info = node.op, node.info

    if op == "scan":
        return schema

    if op == "select":
        _check_cols(info.get("cols", ()), schema, idx, op, diags,
                    "select")
        return schema.restrict(list(info.get("cols", ())))

    if op == "filter":
        if info.get("cols") is not None:
            _check_cols(info["cols"], schema, idx, op, diags, "filter")
        return schema

    if op == "order_by":
        if not info.get("key_is_callable") and info.get("key"):
            _check_cols([info["key"]], schema, idx, op, diags,
                        "order_by key")
        return schema

    if op == "limit":
        n = info.get("n")
        if n is not None and int(n) < 0:
            diags.append(Diagnostic(
                "FLK005", "error", f"limit n={n!r} must be >= 0",
                idx, op))
        return schema

    if op == "project":
        return _add_out(schema, info["out"], "any", idx, op, diags)

    if op in ("llm_complete", "llm_complete_json", "llm_embedding"):
        _check_cols(info.get("cols", ()), schema, idx, op, diags)
        _check_model(ctx, info.get("model"), idx, op, diags)
        if op != "llm_embedding":
            _check_prompt(ctx, info.get("prompt"),
                          list(info.get("cols", ())), schema, idx, op,
                          diags)
        return _add_out(schema, info["out"], _OUT_DTYPE[op], idx, op,
                        diags)

    if op == "llm_filter":
        _check_cols(info.get("cols", ()), schema, idx, op, diags)
        _check_model(ctx, info.get("model"), idx, op, diags)
        _check_prompt(ctx, info.get("prompt"),
                      list(info.get("cols", ())), schema, idx, op, diags)
        return schema

    if op == "llm_rerank":
        _check_cols(info.get("cols", ()), schema, idx, op, diags)
        _check_model(ctx, info.get("model"), idx, op, diags)
        _check_prompt(ctx, info.get("prompt"),
                      list(info.get("cols", ())), schema, idx, op, diags)
        if info.get("by") is not None:
            _check_cols([info["by"]], schema, idx, op, diags,
                        "rerank by")
        return schema

    if op == "llm_fused":
        _check_cols(info.get("cols", ()), schema, idx, op, diags)
        _check_model(ctx, info.get("model"), idx, op, diags)
        for p in info.get("prompts", ()):
            _check_prompt(ctx, p, list(info.get("cols", ())), schema,
                          idx, op, diags)
        out = schema
        outs = iter(info.get("outs", ()))
        for kind in info.get("kinds", ()):
            if kind == "filter":
                continue
            out = _add_out(out, next(outs), _OUT_DTYPE.get(kind, "any"),
                           idx, op, diags)
        return out

    if op == "llm_spec_chain":
        for member in info.get("member_specs", ()):
            _check_cols(member.get("cols", ()), schema, idx, op, diags)
            _check_model(ctx, member.get("model"), idx, op, diags)
            _check_prompt(ctx, member.get("prompt"),
                          list(member.get("cols", ())), schema, idx, op,
                          diags)
        return schema

    if op == "llm_spec_map":
        # filter members see the node's INPUT schema (the map runs
        # speculatively over the same rows)
        for member in info.get("member_specs", ()):
            _check_cols(member.get("cols", ()), schema, idx, op, diags)
            _check_model(ctx, member.get("model"), idx, op, diags)
            _check_prompt(ctx, member.get("prompt"),
                          list(member.get("cols", ())), schema, idx, op,
                          diags)
        _check_cols(info.get("cols", ()), schema, idx, op, diags)
        _check_model(ctx, info.get("model"), idx, op, diags)
        _check_prompt(ctx, info.get("prompt"),
                      list(info.get("cols", ())), schema, idx, op, diags)
        dtype = _OUT_DTYPE.get(info.get("map_op", "llm_complete"), "str")
        return _add_out(schema, info["out"], dtype, idx, op, diags)

    if op == "spec_rerank":
        # the wrapped retrieval node expands the schema exactly as the
        # standalone node would; the rerank spec then reads the
        # EXPANDED columns
        retr = _NodeShim(info["retr_op"], info["_retr"])
        out = _analyze_node(ctx, retr, schema, idx, diags)
        rr = info.get("_rerank", {})
        _check_cols(rr.get("cols", ()), out, idx, op, diags)
        _check_model(ctx, rr.get("model"), idx, op, diags)
        _check_prompt(ctx, rr.get("prompt"), list(rr.get("cols", ())),
                      out, idx, op, diags)
        if rr.get("by") is not None:
            _check_cols([rr["by"]], out, idx, op, diags, "rerank by")
        return out

    if op in RETRIEVAL_OPS:
        qcol = info.get("query_col")
        if qcol is not None:
            _check_cols([qcol], schema, idx, op, diags, "query_col")
        k = info.get("k")
        if k is None or int(k) < 1:
            diags.append(Diagnostic(
                "FLK005", "error",
                f"k={k!r} must be an int >= 1", idx, op))
        ck = info.get("candidate_k")
        if ck is not None:
            if int(ck) < 1:
                diags.append(Diagnostic(
                    "FLK005", "error",
                    f"candidate_k={ck!r} must be >= 1", idx, op))
            elif k is not None and int(ck) < int(k):
                diags.append(Diagnostic(
                    "FLK005", "warning",
                    f"candidate_k={ck} < k={k}: per-retriever depth "
                    f"truncates the final top-k", idx, op))
        if op == "hybrid_topk" and info.get(
                "fusion") not in FUSION_METHODS:
            diags.append(Diagnostic(
                "FLK005", "error",
                f"fusion={info.get('fusion')!r} is not one of "
                f"{FUSION_METHODS}", idx, op))
        if op != "bm25_topk":
            _check_model(ctx, info.get("model"), idx, op, diags)
            _check_ann(info, idx, op, diags)
        return _infer_retrieval(node, schema, idx, diags)

    diags.append(Diagnostic(
        "FLK005", "warning",
        f"unknown operator {op!r}: schema passed through unchanged",
        idx, op))
    return schema


def analyze_plan(ctx, source: Table, nodes: Sequence) -> PlanAnalysis:
    """Full static pass over a node list: per-node output schemas plus
    pre-flight diagnostics.  Pure planning — resolves resources against
    the catalog but never touches the provider."""
    res = PlanAnalysis()
    schema = table_schema(source)
    for idx, node in enumerate(nodes):
        schema = _analyze_node(ctx, node, schema, idx, res.diagnostics)
        res.schemas.append(schema)
    return res


def infer_schema(source: Table, nodes: Sequence) -> List[Schema]:
    """Per-node inferred OUTPUT schemas (catalog checks skipped —
    shape-only inference; use ``analyze_plan`` for full pre-flight)."""
    return analyze_plan(None, source, nodes).schemas


# ---------------------------------------------------------------------------
# rewrite-soundness obligations
# ---------------------------------------------------------------------------
@dataclass(frozen=False)
class Obligation:
    """One machine-checkable claim an optimizer rewrite must honour on
    the optimized plan.  ``rule`` is the human rewrite string (aligned
    with ``OptimizedPlan.rewrites``), ``kind`` selects the discharge
    procedure, ``payload`` carries the structured claim."""
    rule: str
    kind: str       # commute | fusion_exact | mask_equivalence |
    #                 selection_invariance | recall_contract |
    #                 index_shared | schema_preserved
    payload: dict = field(default_factory=dict)

    def __str__(self):
        return f"{self.kind}[{self.rule}]"


def semantic_key(node) -> dict:
    """Identity of a semantic/retrieval node that survives rebuilds:
    op + output column + corpus fingerprint + prompt spec.  Used by
    commute obligations to re-locate the node in the optimized plan
    (retrieval nodes are REBUILT by the retrieval rewrites, and fusable
    nodes may merge into an ``llm_fused``, so ``id()`` would dangle)."""
    info = node.info
    return {"op": node.op, "out": info.get("out"),
            "corpus_fp": info.get("corpus_fp"),
            "prompt": info.get("prompt")}


def _node_ban_set(node) -> set:
    """Columns node may produce — the pushdown ban set (mirrors
    ``Pipeline._node_outs`` plus the retrieval ``outs``)."""
    info = node.info
    banned = set(info.get("outs", ()))
    if info.get("out"):
        banned.add(info["out"])
        banned.add(info["out"] + "_rank")
    return banned


def commute_legal(rel, sem) -> Tuple[bool, str]:
    """Independent encoding of the pushdown legality table (the
    verifier's own, NOT a call into ``optimizer._commutes_before`` —
    so a bug in either is caught by the other).  Returns (legal,
    reason-when-not)."""
    r, s = rel.op, sem.op
    banned = _node_ban_set(sem)
    row_preserving = ("llm_complete", "llm_complete_json",
                      "llm_embedding", "project")
    if r == "limit":
        if s in row_preserving:
            return True, ""
        return False, (f"limit only commutes with row-preserving map "
                       f"ops, not {s}")
    if r == "filter":
        if s == "llm_filter":
            return True, ""     # conjunctive predicates commute
        deps = rel.info.get("cols")
        if deps is None:
            return False, "opaque filter predicate cannot cross"
        if s in row_preserving or s in RETRIEVAL_OPS:
            hit = set(deps) & banned
            if hit:
                return False, (f"filter reads {sorted(hit)} which "
                               f"{s} produces")
            return True, ""
        return False, f"filter does not commute with {s}"
    if r == "select":
        if s in ("llm_filter", "llm_rerank"):
            needed = set(sem.info.get("cols", ()))
            if sem.info.get("by") is not None:
                needed.add(sem.info["by"])
            missing = needed - set(rel.info.get("cols", ()))
            if missing:
                return False, (f"select drops columns {sorted(missing)} "
                               f"that {s} reads")
            return True, ""
        return False, f"select does not commute with {s}"
    if r == "order_by":
        if rel.info.get("key_is_callable"):
            return False, "callable sort key cannot cross"
        if s == "llm_filter":
            return True, ""
        if s in row_preserving:
            if rel.info.get("key") in banned:
                return False, (f"sort key {rel.info.get('key')!r} is "
                               f"produced by {s}")
            return True, ""
        return False, f"order_by does not commute with {s}"
    return False, f"{r} is not a pushdown-eligible relational op"


def _prompt_fingerprint(spec) -> str:
    if not isinstance(spec, dict):
        return repr(spec)
    return repr(sorted((k, repr(v)) for k, v in spec.items()))


def _plan_filter_multiset(ctx, nodes) -> Dict[str, int]:
    """Multiset of filter predicates a plan evaluates (as prompt
    fingerprints), counted across plain ``llm_filter`` nodes, fused
    filter sub-tasks, and speculative chain members — the invariant a
    mask-equivalence obligation checks: AND is commutative, so a sound
    reorder/fusion/speculation preserves exactly this multiset."""
    counts: Dict[str, int] = {}

    def bump(spec):
        fp = _prompt_fingerprint(spec)
        counts[fp] = counts.get(fp, 0) + 1

    for node in nodes:
        if node.op == "llm_filter":
            bump(node.info.get("prompt"))
        elif node.op == "llm_fused":
            for kind, p in zip(node.info.get("kinds", ()),
                               node.info.get("prompts", ())):
                if kind == "filter":
                    bump(p)
        elif node.op in ("llm_spec_chain", "llm_spec_map"):
            for member in node.info.get("member_specs", ()):
                bump(member.get("prompt"))
    return counts


def _find_node(nodes, key: dict) -> Optional[int]:
    """Locate the optimized-plan node carrying a semantic identity:
    directly, merged into an ``llm_fused`` node, or as a speculative
    chain member."""
    for i, node in enumerate(nodes):
        info = node.info
        if (node.op == key["op"]
                and info.get("out") == key.get("out")
                and info.get("corpus_fp") == key.get("corpus_fp")
                and (key.get("prompt") is None
                     or info.get("prompt") == key["prompt"])):
            return i
        if node.op == "llm_fused" and key["op"] in _FUSABLE_KINDS:
            if (key.get("out") and key["out"] in info.get("outs", ())) \
                    or (key.get("prompt") is not None
                        and key["prompt"] in info.get("prompts", ())):
                return i
        if (node.op in ("llm_spec_chain", "llm_spec_map")
                and key["op"] == "llm_filter"):
            for member in info.get("member_specs", ()):
                if member.get("prompt") == key.get("prompt"):
                    return i
        if node.op == "spec_rerank" and key["op"] in RETRIEVAL_OPS:
            ri = info.get("_retr", {})
            if (info.get("retr_op") == key["op"]
                    and ri.get("out") == key.get("out")
                    and ri.get("corpus_fp") == key.get("corpus_fp")):
                return i
    return None


def _retrieval_info(node) -> dict:
    """The retrieval-shaped info dict of a node: the node's own for a
    plain retrieval op, the wrapped ``_retr`` for ``spec_rerank``."""
    if node.op == "spec_rerank":
        return node.info.get("_retr", {})
    return node.info


def _discharge(ctx, source: Table, naive_nodes, opt_nodes,
               ob: Obligation) -> Optional[str]:
    """Check one obligation against the optimized plan.  Returns None
    when discharged, else the failure reason."""
    p = ob.payload

    if ob.kind == "commute":
        rel_idx = next((i for i, n in enumerate(opt_nodes)
                        if id(n) == p["rel_id"]), None)
        if rel_idx is None:
            return "pushed relational node vanished from the plan"
        sem_idx = _find_node(opt_nodes, p["sem_key"])
        if sem_idx is None:
            return (f"semantic node {p['sem_key']['op']} vanished from "
                    f"the plan")
        if rel_idx > sem_idx:
            return (f"pushdown claimed {opt_nodes[rel_idx].op} runs "
                    f"before {p['sem_key']['op']} but it does not")
        legal, why = commute_legal(opt_nodes[rel_idx], p["sem_node"])
        if not legal:
            return f"commute is illegal: {why}"
        # the pushed node's read-set must be satisfiable at its NEW
        # position — columns it reads exist before the semantic node
        schemas = infer_schema(source, opt_nodes)
        avail = (schemas[rel_idx - 1] if rel_idx > 0
                 else table_schema(source))
        reads = set(opt_nodes[rel_idx].info.get("cols") or ())
        if opt_nodes[rel_idx].op == "order_by":
            if not opt_nodes[rel_idx].info.get("key_is_callable"):
                reads = {opt_nodes[rel_idx].info.get("key")}
        missing = {c for c in reads if c and c not in avail}
        if missing:
            return (f"pushed {opt_nodes[rel_idx].op} reads "
                    f"{sorted(missing)}, unavailable at its new "
                    f"position")
        return None

    if ob.kind == "fusion_exact":
        for node in opt_nodes:
            if node.op != "llm_fused":
                continue
            info = node.info
            if (list(info.get("kinds", ())) == p["kinds"]
                    and list(info.get("cols", ())) == p["cols"]
                    and list(info.get("outs", ())) == p["outs"]
                    and list(info.get("prompts", ())) == p["prompts"]):
                if ctx is not None:
                    idents = set()
                    for spec in p["models"]:
                        try:
                            idents.add(ctx.resolve_model(spec))
                        except KeyError:
                            return ("fused member MODEL no longer "
                                    "resolves")
                    if len(idents) > 1:
                        return ("fused members resolve to different "
                                "models")
                return None
        return "no llm_fused node matches the fused group"

    if ob.kind == "mask_equivalence":
        naive_f = _plan_filter_multiset(ctx, naive_nodes)
        opt_f = _plan_filter_multiset(ctx, opt_nodes)
        if naive_f != opt_f:
            return (f"filter predicate multiset changed: "
                    f"{sorted(naive_f.items())} -> "
                    f"{sorted(opt_f.items())}")
        if p.get("spec_chain") or p.get("spec_map"):
            want = sorted(_prompt_fingerprint(s) for s in p["prompts"])
            # a chain chosen for chain-speculation may later be absorbed
            # into an llm_spec_map by the map-past-filter rule — either
            # node form discharges the chain's claim
            ops = (("llm_spec_chain", "llm_spec_map")
                   if p.get("spec_chain") else ("llm_spec_map",))
            for node in opt_nodes:
                if node.op not in ops:
                    continue
                got = sorted(
                    _prompt_fingerprint(m.get("prompt"))
                    for m in node.info.get("member_specs", ()))
                if got == want:
                    return None
            if p.get("spec_chain"):
                return "no llm_spec_chain node carries the chain members"
            return "no llm_spec_map node carries the filter members"
        return None

    if ob.kind == "selection_invariance":
        idx = _find_node(opt_nodes, p["key"])
        if idx is None:
            return "pruned retrieval node vanished from the plan"
        info = _retrieval_info(opt_nodes[idx])
        if not info.get("prune_corpus"):
            return "prune_corpus flag missing on the rewritten node"
        if info.get("corpus_filter") is None:
            return ("corpus predicate dropped — pruning may only move "
                    "WHERE the predicate applies, never remove it")
        return None

    if ob.kind == "recall_contract":
        idx = _find_node(opt_nodes, p["key"])
        if idx is None:
            return "retrieval node vanished from the plan"
        info = _retrieval_info(opt_nodes[idx])
        if p.get("spec_rerank"):
            node = opt_nodes[idx]
            if node.op != "spec_rerank":
                return "speculative rerank node vanished from the plan"
            if node.info.get("k") != p["k"]:
                return (f"spec_rerank k drifted: claimed {p['k']}, "
                        f"plan has {node.info.get('k')}")
            # reconciliation is structural: the authoritative retrieval
            # runs unchanged inside the node, so the final top-k is the
            # serial one by construction — only identity + k can drift
            return None
        if "candidate_k" in p:
            ck = info.get("candidate_k")
            if ck is None or ck < max(p["k"], 1):
                return (f"candidate depth {ck!r} no longer covers the "
                        f"final top-{p['k']}")
            if ck != p["candidate_k"]:
                return (f"candidate_k drifted: claimed "
                        f"{p['candidate_k']}, plan has {ck}")
            return None
        # ann_select contract
        if info.get("ann_resolved") != p["choice"]:
            return (f"ann choice drifted: claimed {p['choice']!r}, "
                    f"plan has {info.get('ann_resolved')!r}")
        if p["choice"] == "exact":
            return None
        nlist, nprobe = info.get("ann_nlist"), info.get("ann_nprobe")
        if not (nlist and nprobe and 1 <= nprobe <= nlist):
            return (f"IVF knobs out of range: nprobe={nprobe} "
                    f"nlist={nlist}")
        if (p.get("mode") == "auto"
                and p["recall_est"] < p["recall_target"]):
            return (f"auto-selected IVF misses the recall target: "
                    f"est {p['recall_est']:.2f} < "
                    f"{p['recall_target']:.2f}")
        return None

    if ob.kind == "index_shared":
        if ctx is None:
            return None
        hits = 0
        for node in opt_nodes:
            if node.op not in RETRIEVAL_OPS:
                continue
            if node.info.get("corpus_fp") != p["fp"]:
                continue
            if "model" not in node.info:
                continue
            try:
                if ctx.resolve_model(node.info["model"]).ref == p["ref"]:
                    hits += 1
            except KeyError:
                return "shared-index MODEL no longer resolves"
        if hits < 2:
            return (f"claimed shared corpus index but only {hits} "
                    f"node(s) reference (model={p['ref']}, corpus)")
        return None

    if ob.kind == "schema_preserved":
        naive_sch = infer_schema(source, naive_nodes)
        opt_sch = infer_schema(source, opt_nodes)
        a = naive_sch[-1] if naive_sch else table_schema(source)
        b = opt_sch[-1] if opt_sch else table_schema(source)
        if set(a.names) != set(b.names):
            only_a = sorted(set(a.names) - set(b.names))
            only_b = sorted(set(b.names) - set(a.names))
            return (f"output schema changed: optimized plan "
                    f"{'drops ' + str(only_a) if only_a else ''}"
                    f"{' adds ' + str(only_b) if only_b else ''}")
        for name in a.names:
            da, db = a.get(name).dtype, b.get(name).dtype
            if not _dtype_compatible(da, db):
                return (f"column {name!r} changed dtype: "
                        f"{da} -> {db}")
        return None

    return f"unknown obligation kind {ob.kind!r}"


def verify_rewrites(ctx, source: Table, naive_nodes: Sequence,
                    opt) -> List[Diagnostic]:
    """Discharge every obligation the optimizer emitted for one
    rewritten plan (``opt`` is an ``optimizer.OptimizedPlan``).  Each
    failure is an FLK010 error diagnostic; an empty return means every
    rewrite's soundness claim held on the optimized plan."""
    diags: List[Diagnostic] = []
    for ob in getattr(opt, "obligations", ()):
        try:
            reason = _discharge(ctx, source, list(naive_nodes),
                                list(opt.nodes), ob)
        except (KeyError, IndexError, TypeError) as exc:
            reason = f"verifier could not evaluate the claim: {exc!r}"
        if reason is not None:
            diags.append(Diagnostic(
                "FLK010", "error",
                f"obligation {ob} not discharged: {reason}"))
    return diags
