"""BM25 full-text index (paper Query 3 step 3 — the FTS retriever).

Okapi BM25 with k1/b defaults matching DuckDB's FTS extension (k1=1.2,
b=0.75).  Pure numpy over a CSR-ish postings layout; scoring a query scans
only the postings of the query terms.
"""

from __future__ import annotations

import math
import re
from collections import Counter, defaultdict
from typing import Dict, List, Sequence

import numpy as np

_TOKEN_RE = re.compile(r"[a-z0-9]+")


def tokenize(text: str) -> List[str]:
    return _TOKEN_RE.findall(str(text).lower())


class BM25Index:
    def __init__(self, k1: float = 1.2, b: float = 0.75):
        self.k1 = k1
        self.b = b
        self._postings: Dict[str, list] = {}
        self._doc_len = np.zeros(0, np.float64)
        self._n_docs = 0
        self._avgdl = 0.0

    @classmethod
    def build(cls, docs: Sequence[str], **kw) -> "BM25Index":
        idx = cls(**kw)
        postings: Dict[str, list] = defaultdict(list)
        lens = []
        for d, text in enumerate(docs):
            toks = tokenize(text)
            lens.append(len(toks))
            for term, tf in Counter(toks).items():
                postings[term].append((d, tf))
        idx._postings = {
            t: (np.array([d for d, _ in ps], np.int64),
                np.array([tf for _, tf in ps], np.float64))
            for t, ps in postings.items()}
        idx._doc_len = np.asarray(lens, np.float64)
        idx._n_docs = len(docs)
        idx._avgdl = float(idx._doc_len.mean()) if len(docs) else 0.0
        return idx

    def idf(self, term: str) -> float:
        n_t = len(self._postings.get(term, ((), ()))[0])
        # BM25+-style floor keeps idf non-negative
        return math.log(1.0 + (self._n_docs - n_t + 0.5) / (n_t + 0.5))

    def score(self, query: str) -> np.ndarray:
        """BM25 score of every document for ``query`` (0 when no overlap)."""
        scores = np.zeros(self._n_docs, np.float64)
        if not self._n_docs:
            return scores
        norm = 1.0 - self.b + self.b * self._doc_len / max(self._avgdl, 1e-9)
        for term, qf in Counter(tokenize(query)).items():
            if term not in self._postings:
                continue
            docs, tf = self._postings[term]
            idf = self.idf(term)
            s = idf * tf * (self.k1 + 1.0) / (tf + self.k1 * norm[docs])
            np.add.at(scores, docs, s * qf)
        return scores

    def score_many(self, queries: Sequence[str]) -> np.ndarray:
        """BM25 scores for a batch of queries in one vectorized pass:
        (len(queries), n_docs).

        Each term's per-doc score array is query-independent, so it is
        computed once per distinct term and scattered for every query
        that uses it with a single ``np.add.at``.  Scatter pairs are
        emitted in (query, per-query term) order — the same float
        accumulation order as ``score`` — so rows are bit-identical to
        the per-query path.
        """
        nq = len(queries)
        out = np.zeros((nq, self._n_docs), np.float64)
        if not self._n_docs or not nq:
            return out
        norm = 1.0 - self.b + self.b * self._doc_len / max(self._avgdl, 1e-9)
        term_scores: Dict[str, np.ndarray] = {}
        rows, cols, vals = [], [], []
        for qi, query in enumerate(queries):
            for term, qf in Counter(tokenize(str(query))).items():
                if term not in self._postings:
                    continue
                if term not in term_scores:
                    docs, tf = self._postings[term]
                    term_scores[term] = self.idf(term) * tf * (
                        self.k1 + 1.0) / (tf + self.k1 * norm[docs])
                docs = self._postings[term][0]
                rows.append(np.full(len(docs), qi, np.int64))
                cols.append(docs)
                vals.append(term_scores[term] * qf)
        if rows:
            np.add.at(out, (np.concatenate(rows), np.concatenate(cols)),
                      np.concatenate(vals))
        return out

    def topk(self, query: str, k: int = 100):
        scores = self.score(query)
        k = min(k, self._n_docs)
        idx = np.argpartition(-scores, k - 1)[:k] if k else np.array([], int)
        idx = idx[np.argsort(-scores[idx], kind="stable")]
        return idx, scores[idx]
