"""Mesh-sharded corpus scan: the hybrid-search vector index at pod scale.

The paper's Query 3 scans every passage embedding; at cluster scale the
corpus shards across the mesh.  ``sharded_topk`` shards the corpus rows
over every mesh axis (pure data parallelism — queries replicate), computes
block-local top-k per shard with the same blocked-scan structure as the
``topk_sim`` kernel, and lets GSPMD reduce the per-shard candidates with an
all-gather of only (Q, devices*k) scores instead of the full corpus —
collective payload is k/shard_rows of the naive approach.

``make_sharded_topk(mesh)`` returns a jitted function with in/out
shardings bound, usable by VectorIndex when a mesh is active and by the
dry-run (tests/test_distributed_retrieval.py lowers it on an 8-device
mesh and checks both numerics and the compiled sharding).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

F32 = jnp.float32


def _local_topk(corpus_rows, queries, k: int, row_offset):
    """Exact top-k of ``queries`` against a contiguous corpus slice."""
    s = jnp.einsum("qd,nd->qn", queries, corpus_rows,
                   preferred_element_type=F32)
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, top_i + row_offset


def sharded_topk(corpus, queries, k: int):
    """corpus: (N, D) [shard rows over the mesh]; queries: (Q, D)
    [replicated].  Returns (scores (Q, k), indices (Q, k)).

    Written so GSPMD partitions it from the in-shardings alone: the
    einsum + top_k run shard-local, then one small all-gather + final
    top_k reduce the candidates.
    """
    N = corpus.shape[0]
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-9)
    cn = corpus / jnp.maximum(
        jnp.linalg.norm(corpus, axis=-1, keepdims=True), 1e-9)
    k = min(k, N)
    # global top-k of a sharded score row: lax.top_k over the sharded dim
    # makes GSPMD compute local top-k then combine (verified in the test's
    # HLO: per-shard top-k + all-gather of (Q, shards*k) candidates).
    s = jnp.einsum("qd,nd->qn", qn.astype(F32), cn.astype(F32))
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, top_i


def make_sharded_topk(mesh: Mesh, k: int, *, corpus_axes=None):
    """Bind shardings: corpus rows over every mesh axis, queries replicated."""
    axes = corpus_axes or tuple(mesh.axis_names)
    fn = jax.jit(
        lambda c, q: sharded_topk(c, q, k),
        in_shardings=(NamedSharding(mesh, P(axes, None)),
                      NamedSharding(mesh, P(None, None))),
        out_shardings=(NamedSharding(mesh, P(None, None)),
                       NamedSharding(mesh, P(None, None))),
    )
    return fn
