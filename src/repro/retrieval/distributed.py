"""Mesh-sharded corpus scan: the hybrid-search vector index at pod scale.

The paper's Query 3 scans every passage embedding; at cluster scale the
corpus shards across the mesh.  ``make_sharded_topk(mesh)`` builds a
``shard_map``-composed scan: corpus rows shard over every mesh axis (pure
data parallelism — queries replicate), each shard runs the same two-phase
block-max prune as the ``kernels/topk_sim`` Pallas kernel (per-block
maxima -> top-k blocks -> exact rescore of only those rows, so the full
(Q, N/shard) score matrix is never materialised), and only the
(Q, devices*k) per-shard candidates all-gather for the final top-k —
collective payload is k/shard_rows of the naive approach.

The shard-local prune is plain jnp (``lax.map`` over corpus blocks) so
it lowers on every backend under ``shard_map``; the single-device path
in ``VectorIndex`` routes through the Pallas kernel itself.

``sharded_topk`` remains the GSPMD reference formulation (einsum +
top_k, partitioned from in-shardings alone); the bound fast path is
``make_sharded_topk``, which tests/test_distributed.py lowers on an
8-device mesh and checks for both oracle numerics and a compiled HLO
that keeps the corpus sharded (no full all-gather of it).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

F32 = jnp.float32


def _local_topk(corpus_rows, queries, k: int, row_offset):
    """Exact top-k of ``queries`` against a contiguous corpus slice."""
    s = jnp.einsum("qd,nd->qn", queries, corpus_rows,
                   preferred_element_type=F32)
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, top_i + row_offset


def sharded_topk(corpus, queries, k: int):
    """corpus: (N, D) [shard rows over the mesh]; queries: (Q, D)
    [replicated].  Returns (scores (Q, k), indices (Q, k)).

    GSPMD reference: written so the partitioner splits it from the
    in-shardings alone — the einsum + top_k run shard-local, then one
    small all-gather + final top_k reduce the candidates.
    """
    N = corpus.shape[0]
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-9)
    cn = corpus / jnp.maximum(
        jnp.linalg.norm(corpus, axis=-1, keepdims=True), 1e-9)
    k = min(k, N)
    s = jnp.einsum("qd,nd->qn", qn.astype(F32), cn.astype(F32))
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, top_i


def _blocked_local_topk(c, qn, k: int, offset, n_global: int, block: int):
    """Shard-local exact top-k with the ``topk_sim`` block-max structure,
    in plain jnp: per-block maxima via a sequential on-device ``lax.map``
    (live memory (Q, n_blocks), never (Q, rows)), top-k blocks, exact
    rescore of the gathered candidates.  ``offset`` is this shard's
    global row offset; rows at global id >= ``n_global`` are padding."""
    rows, D = c.shape
    Q = qn.shape[0]
    bn = min(block, rows)
    nb = -(-rows // bn)
    pad = nb * bn - rows
    cp = jnp.pad(c, ((0, pad), (0, 0))) if pad else c
    gids = offset + jnp.arange(nb * bn)
    valid = gids < n_global

    def bmax(blk):
        cb, vb = blk                                  # (bn, D), (bn,)
        s = jnp.einsum("qd,nd->qn", qn, cb,
                       preferred_element_type=F32)
        return jnp.where(vb[None, :], s, -jnp.inf).max(axis=1)

    bm = jax.lax.map(bmax, (cp.reshape(nb, bn, D),
                            valid.reshape(nb, bn)))   # (nb, Q)
    kb = min(k, nb)
    _, top_blocks = jax.lax.top_k(bm.T, kb)           # (Q, kb)
    row_idx = (top_blocks[:, :, None] * bn
               + jnp.arange(bn)[None, None, :]).reshape(Q, kb * bn)
    cand = jnp.take(cp, row_idx, axis=0)              # (Q, kb*bn, D)
    s = jnp.einsum("qd,qnd->qn", qn, cand,
                   preferred_element_type=F32)
    s = jnp.where(valid[row_idx], s, -jnp.inf)
    top_s, pos = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(gids[row_idx], pos, axis=1)


def _flat_axes(mesh: Mesh, corpus_axes) -> tuple:
    axes = corpus_axes or tuple(mesh.axis_names)
    if isinstance(axes, str):
        axes = (axes,)
    flat = []
    for a in axes:
        flat.extend(a if isinstance(a, (tuple, list)) else (a,))
    return tuple(flat)


def make_sharded_topk(mesh: Mesh, k: int, *, corpus_axes=None,
                      block: int = 2048):
    """Bind the shard-mapped blocked scan: corpus rows over every mesh
    axis, queries replicated, (Q, shards*k) candidate all-gather only."""
    axes = _flat_axes(mesh, corpus_axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nshards = math.prod(sizes[a] for a in axes)

    def fn(corpus, queries):
        N, D = corpus.shape
        cn = corpus / jnp.maximum(
            jnp.linalg.norm(corpus, axis=-1, keepdims=True), 1e-9)
        qn = queries / jnp.maximum(
            jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-9)
        qn = qn.astype(F32)
        kk = min(k, N)
        pad = (-N) % nshards
        cp = jnp.pad(cn, ((0, pad), (0, 0))) if pad else cn
        rows_local = cp.shape[0] // nshards
        kl = min(kk, rows_local)

        def local(c, q):
            shard = 0
            for name in axes:
                shard = shard * sizes[name] + jax.lax.axis_index(name)
            return _blocked_local_topk(c, q, kl, shard * rows_local, N,
                                       block)

        cand_s, cand_i = shard_map(
            local, mesh=mesh,
            in_specs=(P(axes, None), P(None, None)),
            out_specs=(P(None, axes), P(None, axes)))(cp, qn)
        top_s, pos = jax.lax.top_k(cand_s, kk)     # (Q, shards*kl) -> kk
        return top_s, jnp.take_along_axis(cand_i, pos, axis=1)

    return jax.jit(
        fn,
        in_shardings=(NamedSharding(mesh, P(axes, None)),
                      NamedSharding(mesh, P(None, None))),
        out_shardings=(NamedSharding(mesh, P(None, None)),
                       NamedSharding(mesh, P(None, None))),
    )
