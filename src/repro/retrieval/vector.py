"""Exact vector similarity search (paper Query 3 step 2 — the VSS scan).

``cosine_topk`` is the jnp oracle for the ``topk_sim`` Pallas kernel: the
corpus-side scan is a blocked matmul with a running top-k, sharded over the
(data, model) mesh when a policy is supplied.

``VectorIndex`` is the materialised index behind the ``vector_topk`` /
``hybrid_topk`` plan operators (``engine.retrieval_ops``).  Scan routing:

  * >1-device mesh active (enclosing ``with mesh:`` or ``mesh=``) — the
    shard-mapped ``distributed.sharded_topk`` blocked scan; corpus rows
    shard, queries replicate, only (Q, devices*k) candidates all-gather.
  * single device, compiled backend (TPU/GPU) or a large corpus — the
    ``kernels/topk_sim`` block-max Pallas kernel (compiled on
    accelerators, interpreted on CPU where only big scans amortise the
    interpreter overhead).
  * otherwise — the ``cosine_topk`` jnp scan.

``topk_ann`` routes through a lazily built ``retrieval.ivf.IVFIndex``
(the ``vector_topk(ann=...)`` plan option); ``nprobe >= nlist`` probes
everything and reproduces the exact scan.

Built indexes are memoised per session and in the persistent
``IndexStore`` sidecar via ``ensure_index``, keyed by (embedding model
ref, corpus fingerprint).  A corpus that *extends* a memoised one is an
incremental append: only the delta is embedded (through the same
``plan_batches``/co-pack path as any embed) and stored as a new segment
next to the base instead of re-embedding the whole corpus.
"""

from __future__ import annotations

import logging
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .ivf import IVFIndex

logger = logging.getLogger(__name__)

# On CPU the Pallas kernel runs interpreted; its per-call overhead only
# amortises over big corpora, so small scans keep the jnp path (which is
# also what the equivalence tests pin bit-for-bit on CPU).
KERNEL_MIN_ROWS_CPU = 32768
DEFAULT_RECALL_TARGET = 0.95


def cosine_topk(corpus: jnp.ndarray, queries: jnp.ndarray, k: int,
                block: int = 4096):
    """corpus: (N, D) unit-normalised; queries: (Q, D).  Returns
    (scores (Q,k), indices (Q,k)) by cosine similarity, blocked over N so the
    full (N, Q) score matrix is never materialised.  ``k`` is capped at N;
    an empty corpus returns empty (Q, 0) results."""
    N, D = corpus.shape
    Q = queries.shape[0]
    k = min(k, N)
    if N == 0 or k == 0:
        return (jnp.zeros((Q, 0), jnp.float32),
                jnp.zeros((Q, 0), jnp.int32))
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-9)
    block = min(block, N)
    nblk = -(-N // block)
    pad = nblk * block - N
    c = jnp.pad(corpus, ((0, pad), (0, 0))) if pad else corpus
    c = c.reshape(nblk, block, D)

    def step(carry, inp):
        best_s, best_i = carry                       # (Q, k)
        blk_idx, cb = inp
        s = jnp.einsum("qd,nd->qn", qn, cb,
                       preferred_element_type=jnp.float32)
        idx = blk_idx * block + jnp.arange(block)
        s = jnp.where(idx[None, :] < N, s, -jnp.inf)
        cat_s = jnp.concatenate([best_s, s], axis=1)
        cat_i = jnp.concatenate([best_i,
                                 jnp.broadcast_to(idx, (Q, block))], axis=1)
        top_s, top_pos = jax.lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, top_pos, axis=1)
        return (top_s, top_i), None

    init = (jnp.full((Q, k), -jnp.inf, jnp.float32),
            jnp.zeros((Q, k), jnp.int32))
    (s, i), _ = jax.lax.scan(step, init, (jnp.arange(nblk), c))
    return s, i


def active_mesh():
    """The physical mesh of an enclosing ``with mesh:`` block, or None.

    A single-device mesh is reported as None — sharding the corpus over
    one device only adds dispatch overhead."""
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
    except (ImportError, AttributeError) as exc:
        # pxla internals moved across jax releases; treat an unknown
        # layout as "no mesh" rather than failing the scan
        logger.debug("active_mesh probe failed: %s", exc)
        return None
    if mesh is None or mesh.empty or mesh.size <= 1:
        return None
    return mesh


class VectorIndex:
    """Materialised embedding index over a column of texts.

    ``topk`` is the exact scan (mesh-sharded / Pallas / jnp — see module
    docstring); ``topk_ann`` the IVF approximate scan.  ``raw`` keeps the
    pre-normalisation vectors so segment appends (``extended``) rebuild
    bit-identically to a from-scratch index over the full corpus."""

    def __init__(self, vectors: np.ndarray, mesh=None,
                 use_kernel: Optional[bool] = None):
        v = np.asarray(vectors, np.float32)
        if v.ndim == 1:
            v = v.reshape(0, 0) if v.size == 0 else v.reshape(1, -1)
        self.raw = v
        norms = np.maximum(np.linalg.norm(v, axis=-1, keepdims=True), 1e-9)
        self.vectors = v / norms
        self.mesh = mesh
        self.use_kernel = use_kernel
        self._topk = jax.jit(cosine_topk, static_argnames=("k", "block"))
        self._sharded = {}          # k -> bound sharded scan
        self._ivf: Optional[IVFIndex] = None

    @classmethod
    def build(cls, ctx, model_spec, texts: Sequence[str],
              mesh=None) -> "VectorIndex":
        from repro.core.functions import llm_embedding
        return cls(llm_embedding(ctx, model_spec, list(texts)), mesh=mesh)

    def _sharded_topk(self, mesh, k: int):
        from .distributed import make_sharded_topk
        key = (id(mesh), k)
        fn = self._sharded.get(key)
        if fn is None:
            fn = self._sharded[key] = make_sharded_topk(mesh, k)
        return fn

    def _route_kernel(self) -> bool:
        if self.use_kernel is not None:
            return self.use_kernel
        if jax.default_backend() != "cpu":
            return True
        return len(self.vectors) >= KERNEL_MIN_ROWS_CPU

    def topk(self, query_vecs: np.ndarray, k: int = 100):
        q = np.atleast_2d(np.asarray(query_vecs, np.float32))
        use_k = min(k, len(self.vectors))
        if use_k <= 0 or q.shape[-1] == 0:
            return (np.zeros((len(q), 0), np.float32),
                    np.zeros((len(q), 0), np.int32))
        mesh = self.mesh if self.mesh is not None else active_mesh()
        if mesh is not None:
            fn = self._sharded_topk(mesh, use_k)
            s, i = fn(jnp.asarray(self.vectors), jnp.asarray(q))
        elif self._route_kernel():
            from repro.kernels.topk_sim.ops import topk_sim
            s, i = topk_sim(jnp.asarray(self.vectors), jnp.asarray(q),
                            use_k)
        else:
            s, i = self._topk(jnp.asarray(self.vectors), jnp.asarray(q),
                              use_k)
        return np.asarray(s), np.asarray(i)

    # ---- ANN -------------------------------------------------------------
    def ivf(self, nlist: Optional[int] = None) -> IVFIndex:
        """The lazily built (and memoised) IVF index over this corpus.
        An explicit ``nlist`` differing from the memoised quantizer
        rebuilds it."""
        if self._ivf is None or (
                nlist is not None and self._ivf.nlist != min(
                    max(int(nlist), 1), len(self.vectors))):
            self._ivf = IVFIndex.build(self.vectors, nlist)
        return self._ivf

    def topk_ann(self, query_vecs: np.ndarray, k: int = 100, *,
                 nprobe: Optional[int] = None,
                 nlist: Optional[int] = None,
                 recall_target: Optional[float] = None):
        """IVF approximate top-k.  ``nprobe`` wins over ``recall_target``
        (which picks the smallest calibrated nprobe meeting the target);
        ``nprobe >= nlist`` reproduces the exact scan."""
        q = np.atleast_2d(np.asarray(query_vecs, np.float32))
        use_k = min(k, len(self.vectors))
        if use_k <= 0 or q.shape[-1] == 0:
            return (np.zeros((len(q), 0), np.float32),
                    np.zeros((len(q), 0), np.int64))
        qn = q / np.maximum(np.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
        ivf = self.ivf(nlist)
        if nprobe is None:
            nprobe = ivf.nprobe_for(recall_target
                                    if recall_target is not None
                                    else DEFAULT_RECALL_TARGET)
        return ivf.search(qn, use_k, nprobe)

    # ---- incremental appends ---------------------------------------------
    def extended(self, delta_vectors: np.ndarray) -> "VectorIndex":
        """A NEW index over this corpus plus ``delta_vectors`` (raw,
        un-normalised — same as ``llm_embedding`` output).  The base
        index is untouched (it stays registered under its own
        fingerprint); a built IVF quantizer carries over with the new
        rows assigned to existing lists (merged lazily)."""
        delta = np.asarray(delta_vectors, np.float32)
        if delta.ndim == 1 and delta.size:
            delta = delta.reshape(1, -1)
        if not delta.size:
            return self
        idx = VectorIndex(np.concatenate([self.raw, delta]),
                          mesh=self.mesh, use_kernel=self.use_kernel)
        if self._ivf is not None:
            idx._ivf = self._ivf.extended(idx.vectors, len(delta))
        return idx


def _find_prefix_base(ctx, store, model_ref: str, texts):
    """An existing index over a strict prefix of ``texts``: returns
    ``(n_base, base_fp, base_index_or_None, base_vectors_or_None)`` for
    the LONGEST matching prefix, or None.  Candidates come from the
    session registry and the ``IndexStore``; a candidate of length n
    matches iff ``corpus_fingerprint(texts[:n])`` equals its key."""
    from repro.core.cache import corpus_fingerprint

    lengths = {}                       # n -> [fp, ...] candidates
    for fp, n in getattr(ctx, "index_entries", lambda _ref: [])(model_ref):
        if 0 < n < len(texts):
            lengths.setdefault(n, []).append(fp)
    if store is not None:
        for fp, n in store.entries(model_ref):
            if 0 < n < len(texts):
                lengths.setdefault(n, []).append(fp)
    for n in sorted(lengths, reverse=True):
        fp_n = corpus_fingerprint(texts[:n])
        if fp_n not in lengths[n]:
            continue
        index = ctx.lookup_index((model_ref, fp_n))
        if index is not None and len(index.vectors) == n:
            return n, fp_n, index, None
        if store is not None:
            vectors = store.get(model_ref, fp_n)
            if vectors is not None and len(vectors) == n:
                return n, fp_n, None, vectors
    return None


def ensure_index(ctx, model_spec, texts: Sequence[str],
                 fingerprint: Optional[str] = None):
    """Build-or-fetch the vector index for (embedding model, corpus).

    Lookup order: the context's session registry, then the persistent
    ``IndexStore`` sidecar, then — new in the segment era — a memoised
    index over a strict PREFIX of this corpus, in which case only the
    delta texts are embedded (the same ``plan_batches``/co-pack path as
    a full build) and persisted as an appended segment.  Returns
    ``(index, source)`` with source one of ``"session"`` / ``"store"`` /
    ``"appended"`` / ``"built"``."""
    from repro.core.cache import corpus_fingerprint
    from repro.core.functions import llm_embedding

    texts = list(texts)
    model = ctx.resolve_model(model_spec)
    if fingerprint is None:
        fingerprint = corpus_fingerprint(texts)
    key = (model.ref, fingerprint)
    index = ctx.lookup_index(key)
    if index is not None:
        return index, "session"
    store = getattr(ctx, "index_store", None)
    if store is not None:
        vectors = store.get(model.ref, fingerprint)
        if vectors is not None and len(vectors) == len(texts):
            index = VectorIndex(vectors)
            ctx.store_index(key, index)
            return index, "store"

    base = _find_prefix_base(ctx, store, model.ref, texts)
    if base is not None:
        n_base, base_fp, base_index, base_vectors = base
        delta = llm_embedding(ctx, model_spec, texts[n_base:])
        if base_index is None:
            base_index = VectorIndex(base_vectors)
        index = base_index.extended(delta)
        ctx.store_index(key, index)
        if store is not None:
            if store.has(model.ref, base_fp):
                store.append_segment(model.ref, base_fp, fingerprint,
                                     delta)
            else:
                store.put(model.ref, fingerprint, index.raw)
        return index, "appended"

    vectors = llm_embedding(ctx, model_spec, texts)
    index = VectorIndex(vectors)
    ctx.store_index(key, index)
    if store is not None:
        store.put(model.ref, fingerprint, vectors)
    return index, "built"
