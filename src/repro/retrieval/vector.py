"""Exact vector similarity search (paper Query 3 step 2 — the VSS scan).

``cosine_topk`` is the jnp oracle for the ``topk_sim`` Pallas kernel: the
corpus-side scan is a blocked matmul with a running top-k, sharded over the
(data, model) mesh when a policy is supplied.

``VectorIndex`` is the materialised index behind the ``vector_topk`` /
``hybrid_topk`` plan operators (``engine.retrieval_ops``): built indexes
are memoised per session and in the persistent ``IndexStore`` sidecar via
``ensure_index``, keyed by (embedding model ref, corpus fingerprint), so a
repeated RAG query over an unchanged corpus skips re-embedding.  When a
JAX mesh with more than one device is active (an enclosing ``with mesh:``
block, or an explicit ``mesh=`` argument), the corpus scan routes through
``distributed.sharded_topk`` — corpus rows shard over the mesh, queries
replicate, and only (Q, devices*k) candidates all-gather.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def cosine_topk(corpus: jnp.ndarray, queries: jnp.ndarray, k: int,
                block: int = 4096):
    """corpus: (N, D) unit-normalised; queries: (Q, D).  Returns
    (scores (Q,k), indices (Q,k)) by cosine similarity, blocked over N so the
    full (N, Q) score matrix is never materialised."""
    N, D = corpus.shape
    Q = queries.shape[0]
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-9)
    block = min(block, N)
    nblk = -(-N // block)
    pad = nblk * block - N
    c = jnp.pad(corpus, ((0, pad), (0, 0))) if pad else corpus
    c = c.reshape(nblk, block, D)

    def step(carry, inp):
        best_s, best_i = carry                       # (Q, k)
        blk_idx, cb = inp
        s = jnp.einsum("qd,nd->qn", qn, cb,
                       preferred_element_type=jnp.float32)
        idx = blk_idx * block + jnp.arange(block)
        s = jnp.where(idx[None, :] < N, s, -jnp.inf)
        cat_s = jnp.concatenate([best_s, s], axis=1)
        cat_i = jnp.concatenate([best_i,
                                 jnp.broadcast_to(idx, (Q, block))], axis=1)
        top_s, top_pos = jax.lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, top_pos, axis=1)
        return (top_s, top_i), None

    init = (jnp.full((Q, k), -jnp.inf, jnp.float32),
            jnp.zeros((Q, k), jnp.int32))
    (s, i), _ = jax.lax.scan(step, init, (jnp.arange(nblk), c))
    return s, i


def active_mesh():
    """The physical mesh of an enclosing ``with mesh:`` block, or None.

    A single-device mesh is reported as None — sharding the corpus over
    one device only adds dispatch overhead."""
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
    except Exception:
        return None
    if mesh is None or mesh.empty or mesh.size <= 1:
        return None
    return mesh


class VectorIndex:
    """Materialised embedding index over a column of texts.

    ``topk`` scans single-device by default; with a mesh active (or
    passed explicitly) the scan shards the corpus rows over the mesh via
    ``distributed.sharded_topk``."""

    def __init__(self, vectors: np.ndarray, mesh=None):
        v = np.asarray(vectors, np.float32)
        norms = np.maximum(np.linalg.norm(v, axis=-1, keepdims=True), 1e-9)
        self.vectors = v / norms
        self.mesh = mesh
        self._topk = jax.jit(cosine_topk, static_argnames=("k", "block"))
        self._sharded = {}          # k -> bound sharded scan

    @classmethod
    def build(cls, ctx, model_spec, texts: Sequence[str],
              mesh=None) -> "VectorIndex":
        from repro.core.functions import llm_embedding
        return cls(llm_embedding(ctx, model_spec, list(texts)), mesh=mesh)

    def _sharded_topk(self, mesh, k: int):
        from .distributed import make_sharded_topk
        key = (id(mesh), k)
        fn = self._sharded.get(key)
        if fn is None:
            fn = self._sharded[key] = make_sharded_topk(mesh, k)
        return fn

    def topk(self, query_vecs: np.ndarray, k: int = 100):
        q = np.atleast_2d(np.asarray(query_vecs, np.float32))
        use_k = min(k, len(self.vectors))
        mesh = self.mesh if self.mesh is not None else active_mesh()
        if mesh is not None:
            fn = self._sharded_topk(mesh, use_k)
            s, i = fn(jnp.asarray(self.vectors), jnp.asarray(q))
        else:
            s, i = self._topk(jnp.asarray(self.vectors), jnp.asarray(q),
                              use_k)
        return np.asarray(s), np.asarray(i)


def ensure_index(ctx, model_spec, texts: Sequence[str],
                 fingerprint: Optional[str] = None):
    """Build-or-fetch the vector index for (embedding model, corpus).

    Lookup order: the context's session registry, then the persistent
    ``IndexStore`` sidecar, then a fresh ``llm_embedding`` build (which
    populates both).  Returns ``(index, source)`` with source one of
    ``"session"`` / ``"store"`` / ``"built"`` — the dedupe path behind
    the optimizer's shared-corpus cost estimate."""
    from repro.core.cache import corpus_fingerprint
    from repro.core.functions import llm_embedding

    texts = list(texts)
    model = ctx.resolve_model(model_spec)
    if fingerprint is None:
        fingerprint = corpus_fingerprint(texts)
    key = (model.ref, fingerprint)
    index = ctx.lookup_index(key)
    if index is not None:
        return index, "session"
    store = getattr(ctx, "index_store", None)
    if store is not None:
        vectors = store.get(model.ref, fingerprint)
        if vectors is not None and len(vectors) == len(texts):
            index = VectorIndex(vectors)
            ctx.store_index(key, index)
            return index, "store"
    vectors = llm_embedding(ctx, model_spec, texts)
    index = VectorIndex(vectors)
    ctx.store_index(key, index)
    if store is not None:
        store.put(model.ref, fingerprint, vectors)
    return index, "built"
