"""Exact vector similarity search (paper Query 3 step 2 — the VSS scan).

``cosine_topk`` is the jnp oracle for the ``topk_sim`` Pallas kernel: the
corpus-side scan is a blocked matmul with a running top-k, sharded over the
(data, model) mesh when a policy is supplied.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def cosine_topk(corpus: jnp.ndarray, queries: jnp.ndarray, k: int,
                block: int = 4096):
    """corpus: (N, D) unit-normalised; queries: (Q, D).  Returns
    (scores (Q,k), indices (Q,k)) by cosine similarity, blocked over N so the
    full (N, Q) score matrix is never materialised."""
    N, D = corpus.shape
    Q = queries.shape[0]
    qn = queries / jnp.maximum(
        jnp.linalg.norm(queries, axis=-1, keepdims=True), 1e-9)
    block = min(block, N)
    nblk = -(-N // block)
    pad = nblk * block - N
    c = jnp.pad(corpus, ((0, pad), (0, 0))) if pad else corpus
    c = c.reshape(nblk, block, D)

    def step(carry, inp):
        best_s, best_i = carry                       # (Q, k)
        blk_idx, cb = inp
        s = jnp.einsum("qd,nd->qn", qn, cb,
                       preferred_element_type=jnp.float32)
        idx = blk_idx * block + jnp.arange(block)
        s = jnp.where(idx[None, :] < N, s, -jnp.inf)
        cat_s = jnp.concatenate([best_s, s], axis=1)
        cat_i = jnp.concatenate([best_i,
                                 jnp.broadcast_to(idx, (Q, block))], axis=1)
        top_s, top_pos = jax.lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, top_pos, axis=1)
        return (top_s, top_i), None

    init = (jnp.full((Q, k), -jnp.inf, jnp.float32),
            jnp.zeros((Q, k), jnp.int32))
    (s, i), _ = jax.lax.scan(step, init, (jnp.arange(nblk), c))
    return s, i


class VectorIndex:
    """Materialised embedding index over a column of texts."""

    def __init__(self, vectors: np.ndarray):
        v = np.asarray(vectors, np.float32)
        norms = np.maximum(np.linalg.norm(v, axis=-1, keepdims=True), 1e-9)
        self.vectors = v / norms
        self._topk = jax.jit(cosine_topk, static_argnames=("k", "block"))

    @classmethod
    def build(cls, ctx, model_spec, texts: Sequence[str]) -> "VectorIndex":
        from repro.core.functions import llm_embedding
        return cls(llm_embedding(ctx, model_spec, list(texts)))

    def topk(self, query_vecs: np.ndarray, k: int = 100):
        q = np.atleast_2d(np.asarray(query_vecs, np.float32))
        use_pallas_k = min(k, len(self.vectors))
        s, i = self._topk(jnp.asarray(self.vectors), jnp.asarray(q),
                          use_pallas_k)
        return np.asarray(s), np.asarray(i)
