from .bm25 import BM25Index, tokenize
from .vector import VectorIndex, cosine_topk
