from .bm25 import BM25Index, tokenize
from .ivf import IVFIndex
from .vector import VectorIndex, active_mesh, cosine_topk, ensure_index
