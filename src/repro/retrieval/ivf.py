"""IVF-ANN coarse-quantized vector index (million-document retrieval).

The exact scan in ``vector.py`` prices at ``2*N*D`` FLOPs per query; at
millions of documents that is the plan's dominant non-provider cost.
``IVFIndex`` is the classic inverted-file ANN: a k-means coarse quantizer
partitions the corpus into ``nlist`` cluster lists, and a query scans only
its ``nprobe`` nearest lists — ``~2*(nlist + N*nprobe/nlist)*D`` FLOPs per
query, the estimate the plan optimizer prices against ``scan_flops``.

Contracts the test suite pins:

  * ``nprobe >= nlist`` probes every list and degenerates to the exact
    scan — ``search`` routes through the same ``exact_scan`` scorer, so
    the results are bit-identical by construction.
  * The candidate cut is the canonical retrieval tie-break
    ``(score desc, doc id asc)``, matching ``engine.retrieval_ops``.
  * A query whose probed lists hold fewer than ``k`` docs falls back to
    the exact scan for that query — ``search`` never returns short rows.

Recall is *calibrated per index*: ``build`` samples corpus vectors as
held-out queries, ranks each sample's true top-k neighbours by the
cluster rank the quantizer assigns them, and stores the cumulative
recall-vs-nprobe curve.  ``nprobe_for(recall_target)`` reads the curve;
the optimizer renders ``estimated_recall`` in ``explain()``.  Before an
index exists the optimizer falls back to the planning prior
``planned_recall`` below.

Appends are lazy: ``extended`` assigns new vectors to their nearest
*existing* centroid (no re-training) and defers the inverted-list merge
and recall re-calibration to the next ``search`` — the incremental
``IndexStore`` path adds segments without touching the quantizer.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

# Planning prior for recall(nprobe) before a calibrated curve exists:
# ``1 - (1 - nprobe/nlist) ** SHARPNESS``.  Sharpness 32 encodes the
# empirical IVF behaviour on clustered embedding corpora (recall ~0.95
# near nprobe/nlist ~ 0.09); the per-index calibrated curve replaces it
# as soon as the index is built.
IVF_PLANNING_SHARPNESS = 32
IVF_DEFAULT_TRAIN_ITERS = 8
IVF_CALIB_QUERIES = 32
IVF_CALIB_K = 10

# below this corpus size the optimizer never auto-selects IVF: training
# the quantizer costs more than the exact scan it would save
IVF_MIN_DOCS = 256


def default_nlist(n_docs: int) -> int:
    """sqrt(N) coarse-quantizer size, the standard IVF default."""
    return max(1, min(int(n_docs), int(round(math.sqrt(max(n_docs, 1))))))


def planned_recall(nprobe: int, nlist: int) -> float:
    """Planning-prior recall estimate (no built index yet)."""
    if nlist <= 0:
        return 1.0
    p = min(max(int(nprobe), 1), nlist) / nlist
    if p >= 1.0:
        return 1.0
    return 1.0 - (1.0 - p) ** IVF_PLANNING_SHARPNESS


def planned_nprobe(nlist: int, recall_target: float) -> int:
    """Smallest nprobe whose planning-prior recall meets the target."""
    if recall_target >= 1.0:
        return nlist
    rho = 1.0 - (1.0 - recall_target) ** (1.0 / IVF_PLANNING_SHARPNESS)
    return max(1, min(nlist, int(math.ceil(rho * nlist))))


def ivf_scan_flops(nq: float, n_docs: float, dim: float, nlist: int,
                   nprobe: int) -> float:
    """Priced probe cost: centroid scan + the probed fraction of lists."""
    nlist = max(int(nlist), 1)
    probe = min(max(int(nprobe), 1), nlist)
    return 2.0 * nq * dim * (nlist + n_docs * probe / nlist)


def kmeans(vectors: np.ndarray, nlist: int, *, iters: int = 8,
           seed: int = 0) -> np.ndarray:
    """Deterministic Lloyd's k-means over unit-normalised rows.

    Returns unit-normalised centroids (nlist, D).  Trains on a bounded
    sample (k-means cost must not dwarf the scan it amortises); empty
    clusters keep their previous centroid."""
    x = np.asarray(vectors, np.float32)
    n = len(x)
    nlist = max(1, min(nlist, n))
    rng = np.random.default_rng(seed)
    train_n = min(n, max(10 * nlist, 4096))
    train = x[rng.choice(n, size=train_n, replace=False)] if train_n < n \
        else x
    cent = train[rng.choice(len(train), size=nlist, replace=False)].copy()
    for _ in range(max(int(iters), 1)):
        cn = cent / np.maximum(
            np.linalg.norm(cent, axis=1, keepdims=True), 1e-9)
        assign = np.argmax(train @ cn.T, axis=1)
        sums = np.zeros_like(cent)
        np.add.at(sums, assign, train)
        counts = np.bincount(assign, minlength=nlist).astype(np.float32)
        nonempty = counts > 0
        cent[nonempty] = sums[nonempty] / counts[nonempty, None]
    return cent / np.maximum(np.linalg.norm(cent, axis=1, keepdims=True),
                             1e-9)


def _topk_rows(scores: np.ndarray, ids: np.ndarray, k: int
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Top-k of one score row by (score desc, id asc)."""
    if k >= len(ids):
        sel = np.lexsort((ids, -scores))
    else:
        part = np.argpartition(-scores, k - 1)[:k]
        sel = part[np.lexsort((ids[part], -scores[part]))]
    sel = sel[:k]
    return scores[sel], ids[sel]


class IVFIndex:
    """Inverted-file ANN over a unit-normalised embedding matrix."""

    def __init__(self, centroids: np.ndarray, vectors: np.ndarray,
                 assign: np.ndarray, *, seed: int = 0):
        self.centroids = np.asarray(centroids, np.float32)
        self.nlist = len(self.centroids)
        self._vectors = np.asarray(vectors, np.float32)
        self._assign = np.asarray(assign, np.int32)
        self._seed = seed
        self._order: Optional[np.ndarray] = None     # doc ids by cluster
        self._offsets: Optional[np.ndarray] = None   # CSR list bounds
        self.recall_curve: Optional[np.ndarray] = None

    # ---- construction ----------------------------------------------------
    @classmethod
    def build(cls, vectors: np.ndarray, nlist: Optional[int] = None, *,
              seed: int = 0,
              train_iters: int = IVF_DEFAULT_TRAIN_ITERS) -> "IVFIndex":
        v = np.asarray(vectors, np.float32)
        nlist = default_nlist(len(v)) if nlist is None else \
            max(1, min(int(nlist), len(v)))
        cent = kmeans(v, nlist, iters=train_iters, seed=seed)
        assign = np.argmax(v @ cent.T, axis=1).astype(np.int32)
        return cls(cent, v, assign, seed=seed)

    def extended(self, vectors_full: np.ndarray, n_new: int) -> "IVFIndex":
        """A new index over ``vectors_full`` (= this index's corpus plus
        ``n_new`` appended rows) sharing this quantizer: new rows join
        their nearest existing list, the CSR merge and recall
        re-calibration stay lazy (next ``search``)."""
        v = np.asarray(vectors_full, np.float32)
        if n_new <= 0:
            return IVFIndex(self.centroids, v, self._assign,
                            seed=self._seed)
        new_assign = np.argmax(v[-n_new:] @ self.centroids.T,
                               axis=1).astype(np.int32)
        return IVFIndex(self.centroids, v,
                        np.concatenate([self._assign, new_assign]),
                        seed=self._seed)

    def _merge(self):
        """Materialise the inverted lists (CSR over cluster-sorted doc
        ids) and the calibrated recall curve; no-op when current."""
        if self._order is not None and len(self._order) == len(
                self._vectors):
            return
        order = np.argsort(self._assign, kind="stable")
        counts = np.bincount(self._assign, minlength=self.nlist)
        self._order = order.astype(np.int64)
        self._offsets = np.concatenate(
            [[0], np.cumsum(counts)]).astype(np.int64)
        self._by_list = self._vectors[order]
        self._calibrate()

    def _calibrate(self):
        """Recall-vs-nprobe curve from held-out sampled corpus vectors:
        for each sample's true top-k neighbour, record the rank the
        quantizer gives the neighbour's cluster; the cumulative
        distribution IS recall(nprobe)."""
        n = len(self._vectors)
        if n == 0 or self.nlist <= 1:
            self.recall_curve = np.ones(max(self.nlist, 1))
            return
        rng = np.random.default_rng(self._seed + 1)
        qids = rng.choice(n, size=min(IVF_CALIB_QUERIES, n), replace=False)
        q = self._vectors[qids]
        k = min(IVF_CALIB_K, n)
        s = q @ self._vectors.T                       # (S, N)
        part = np.argpartition(-s, k - 1, axis=1)[:, :k]
        cq = q @ self.centroids.T                     # (S, nlist)
        cluster_order = np.argsort(-cq, axis=1, kind="stable")
        rank_of = np.empty_like(cluster_order)
        rows = np.arange(len(qids))[:, None]
        rank_of[rows, cluster_order] = np.arange(self.nlist)[None, :]
        neigh_cluster = self._assign[part]            # (S, k)
        neigh_rank = rank_of[rows, neigh_cluster].ravel()
        hits = np.bincount(neigh_rank, minlength=self.nlist)
        self.recall_curve = np.cumsum(hits) / max(len(neigh_rank), 1)

    # ---- recall knobs ----------------------------------------------------
    def nprobe_for(self, recall_target: float) -> int:
        """Smallest nprobe whose calibrated recall meets the target."""
        self._merge()
        meets = np.nonzero(self.recall_curve >= recall_target)[0]
        return int(meets[0]) + 1 if len(meets) else self.nlist

    def estimated_recall(self, nprobe: int) -> float:
        self._merge()
        if not len(self.recall_curve):
            return 1.0
        return float(
            self.recall_curve[min(max(int(nprobe), 1), self.nlist) - 1])

    # ---- search ----------------------------------------------------------
    def exact_scan(self, queries: np.ndarray, k: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact scan over all docs in id order — the ``nprobe == nlist``
        degenerate case shares this scorer, making the equality
        bit-identical by construction."""
        qn = np.atleast_2d(np.asarray(queries, np.float32))
        n = len(self._vectors)
        k = min(int(k), n)
        out_s = np.zeros((len(qn), k), np.float32)
        out_i = np.zeros((len(qn), k), np.int64)
        if k == 0:
            return out_s, out_i
        ids = np.arange(n, dtype=np.int64)
        scores = qn @ self._vectors.T                 # (Q, N)
        for r in range(len(qn)):
            out_s[r], out_i[r] = _topk_rows(scores[r], ids, k)
        return out_s, out_i

    def search(self, queries: np.ndarray, k: int, nprobe: int
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k over the ``nprobe`` nearest inverted lists per query.
        queries: (Q, D) unit-normalised.  Returns (scores (Q, k),
        doc ids (Q, k))."""
        self._merge()
        qn = np.atleast_2d(np.asarray(queries, np.float32))
        n = len(self._vectors)
        k = min(int(k), n)
        if k == 0:
            return (np.zeros((len(qn), 0), np.float32),
                    np.zeros((len(qn), 0), np.int64))
        nprobe = min(max(int(nprobe), 1), self.nlist)
        if nprobe >= self.nlist:
            return self.exact_scan(qn, k)

        cq = qn @ self.centroids.T                    # (Q, nlist)
        if nprobe < self.nlist:
            part = np.argpartition(-cq, nprobe - 1, axis=1)[:, :nprobe]
        else:
            part = np.tile(np.arange(self.nlist), (len(qn), 1))
        # cluster-major probe: each probed list is scored ONCE for every
        # query probing it (one contiguous matmul per list — the lists
        # are CSR-contiguous, so no gather), instead of per-query loops
        q_of = np.repeat(np.arange(len(qn)), part.shape[1])
        c_of = part.ravel()
        grp = np.argsort(c_of, kind="stable")
        q_of, c_of = q_of[grp], c_of[grp]
        bounds = np.searchsorted(c_of, np.arange(self.nlist + 1))
        per_q_ids: list = [[] for _ in range(len(qn))]
        per_q_s: list = [[] for _ in range(len(qn))]
        for c in range(self.nlist):
            glo, ghi = bounds[c], bounds[c + 1]
            if glo == ghi:
                continue
            lo, hi = self._offsets[c], self._offsets[c + 1]
            if lo == hi:
                continue
            qs = q_of[glo:ghi]
            s = qn[qs] @ self._by_list[lo:hi].T       # (nq_c, list_len)
            ids = self._order[lo:hi]
            for row, qi in enumerate(qs):
                per_q_ids[qi].append(ids)
                per_q_s[qi].append(s[row])
        out_s = np.zeros((len(qn), k), np.float32)
        out_i = np.zeros((len(qn), k), np.int64)
        for qi in range(len(qn)):
            if per_q_ids[qi]:
                ids = np.concatenate(per_q_ids[qi])
                sc = np.concatenate(per_q_s[qi])
            else:
                ids = np.zeros(0, np.int64)
                sc = np.zeros(0, np.float32)
            if len(ids) < k:
                # probed lists too small for k: exact fallback for this
                # query keeps rows rectangular and results exact-capped
                out_s[qi:qi + 1], out_i[qi:qi + 1] = self.exact_scan(
                    qn[qi:qi + 1], k)
                continue
            out_s[qi], out_i[qi] = _topk_rows(sc, ids, k)
        return out_s, out_i
