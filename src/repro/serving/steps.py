"""Serving step functions: prefill / decode with greedy+temperature sampling.

These are the units the dry-run lowers for the inference shape cells, and
the units the continuous-batching engine (engine.py) drives at runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.layers import NULL_POLICY

F32 = jnp.float32


def _sample(cfg: ModelConfig, logits, rng, temperature):
    """logits: (B, 1, V) fp32 -> tokens (B, 1) int32 (greedy if temp==0)."""
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, -jnp.inf)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if rng is None:
        return greedy
    noisy = jax.random.categorical(rng, logits / jnp.maximum(temperature,
                                                             1e-4))
    use_greedy = temperature <= 0.0
    return jnp.where(use_greedy, greedy, noisy.astype(jnp.int32))


def make_prefill_step(cfg: ModelConfig, cache_len: int, policy=NULL_POLICY):
    cfg = cfg.replace(remat=False)      # no backward pass in serving

    def prefill_step(params, batch):
        logits, cache, pos = M.prefill(cfg, params, batch, cache_len, policy)
        next_tok = _sample(cfg, logits, None, 0.0)
        return {"logits": logits, "next_token": next_tok,
                "cache": cache, "pos": jnp.int32(pos)}
    return prefill_step


def make_decode_step(cfg: ModelConfig, policy=NULL_POLICY):
    cfg = cfg.replace(remat=False)      # no backward pass in serving

    def decode_step(params, tokens, cache, pos, rng=None, temperature=0.0):
        logits, cache = M.decode_step(cfg, params, tokens, cache, pos, policy)
        next_tok = _sample(cfg, logits, rng, temperature)
        return {"logits": logits, "next_token": next_tok, "cache": cache}
    return decode_step


def make_embed_step(cfg: ModelConfig, policy=NULL_POLICY):
    """Mean-pooled final hidden state as the text embedding (llm_embedding)."""
    cfg = cfg.replace(remat=False)      # no backward pass in serving

    def embed_step(params, batch):
        # run the decoder stack in train (full-sequence) mode, no logits
        enc_out = None
        if cfg.is_encoder_decoder:
            enc_out = M._run_encoder(cfg, params, batch["frames"], policy)
        x, positions = M._assemble_input(cfg, params, batch, policy)
        x, _, _ = M._run_stages(cfg, params["stages"], list(cfg.stages()), x,
                                mode="train", positions=positions,
                                policy=policy, enc_out=enc_out)
        from repro.models import layers as L
        x = L.norm_apply(cfg, params["final_norm"], x)
        mask = (batch["tokens"] >= 0).astype(F32)
        if cfg.frontend == "vision" and "patches" in batch:
            P_ = batch["patches"].shape[1]
            x = x[:, P_:]
        emb = (x.astype(F32) * mask[..., None]).sum(1) / \
            jnp.maximum(mask.sum(1, keepdims=True), 1.0)
        emb = emb / jnp.maximum(jnp.linalg.norm(emb, axis=-1, keepdims=True),
                                1e-9)
        return emb
    return embed_step
