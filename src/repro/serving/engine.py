"""Continuous-batching serving engine (the runtime behind LocalJaxProvider).

Design (vLLM-style, adapted to JAX static shapes):
  * a fixed number of decode SLOTS; each slot owns one row of the batched
    cache pytree (B = n_slots);
  * prompts enter through CHUNKED PREFILL (prefill_chunk, Sarathi-style):
    whole chunks of ``chunk`` tokens, remainder token-by-token through the
    decode step — exact for attention AND recurrent archs, and only two
    compiled shapes per model;
  * every engine step decodes all active slots at their own positions
    (per-row ``pos`` vectors);
  * finished requests free their slot; waiting requests are admitted FCFS.

On CPU this runs the same jitted step functions the TPU mesh would run
(minus the sharding policy), so scheduler behaviour, cache management and
sampling are exercised end-to-end.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 32
    eos_token: int = -1              # -1: never stop early
    generated: List[int] = field(default_factory=list)
    # wall-clock arrival timestamp  # flocklint: ignore[FLKL101]
    submitted_at: float = field(default_factory=time.time)
    finished: bool = False
    slot: int = -1
    pos: int = 0                     # tokens of this request already cached
    pending_prompt: int = 0          # prompt tokens not yet prefetched


class ServingEngine:
    def __init__(self, cfg: ModelConfig, *, n_slots: int = 4,
                 max_context: int = 2048, chunk: int = 32,
                 checkpoint: Optional[str] = None, seed: int = 0):
        self.cfg = cfg.replace(remat=False)
        self.n_slots = n_slots
        self.max_context = max_context
        self.chunk = chunk
        if checkpoint:
            from repro.training.checkpoint import CheckpointManager
            mgr = CheckpointManager(checkpoint)
            self.params = mgr.restore_latest()["params"]
        else:
            self.params = M.init_params(self.cfg, jax.random.PRNGKey(seed))
        self.cache = M.init_cache(self.cfg, n_slots, max_context)
        self._rid = itertools.count()
        self.waiting: List[Request] = []
        self.active: List[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros(n_slots, np.int32)
        self.cur_tok = np.zeros(n_slots, np.int32)
        self.steps = 0

        cfgc = self.cfg
        self._decode = jax.jit(
            lambda p, t, c, pos: M.decode_step(cfgc, p, t, c, pos))
        self._extend = jax.jit(
            lambda p, t, c, off: M.prefill_chunk(cfgc, p, t, c, off))
        self._embed_cache = {}

    # ------------------------------------------------------------------ API
    def submit(self, prompt: Sequence[int], max_new_tokens: int = 32,
               eos_token: int = -1) -> Request:
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, eos_token=eos_token)
        req.pending_prompt = len(req.prompt)
        self.waiting.append(req)
        return req

    def generate(self, prompt: Sequence[int], max_new_tokens: int = 32,
                 eos_token: int = -1) -> List[int]:
        req = self.submit(prompt, max_new_tokens, eos_token)
        while not req.finished:
            self.step()
        return req.generated

    def run_until_idle(self, max_steps: int = 100_000):
        while (self.waiting or any(self.active)) and max_steps:
            self.step()
            max_steps -= 1

    # ----------------------------------------------------------------- step
    def _admit(self):
        for slot in range(self.n_slots):
            if self.active[slot] is None and self.waiting:
                req = self.waiting.pop(0)
                if len(req.prompt) + req.max_new_tokens > self.max_context:
                    req.finished = True      # reject: cannot fit
                    continue
                req.slot = slot
                req.pos = 0
                self.active[slot] = req

    def _prefill_work(self):
        """Advance chunked prefill for slots still consuming their prompt."""
        for slot, req in enumerate(self.active):
            # keep >=1 prompt token for the decode path so the first
            # generated token comes from real last-token logits
            if req is None or req.pending_prompt <= self.chunk:
                continue
            # process one full chunk for this slot (other slots no-op via
            # a masked chunk of repeated pad? -> simpler: per-slot call on a
            # batch where only this slot's chunk is real; positions of the
            # other slots point at their current pos so their cache rows
            # are overwritten with identical values (harmless: we reuse the
            # current token, and the masked write targets the same cells).
            start = len(req.prompt) - req.pending_prompt
            chunk_toks = req.prompt[start:start + self.chunk]
            toks = np.zeros((self.n_slots, self.chunk), np.int32)
            toks[slot] = chunk_toks
            offs = np.array(self.pos, np.int32)
            offs_vec = offs.copy()
            # rows without work: point their writes at their own positions
            # (re-writing the same K/V values they already hold)
            logits, new_cache = self._extend(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(offs_vec))
            # merge: keep new cache rows only for the working slot
            self.cache = _merge_row(self.cache, new_cache, slot)
            req.pos += self.chunk
            self.pos[slot] += self.chunk
            req.pending_prompt -= self.chunk
            return True      # one chunk per engine step keeps latency fair
        return False

    def step(self):
        self._admit()
        self.steps += 1
        if self._prefill_work():
            return
        # build the decode batch: remaining prompt tokens are fed one at a
        # time (teacher forcing); slots past their prompt sample greedily
        any_active = False
        toks = np.zeros((self.n_slots, 1), np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            any_active = True
            if req.pending_prompt > 0:
                idx = len(req.prompt) - req.pending_prompt
                toks[slot, 0] = req.prompt[idx]
            else:
                toks[slot, 0] = self.cur_tok[slot]
        if not any_active:
            return
        pos_vec = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache, pos_vec)
        nxt = np.asarray(jnp.argmax(
            _mask_vocab(self.cfg, logits[:, 0]), axis=-1), np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[slot] += 1
            req.pos += 1
            if req.pending_prompt > 0:
                req.pending_prompt -= 1
                if req.pending_prompt == 0:
                    self.cur_tok[slot] = nxt[slot]
                    req.generated.append(int(nxt[slot]))
            else:
                self.cur_tok[slot] = nxt[slot]
                req.generated.append(int(nxt[slot]))
            done = (len(req.generated) >= req.max_new_tokens
                    or (req.eos_token >= 0 and req.generated
                        and req.generated[-1] == req.eos_token)
                    or req.pos >= self.max_context - 1)
            if done and req.pending_prompt == 0:
                req.finished = True
                self.active[slot] = None
                self.pos[slot] = 0
                self.cur_tok[slot] = 0

    # ---------------------------------------------------------------- embed
    def embed(self, tokens: Sequence[int]) -> np.ndarray:
        """Mean-pooled hidden state (llm_embedding backend); bucketed jit.

        Padding uses token id -1: the embedding lookup clips it to 0 but the
        pooling mask inside the embed step (tokens >= 0) excludes it.
        """
        return self.embed_batch([tokens])[0]

    def embed_batch(self, token_lists) -> np.ndarray:
        """One padded forward for N texts — the 48x-style batching lever."""
        from repro.serving.steps import make_embed_step
        longest = max((len(t) for t in token_lists), default=1)
        L = 1 << max(5, (max(longest, 1) - 1).bit_length())
        if L not in self._embed_cache:
            self._embed_cache[L] = jax.jit(make_embed_step(self.cfg))
        toks = np.full((len(token_lists), L), -1, np.int32)
        for i, t in enumerate(token_lists):
            toks[i, :len(t)] = t
        emb = self._embed_cache[L](self.params, {"tokens": jnp.asarray(toks)})
        return np.asarray(emb)


def _mask_vocab(cfg, logits):
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(mask, logits, -jnp.inf)
    return logits


def _merge_row(old_tree, new_tree, row: int):
    """Take row ``row`` (batch dim = axis 1 under the stacked-layer axis 0)
    from new_tree, everything else from old_tree."""
    def merge(o, n):
        sel = jnp.arange(o.shape[1]) == row
        shape = [1, o.shape[1]] + [1] * (o.ndim - 2)
        return jnp.where(sel.reshape(shape), n, o)
    return jax.tree.map(merge, old_tree, new_tree)
